"""Beyond-the-figures ablations grounded in the paper's discussion sections.

* estimator ablation (paper §4.1): Zen vs Lwb vs Upb quality at equal k —
  quantifies how much of Zen's win comes from the zenith geometry;
* dimension profile (paper §5 quality-profile protocol): Kruskal stress for
  zen/pca as k sweeps down to 2 — the "2-d beats 80-d" effect;
* reference-selection (paper §7.2): random refs vs mutually-close refs vs
  far-apart refs — the paper reports close references improve the small-
  distance weakness; measured here on kNN recall and Kruskal stress.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    NSimplexTransform,
    PCATransform,
    metrics as M,
    quality as Q,
)
from repro.core.zen import estimate_triple
from repro.data import synthetic as syn


def _pairs(D):
    return D[np.triu_indices(D.shape[0], 1)]


def estimator_ablation(n: int = 250, m: int = 200, k: int = 16,
                       seed: int = 0) -> Dict[str, float]:
    key = jax.random.PRNGKey(seed)
    X = syn.manifold_space(key, n + k, m, m // 8)
    refs, X = X[:k], X[k:]
    tr = NSimplexTransform(k=k).fit(refs)
    Xp = tr.transform(X)
    D = np.asarray(M.euclidean_pdist(X, X))
    delta = _pairs(D)
    lwb, zen, upb = (np.asarray(a) for a in estimate_triple(Xp, Xp))
    return {
        f"{name}_kruskal": Q.kruskal_stress(delta, _pairs(z))
        for name, z in (("lwb", lwb), ("zen", zen), ("upb", upb))
    }


def dimension_profile(ks=(2, 4, 8, 16, 32, 64), n: int = 220, m: int = 100,
                      seed: int = 0) -> Dict[str, float]:
    """zen stress at each k + pca stress at max(ks) — the headline effect is
    zen@2 <= pca@64."""
    key = jax.random.PRNGKey(seed)
    X = syn.uniform_space(key, n, m)
    D = np.asarray(M.euclidean_pdist(X, X))
    delta = _pairs(D)
    out = {}
    for k in ks:
        refs = syn.uniform_space(jax.random.fold_in(key, k), k, m)
        tr = NSimplexTransform(k=k).fit(refs)
        Xp = tr.transform(X)
        _, zen, _ = estimate_triple(Xp, Xp)
        out[f"zen_k{k}"] = Q.kruskal_stress(delta, _pairs(np.asarray(zen)))
    pca = PCATransform(k=max(ks)).fit(syn.uniform_space(
        jax.random.fold_in(key, 999), 1000, m))
    Xp = pca.transform(X)
    out[f"pca_k{max(ks)}"] = Q.kruskal_stress(
        delta, _pairs(np.asarray(M.euclidean_pdist(Xp, Xp))))
    return out


def _fit_with_refs(refs, X):
    tr = NSimplexTransform(k=refs.shape[0]).fit(refs)
    Xp = tr.transform(X)
    _, zen, _ = estimate_triple(Xp, Xp)
    return np.asarray(zen)


def reference_selection(n: int = 200, m: int = 100, k: int = 10,
                        seed: int = 0) -> Dict[str, float]:
    """random vs close vs spread reference sets (paper §7.2)."""
    key = jax.random.PRNGKey(seed)
    pool = syn.uniform_space(key, 2000, m)
    X = syn.uniform_space(jax.random.fold_in(key, 1), n, m)
    D_true = np.asarray(M.euclidean_pdist(X, X))
    delta = _pairs(D_true)
    true_nn = np.argsort(D_true + np.eye(n) * 1e9, axis=1)[:, :10]

    rng = np.random.default_rng(seed)
    variants = {}
    variants["random"] = pool[rng.choice(2000, k, replace=False)]
    # mutually close: k nearest neighbours of a random anchor
    anchor = pool[int(rng.integers(0, 2000))][None]
    d_anchor = np.asarray(M.euclidean_pdist(jnp.asarray(anchor), pool))[0]
    variants["close"] = pool[np.argsort(d_anchor)[:k]]
    # spread: greedy max-min farthest-point sample
    chosen = [int(rng.integers(0, 2000))]
    dmat = np.asarray(M.euclidean_pdist(pool, pool))
    for _ in range(k - 1):
        dmin = dmat[:, chosen].min(axis=1)
        chosen.append(int(dmin.argmax()))
    variants["spread"] = pool[np.array(chosen)]

    out = {}
    for name, refs in variants.items():
        zen = _fit_with_refs(jnp.asarray(refs), X)
        out[f"{name}_kruskal"] = Q.kruskal_stress(delta, _pairs(zen))
        approx_nn = np.argsort(zen + np.eye(n) * 1e9, axis=1)[:, :10]
        out[f"{name}_nn_overlap"] = float(np.mean([
            len(set(true_nn[i]) & set(approx_nn[i])) / 10 for i in range(n)
        ]))
    return out
