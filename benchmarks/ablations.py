"""Beyond-the-figures ablations grounded in the paper's discussion sections.

* estimator ablation (paper §4.1): Zen vs Lwb vs Upb quality at equal k —
  quantifies how much of Zen's win comes from the zenith geometry;
* dimension profile (paper §5 quality-profile protocol): Kruskal stress for
  zen/pca as k sweeps down to 2 — the "2-d beats 80-d" effect;
* reference-selection (paper §7.2): random refs vs mutually-close refs vs
  far-apart refs — the paper reports close references improve the small-
  distance weakness; measured here on kNN recall and Kruskal stress.
* pivot-strategy (``core.pivots``): the paper's random pivot redraw vs the
  principled strategies (kmeanspp / farthest_first / maxvol) at fixed k,
  on query->corpus recall@10 and Kruskal stress;
* PQ compression (``kernels.pq``): recall@10 of the product-quantised IVF
  tier as the subspace count M sweeps the bytes-per-member from k/4 down
  to 1 — the recall-vs-compression frontier behind ``storage="pq"``.

Runnable directly (CI): ``python benchmarks/ablations.py [--smoke]`` prints
one ``name,derived`` CSV row per ablation.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    NSimplexTransform,
    PCATransform,
    metrics as M,
    quality as Q,
)
from repro.core.zen import estimate_triple
from repro.data import synthetic as syn


def _pairs(D):
    return D[np.triu_indices(D.shape[0], 1)]


def estimator_ablation(n: int = 250, m: int = 200, k: int = 16,
                       seed: int = 0) -> Dict[str, float]:
    key = jax.random.PRNGKey(seed)
    X = syn.manifold_space(key, n + k, m, m // 8)
    refs, X = X[:k], X[k:]
    tr = NSimplexTransform(k=k).fit(refs)
    Xp = tr.transform(X)
    D = np.asarray(M.euclidean_pdist(X, X))
    delta = _pairs(D)
    lwb, zen, upb = (np.asarray(a) for a in estimate_triple(Xp, Xp))
    return {
        f"{name}_kruskal": Q.kruskal_stress(delta, _pairs(z))
        for name, z in (("lwb", lwb), ("zen", zen), ("upb", upb))
    }


def dimension_profile(ks=(2, 4, 8, 16, 32, 64), n: int = 220, m: int = 100,
                      seed: int = 0) -> Dict[str, float]:
    """zen stress at each k + pca stress at max(ks) — the headline effect is
    zen@2 <= pca@64."""
    key = jax.random.PRNGKey(seed)
    X = syn.uniform_space(key, n, m)
    D = np.asarray(M.euclidean_pdist(X, X))
    delta = _pairs(D)
    out = {}
    for k in ks:
        refs = syn.uniform_space(jax.random.fold_in(key, k), k, m)
        tr = NSimplexTransform(k=k).fit(refs)
        Xp = tr.transform(X)
        _, zen, _ = estimate_triple(Xp, Xp)
        out[f"zen_k{k}"] = Q.kruskal_stress(delta, _pairs(np.asarray(zen)))
    pca = PCATransform(k=max(ks)).fit(syn.uniform_space(
        jax.random.fold_in(key, 999), 1000, m))
    Xp = pca.transform(X)
    out[f"pca_k{max(ks)}"] = Q.kruskal_stress(
        delta, _pairs(np.asarray(M.euclidean_pdist(Xp, Xp))))
    return out


def _fit_with_refs(refs, X):
    tr = NSimplexTransform(k=refs.shape[0]).fit(refs)
    Xp = tr.transform(X)
    _, zen, _ = estimate_triple(Xp, Xp)
    return np.asarray(zen)


def reference_selection(n: int = 200, m: int = 100, k: int = 10,
                        seed: int = 0) -> Dict[str, float]:
    """random vs close vs spread reference sets (paper §7.2)."""
    key = jax.random.PRNGKey(seed)
    pool = syn.uniform_space(key, 2000, m)
    X = syn.uniform_space(jax.random.fold_in(key, 1), n, m)
    D_true = np.asarray(M.euclidean_pdist(X, X))
    delta = _pairs(D_true)
    true_nn = np.argsort(D_true + np.eye(n) * 1e9, axis=1)[:, :10]

    rng = np.random.default_rng(seed)
    variants = {}
    variants["random"] = pool[rng.choice(2000, k, replace=False)]
    # mutually close: k nearest neighbours of a random anchor
    anchor = pool[int(rng.integers(0, 2000))][None]
    d_anchor = np.asarray(M.euclidean_pdist(jnp.asarray(anchor), pool))[0]
    variants["close"] = pool[np.argsort(d_anchor)[:k]]
    # spread: greedy max-min farthest-point sample
    chosen = [int(rng.integers(0, 2000))]
    dmat = np.asarray(M.euclidean_pdist(pool, pool))
    for _ in range(k - 1):
        dmin = dmat[:, chosen].min(axis=1)
        chosen.append(int(dmin.argmax()))
    variants["spread"] = pool[np.array(chosen)]

    out = {}
    for name, refs in variants.items():
        zen = _fit_with_refs(jnp.asarray(refs), X)
        out[f"{name}_kruskal"] = Q.kruskal_stress(delta, _pairs(zen))
        approx_nn = np.argsort(zen + np.eye(n) * 1e9, axis=1)[:, :10]
        out[f"{name}_nn_overlap"] = float(np.mean([
            len(set(true_nn[i]) & set(approx_nn[i])) / 10 for i in range(n)
        ]))
    return out


def _recall(true_nn: np.ndarray, approx_nn: np.ndarray) -> float:
    nn = true_nn.shape[1]
    return float(np.mean([
        len(set(true_nn[i]) & set(approx_nn[i])) / nn
        for i in range(true_nn.shape[0])
    ]))


def pivot_strategy_ablation(
    n: int = 1500, m: int = 64, k: int = 12, n_queries: int = 64,
    nn: int = 10, seed: int = 0,
) -> Dict[str, float]:
    """Recall@nn and stress per base-simplex strategy at fixed k.

    Same corpus, same key, same k — only ``core.pivots`` strategy varies:
    the paper's random redraw loop against kmeanspp / farthest_first /
    maxvol. Recall is query->corpus under the Zen estimator against true
    Euclidean neighbours; at least one principled strategy is expected to
    beat random (pinned by the BENCH snapshot).
    """
    from repro.core import pivots as pivots_lib
    from repro.core.zen import estimate_pdist

    key = jax.random.PRNGKey(seed)
    X = syn.manifold_space(key, n, m, m // 8)
    Qv = syn.manifold_space(jax.random.fold_in(key, 1), n_queries, m, m // 8)
    D_true = np.asarray(M.euclidean_pdist(Qv, X))
    true_nn = np.argsort(D_true, axis=1)[:, :nn]
    out = {}
    for strategy in pivots_lib.PIVOT_STRATEGIES:
        tr = pivots_lib.select_references(
            X, k, jax.random.fold_in(key, 2), strategy=strategy)
        zen = np.asarray(estimate_pdist(tr.transform(Qv), tr.transform(X),
                                        "zen"))
        out[f"{strategy}_recall{nn}"] = _recall(
            true_nn, np.argsort(zen, axis=1)[:, :nn])
        out[f"{strategy}_kruskal"] = Q.kruskal_stress(
            D_true.ravel(), zen.ravel())
    return out


def pq_compression_ablation(
    n: int = 4000, m: int = 64, k: int = 16, n_queries: int = 32,
    nn: int = 10, nprobe: int = 8, subspaces=(16, 8, 4, 2), seed: int = 0,
) -> Dict[str, float]:
    """Recall@nn vs PQ compression as the subspace count M sweeps down.

    One f32 IVF index is the baseline; each PQ index re-uses the same
    coarse quantizer key, so the only variable is bytes-per-member
    (M uint8 codes vs k f32 coordinates — compression counts the codebook
    overhead too). Recall is measured against the f32 index at
    ``nprobe = n_clusters`` (the flat-exact equivalent), raw probe output —
    no rerank — so the curve isolates what the codes alone retain.
    """
    from repro.core.projection import fit_transform
    from repro.index import IVFZenIndex

    key = jax.random.PRNGKey(seed)
    X = syn.manifold_space(key, n + n_queries, m, m // 8)
    Qv, X = X[:n_queries], X[n_queries:]
    tr, Xp = fit_transform(X, k, jax.random.fold_in(key, 1))
    Qp = tr.transform(Qv)
    n_clusters = max(16, int(round(4 * n ** 0.5)))
    f32 = IVFZenIndex.build(Xp, n_clusters, key=jax.random.fold_in(key, 2))
    truth = np.asarray(f32.search(Qp, nn, nprobe=f32.n_clusters)[1])
    base_bytes = f32.tile_coords.nbytes
    out = {
        "float32_mb": base_bytes / 2**20,
        f"float32_nprobe{nprobe}_recall{nn}": _recall(
            truth, np.asarray(f32.search(Qp, nn, nprobe=nprobe)[1])),
    }
    for mcount in subspaces:
        if mcount > k:
            continue
        pq = IVFZenIndex.build(
            Xp, n_clusters, key=jax.random.fold_in(key, 2), storage="pq",
            pq_m=mcount)
        ids = np.asarray(pq.search(Qp, nn, nprobe=nprobe)[1])
        pq_bytes = pq.tile_coords.nbytes + pq.codebooks.nbytes
        out[f"pq_m{mcount}_recall{nn}"] = _recall(truth, ids)
        out[f"pq_m{mcount}_compression"] = base_bytes / pq_bytes
    return out


def main() -> None:
    """CLI: run every ablation, print ``name,derived`` CSV rows."""
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized shapes (smaller corpora, same protocol)")
    args = p.parse_args()

    runs = [
        ("ablate_estimator_zen_vs_bounds", estimator_ablation, {}),
        ("ablate_dim_profile_100d", dimension_profile,
         {"ks": (2, 8, 32)} if args.smoke else {}),
        ("ablate_reference_choice", reference_selection, {}),
        ("ablate_pivot_strategy", pivot_strategy_ablation,
         {"n": 600, "n_queries": 32} if args.smoke else {}),
        ("ablate_pq_compression", pq_compression_ablation,
         {"n": 1500, "subspaces": (8, 4)} if args.smoke else {}),
    ]
    print("name,derived")
    for name, fn, kw in runs:
        res = fn(**kw)
        print(name + "," + ";".join(f"{k}={v:.4f}" for k, v in res.items()))


if __name__ == "__main__":
    main()
