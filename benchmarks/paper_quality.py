"""Paper-experiment replications (Figures 5-20): quality of nSimplex Zen vs
PCA / RP / MDS / LMDS over every space class in Table 3, at CPU-friendly
scale (same protocol, smaller n; the paper's qualitative ordering is the
claim being validated — see EXPERIMENTS.md §Paper-validation).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    LMDSTransform,
    MDSTransform,
    NSimplexTransform,
    PCATransform,
    RandomProjection,
    metrics as M,
    quality as Q,
    select_references,
    zen_pdist,
)
from repro.data import synthetic as syn


def _pairs(D: np.ndarray) -> np.ndarray:
    return D[np.triu_indices(D.shape[0], 1)]


def euclidean_comparison(
    space: str, n_witness: int, n_eval: int, m: int, k: int, seed: int = 0
) -> Dict[str, Dict[str, float]]:
    """One (space, k) cell of the paper's Euclidean comparisons.

    space: uniform | gaussian | manifold | relu  (paper §5.3-§5.5).
    Returns {transform: {kruskal, sammon, spearman, qloss}}.
    """
    key = jax.random.PRNGKey(seed)
    maker = {
        "uniform": lambda kk, n: syn.uniform_space(kk, n, m),
        "gaussian": lambda kk, n: syn.gaussian_space(kk, n, m),
        "manifold": lambda kk, n: syn.manifold_space(kk, n, m, max(m // 8, 4)),
        "relu": lambda kk, n: syn.relu_feature_space(kk, n, m, max(m // 8, 4)),
    }[space]
    witness = maker(key, n_witness)
    X = maker(jax.random.fold_in(key, 1), n_eval)
    metric = "cosine" if space == "relu" else "euclidean"
    if metric == "cosine":
        witness = M.l2_normalize(witness)
        X = M.l2_normalize(X)

    D_true = np.asarray(M.pairwise(metric, X, X))
    delta = _pairs(D_true)
    out: Dict[str, Dict[str, float]] = {}

    def add(name, zeta):
        out[name] = {
            "kruskal": Q.kruskal_stress(delta, zeta),
            "sammon": Q.sammon_stress(delta, zeta),
            "spearman": Q.spearman_rho(delta, zeta),
            "qloss": Q.quadratic_loss(delta, zeta) / delta.size,
        }

    # nSimplex Zen (k references drawn from the witness set)
    tr = select_references(witness, k, jax.random.fold_in(key, 2), metric=metric)
    Xz = tr.transform(X)
    add("zen", _pairs(np.asarray(zen_pdist(Xz, Xz))))

    pca = PCATransform(k=k).fit(witness)
    Xp = pca.transform(X)
    add("pca", _pairs(np.asarray(M.euclidean_pdist(Xp, Xp))))

    rp = RandomProjection(k=k).fit(int(X.shape[1]), key=jax.random.fold_in(key, 3))
    Xr = rp.transform(X)
    add("rp", _pairs(np.asarray(M.euclidean_pdist(Xr, Xr))))

    mds = MDSTransform(k=k).fit(witness[: min(400, n_witness)])
    Xm = mds.transform(X)
    add("mds", _pairs(np.asarray(M.euclidean_pdist(Xm, Xm))))
    return out


def jsd_comparison(
    n_eval: int, m: int, k: int, seed: int = 0, real_manifold: bool = False
) -> Dict[str, Dict[str, float]]:
    """Coordinate-free JSD space: nSimplex Zen vs LMDS (paper §5.6)."""
    key = jax.random.PRNGKey(seed)
    X = syn.probability_space(key, n_eval + k, m,
                              intrinsic=m // 6 if real_manifold else None)
    R, X = X[:k], X[k:]
    D_refs = np.array(M.jsd_pdist(R, R, assume_normalized=True))
    np.fill_diagonal(D_refs, 0.0)
    D_xr = M.jsd_pdist(X, R, assume_normalized=True)
    D_true = np.asarray(M.jsd_pdist(X, X, assume_normalized=True))
    delta = _pairs(D_true)
    out: Dict[str, Dict[str, float]] = {}

    tr = NSimplexTransform.from_distances(D_refs)
    Xz = tr.transform_from_distances(D_xr)
    zeta = _pairs(np.asarray(zen_pdist(Xz, Xz)))
    out["zen"] = {
        "kruskal": Q.kruskal_stress(delta, zeta),
        "sammon": Q.sammon_stress(delta, zeta),
        "spearman": Q.spearman_rho(delta, zeta),
    }

    lmds = LMDSTransform(k=k).fit_from_distances(jnp.asarray(D_refs))
    Xl = lmds.transform_from_distances(D_xr)
    zeta = _pairs(np.asarray(M.euclidean_pdist(Xl, Xl)))
    out["lmds"] = {
        "kruskal": Q.kruskal_stress(delta, zeta),
        "sammon": Q.sammon_stress(delta, zeta),
        "spearman": Q.spearman_rho(delta, zeta),
    }
    return out


def recall_comparison(
    n_corpus: int, n_queries: int, m: int, k: int, n_nn: int = 100,
    seed: int = 0, space: str = "manifold",
) -> Dict[str, float]:
    """kNN DCG recall (paper Appendix E.3), zen vs pca vs rp."""
    key = jax.random.PRNGKey(seed)
    maker = {
        "manifold": lambda kk, n: syn.manifold_space(kk, n, m, max(m // 8, 4)),
        "uniform": lambda kk, n: syn.uniform_space(kk, n, m),
    }[space]
    corpus = maker(key, n_corpus)
    queries = maker(jax.random.fold_in(key, 1), n_queries)
    D_true = np.asarray(M.euclidean_pdist(queries, corpus))
    true_ids = np.argsort(D_true, axis=1)[:, :n_nn]

    out = {}
    tr = select_references(corpus, k, jax.random.fold_in(key, 2))
    cz = tr.transform(corpus)
    qz = tr.transform(queries)
    dz = np.asarray(zen_pdist(qz, cz))
    out["zen"] = Q.batch_dcg_recall(true_ids, np.argsort(dz, 1)[:, :n_nn])

    pca = PCATransform(k=k).fit(corpus[:1000])
    dp = np.asarray(M.euclidean_pdist(pca.transform(queries), pca.transform(corpus)))
    out["pca"] = Q.batch_dcg_recall(true_ids, np.argsort(dp, 1)[:, :n_nn])

    rp = RandomProjection(k=k).fit(m, key=jax.random.fold_in(key, 3))
    dr = np.asarray(M.euclidean_pdist(rp.transform(queries), rp.transform(corpus)))
    out["rp"] = Q.batch_dcg_recall(true_ids, np.argsort(dr, 1)[:, :n_nn])
    return out


def bounds_validation(n: int, m: int, k: int, seed: int = 0) -> Dict[str, float]:
    """Lemma C.2 at benchmark scale: violation counts must be zero."""
    key = jax.random.PRNGKey(seed)
    X = syn.gaussian_space(key, n, m)
    tr = select_references(X, k, jax.random.fold_in(key, 1))
    Xp = tr.transform(X)
    from repro.core.zen import estimate_triple

    lwb, zen, upb = (np.asarray(a) for a in estimate_triple(Xp, Xp))
    D = np.asarray(M.euclidean_pdist(X, X))
    # the bounds hold mathematically; in f32 the nx+ny-2p cancellation leaves
    # ~1e-3-of-scale noise at near-zero distances (float64 property tests in
    # tests/test_core_simplex.py verify the exact inequality)
    tol = 1e-3 * D.max()
    mask = ~np.eye(n, dtype=bool)
    return {
        "lwb_violations": int((lwb > D + tol).sum()),
        "upb_violations": int((D > upb + tol).sum()),
        "order_violations": int(((lwb > zen + tol) | (zen > upb + tol)).sum()),
        "max_violation_over_scale": float(
            max((lwb - D).max(), (D - upb).max(), 0.0) / D.max()),
        "zen_rel_err": float(np.mean(np.abs(zen - D)[mask] / D[mask])),
        "lwb_rel_err": float(np.mean(np.abs(lwb - D)[mask] / D[mask])),
    }
