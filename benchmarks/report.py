"""Assemble EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

Usage: PYTHONPATH=src python -m benchmarks.report [--write]
  --write updates the AUTOGEN-marked sections of EXPERIMENTS.md in place.
"""
from __future__ import annotations

import argparse
import os

from benchmarks.roofline import fmt_table, load_artifacts, roofline_row

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def dryrun_table(rows: list[dict], arts: list[dict], mesh: str) -> str:
    hdr = ("| arch | shape | status | compile_s | HLO GFLOP/dev "
           "| peak GiB/dev | collective MiB/dev | collective ops |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    by_key = {(a["arch"], a["shape"], a["mesh"]): a for a in arts}
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        a = by_key[(r["arch"], r["shape"], mesh)]
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP (mandated) "
                         f"| — | — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {a['compile_s']:.1f} "
            f"| {r['hlo_flops_dev']/1e9:.1f} | {r['peak_gib']:.2f} "
            f"| {a['collectives']['total_bytes']/2**20:.1f} "
            f"| {a['collectives']['total_count']} |")
    return "\n".join(lines)


def collective_mix(arts: list[dict], mesh: str) -> str:
    lines = ["| arch | shape | all-reduce | all-gather | reduce-scatter "
             "| all-to-all | collective-permute |", "|" + "---|" * 7]
    for a in arts:
        if a.get("mesh") != mesh or a.get("status") != "ok":
            continue
        c = a["collectives"]
        f = lambda k: f"{c[k]['bytes']/2**20:.1f}MiB/{c[k]['count']}"
        lines.append(
            f"| {a['arch']} | {a['shape']} | {f('all-reduce')} "
            f"| {f('all-gather')} | {f('reduce-scatter')} | {f('all-to-all')} "
            f"| {f('collective-permute')} |")
    return "\n".join(lines)


def render() -> dict:
    arts = load_artifacts()
    rows = [roofline_row(a) for a in arts]
    return {
        "DRYRUN_POD": dryrun_table(rows, arts, "pod"),
        "DRYRUN_MULTIPOD": dryrun_table(rows, arts, "multipod"),
        "ROOFLINE_POD": fmt_table(rows, "pod"),
        "COLLECTIVES_POD": collective_mix(arts, "pod"),
    }


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--write", action="store_true")
    args = p.parse_args()
    sections = render()
    if not args.write:
        for name, table in sections.items():
            print(f"\n## {name}\n{table}")
        return
    path = os.path.join(REPO, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    for name, table in sections.items():
        start = f"<!-- AUTOGEN:{name} -->"
        end = f"<!-- /AUTOGEN:{name} -->"
        if start in text:
            pre, rest = text.split(start, 1)
            _, post = rest.split(end, 1)
            text = pre + start + "\n" + table + "\n" + end + post
    with open(path, "w") as f:
        f.write(text)
    print(f"updated {path}")


if __name__ == "__main__":
    main()
