"""Benchmark harness — one entry per paper table/figure plus kernel,
transform and retrieval micro-benchmarks. Prints ``name,us_per_call,derived``
CSV.  ``--workload retrieval_topk`` runs only the serving hot-path comparison
(dense vs streaming vs sharded top-k; QPS + XLA peak temp memory);
``--smoke`` shrinks it to a CI-sized index.

  figs 5-6   euclid_uniform_100   Kruskal/quality, 100d uniform -> 80/10d
  figs 7-8   euclid_uniform_500   500d uniform -> 400d
  figs 9-10  euclid_manifold      GloVe-like manifold (200d -> 120/16d)
  figs 11-12 recall_manifold      kNN DCG recall (CNN-feature-like)
  figs 13-16 cosine_relu          RELU'd features under cosine
  figs 17-20 jsd_generated/gist   coordinate-free JSD spaces vs LMDS
  fig 21     runtime_*            transform creation + per-object apply cost
  lemma C.2  bounds               Lwb <= d <= Upb validation
  kernels    kernel_*             pallas (interpret) vs jnp reference oracle

Scales are CPU-friendly (same protocol as the paper at reduced n); §Perf in
EXPERIMENTS.md documents the mapping to the paper's full-size runs.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

# ``python benchmarks/run.py`` puts benchmarks/ (not the repo root) on
# sys.path, which silently breaks every ``from benchmarks.X import ...``
# inside the workload bodies; anchor the repo root explicitly
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)


def _timeit(fn, *args, repeat: int = 3, number: int = 1) -> float:
    """Best-of wall time per call in microseconds (jit-warmed)."""
    fn(*args)  # warmup / compile
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            r = fn(*args)
        if isinstance(r, jax.Array):
            r.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6


_ROWS: list = []  # collected (name, us, derived) rows for --json snapshots


def _row(name: str, us: float, derived: str) -> None:
    _ROWS.append({"name": name, "us_per_call": round(us, 1),
                  "derived": derived})
    print(f"{name},{us:.1f},{derived}")


def bench_euclidean_spaces(smoke: bool = False) -> None:
    from benchmarks.paper_quality import euclidean_comparison

    # the 500d case reduces to k=400: the witness must stay >= max k
    n_witness, n_eval = (500, 80) if smoke else (1000, 220)
    for name, space, m, ks in [
        ("euclid_uniform_100", "uniform", 100, (80, 10)),
        ("euclid_uniform_500", "uniform", 500, (400, 20)),
        ("euclid_manifold_200", "manifold", 200, (120, 16)),
        ("cosine_relu_256", "relu", 256, (64, 16)),
    ]:
        for k in ks:
            t0 = time.perf_counter()
            res = euclidean_comparison(space, n_witness=n_witness,
                                       n_eval=n_eval, m=m, k=k)
            dt = (time.perf_counter() - t0) * 1e6
            derived = ";".join(
                f"{tr}_kruskal={res[tr]['kruskal']:.4f}" for tr in
                ("zen", "pca", "rp", "mds"))
            derived += f";zen_rho={res['zen']['spearman']:.4f}"
            _row(f"{name}_k{k}", dt, derived)


def bench_jsd_spaces(smoke: bool = False) -> None:
    from benchmarks.paper_quality import jsd_comparison

    n_eval = 80 if smoke else 200
    for name, m, k, manifold in [
        ("jsd_generated_100", 100, 20, False),
        ("jsd_gistlike_480", 480, 24, True),
    ]:
        t0 = time.perf_counter()
        res = jsd_comparison(n_eval=n_eval, m=m, k=k, real_manifold=manifold)
        dt = (time.perf_counter() - t0) * 1e6
        _row(name, dt,
             f"zen_kruskal={res['zen']['kruskal']:.4f};"
             f"lmds_kruskal={res['lmds']['kruskal']:.4f};"
             f"zen_rho={res['zen']['spearman']:.4f};"
             f"lmds_rho={res['lmds']['spearman']:.4f}")


def bench_recall(smoke: bool = False) -> None:
    from benchmarks.paper_quality import recall_comparison

    n_corpus, n_queries = (2000, 10) if smoke else (20000, 20)
    t0 = time.perf_counter()
    res = recall_comparison(n_corpus=n_corpus, n_queries=n_queries,
                            m=256, k=16, n_nn=100)
    dt = (time.perf_counter() - t0) * 1e6
    _row("recall_manifold_256_k16", dt,
         ";".join(f"{k}_dcg={v:.4f}" for k, v in res.items()))


def bench_bounds(smoke: bool = False) -> None:
    from benchmarks.paper_quality import bounds_validation

    n = 150 if smoke else 400
    t0 = time.perf_counter()
    res = bounds_validation(n=n, m=128, k=12)
    dt = (time.perf_counter() - t0) * 1e6
    _row("bounds_lemma_c2", dt,
         ";".join(f"{k}={v}" for k, v in res.items()))


def bench_runtime_fig21() -> None:
    """Fig 21: creation + per-object application cost of each transform,
    1000-dim Euclidean -> k, PLUS the paper-faithful sequential nSimplex
    (the paper's own implementation gap this framework closes)."""
    from repro.core import (
        NSimplexTransform, PCATransform, RandomProjection,
    )
    from repro.core.simplex import apex_project_reference
    from repro.core import metrics as M
    from repro.data import synthetic as syn

    key = jax.random.PRNGKey(0)
    m, k, n_apply = 1000, 64, 2048
    witness = syn.uniform_space(key, 1024, m)
    X = syn.uniform_space(jax.random.fold_in(key, 1), n_apply, m)

    # creation costs
    t_pca = _timeit(lambda: PCATransform(k=k).fit(witness).components)
    t_rp = _timeit(lambda: RandomProjection(k=k).fit(m, key=key).matrix)
    t_ns = _timeit(lambda: NSimplexTransform(k=k).fit(witness[:k]).base.chol)
    _row("create_pca_1000d", t_pca, f"k={k}")
    _row("create_rp_1000d", t_rp, f"k={k}")
    _row("create_nsimplex_1000d", t_ns, f"k={k}")

    # application costs (per object)
    pca = PCATransform(k=k).fit(witness)
    rp = RandomProjection(k=k).fit(m, key=key)
    ns = NSimplexTransform(k=k).fit(witness[:k])
    apply_pca = jax.jit(pca.transform)
    apply_rp = jax.jit(rp.transform)
    apply_ns = jax.jit(ns.transform)
    t = _timeit(lambda: apply_pca(X)) / n_apply
    _row("apply_pca_per_obj", t, f"batch={n_apply}")
    t = _timeit(lambda: apply_rp(X)) / n_apply
    _row("apply_rp_per_obj", t, f"batch={n_apply}")
    t = _timeit(lambda: apply_ns(X)) / n_apply
    _row("apply_nsimplex_batched_per_obj", t,
         f"batch={n_apply};TPU-native Cholesky+triangular-solve path")

    # paper-faithful sequential ApexAddition (the paper's reported ~100x gap)
    D_refs = np.array(M.euclidean_pdist(ns.refs, ns.refs))
    np.fill_diagonal(D_refs, 0.0)
    dists = np.asarray(M.euclidean_pdist(X[:64], ns.refs))
    t0 = time.perf_counter()
    apex_project_reference(D_refs, dists)
    t_seq = (time.perf_counter() - t0) * 1e6 / 64
    _row("apply_nsimplex_paper_sequential_per_obj", t_seq,
         "verbatim Algorithm 2 loop (paper-faithful baseline)")


def bench_kernels() -> None:
    from repro.kernels import jsd as jsd_k
    from repro.kernels import pdist as pdist_k
    from repro.kernels import ref
    from repro.kernels import zen as zen_k

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    R = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    t = _timeit(lambda: ref.pdist_sq_ref(X, R))
    _row("kernel_pdist_ref_512x128x256", t, "jnp oracle (XLA:CPU)")
    t = _timeit(lambda: pdist_k.pdist_sq(X, R, interpret=True))
    _row("kernel_pdist_interp_512x128x256", t,
         "pallas interpret mode (correctness path; TPU is the perf target)")

    Xp = jnp.asarray(rng.normal(size=(512, 32)), jnp.float32)
    Yp = jnp.asarray(rng.normal(size=(512, 32)), jnp.float32)
    t = _timeit(lambda: ref.zen_estimate_ref(Xp, Yp))
    _row("kernel_zen_ref_512x512x32", t, "jnp oracle")
    t = _timeit(lambda: zen_k.zen_estimate(Xp, Yp, interpret=True))
    _row("kernel_zen_interp_512x512x32", t, "pallas interpret mode")

    P = jnp.asarray(rng.uniform(size=(128, 128)), jnp.float32)
    P = P / P.sum(1, keepdims=True)
    t = _timeit(lambda: ref.jsd_pdist_ref(P, P))
    _row("kernel_jsd_ref_128x128x128", t, "jnp oracle")
    t = _timeit(lambda: jsd_k.jsd_pdist(P, P, interpret=True))
    _row("kernel_jsd_interp_128x128x128", t, "pallas interpret mode")


def bench_ablations(smoke: bool = False) -> None:
    """Paper §4.1 / §7.2 ablations: estimator choice, dim profile, ref and
    pivot-strategy choice, PQ compression sweep (benchmarks/ablations.py)."""
    import time as _t

    from benchmarks.ablations import (
        dimension_profile, estimator_ablation, pivot_strategy_ablation,
        pq_compression_ablation, reference_selection,
    )

    runs = [
        ("ablate_estimator_zen_vs_bounds", estimator_ablation, {}),
        ("ablate_dim_profile_100d", dimension_profile,
         {"ks": (2, 8, 32)} if smoke else {}),
        ("ablate_reference_choice", reference_selection, {}),
        ("ablate_pivot_strategy", pivot_strategy_ablation,
         {"n": 600, "n_queries": 32} if smoke else {}),
        ("ablate_pq_compression", pq_compression_ablation,
         {"n": 1500, "subspaces": (8, 4)} if smoke else {}),
    ]
    for name, fn, kw in runs:
        t0 = _t.perf_counter()
        res = fn(**kw)
        _row(name, (_t.perf_counter() - t0) * 1e6,
             ";".join(f"{k}={v:.4f}" for k, v in res.items()))


def bench_retrieval_topk(smoke: bool = False) -> None:
    """Serving hot path at scale: dense (Q, N) materialisation vs the
    streaming fused top-k vs the sharded per-device search, on synthetic
    projected coordinates. Reports per-batch wall time, QPS and the XLA temp
    allocation (the peak transient working set) of each jitted search fn —
    the streaming path must stay flat in N while dense grows linearly."""
    import numpy as np_

    from jax.sharding import Mesh

    from repro.core import zen as Z
    from repro.distributed.retrieval import sharded_knn_search
    from repro.kernels import zen_topk as zt

    q, kdim, nn, chunk = 32, 16, 10, 4096
    sizes = [20_000] if smoke else [100_000, 1_000_000]
    mesh = Mesh(np_.asarray(jax.devices()), ("shard",))

    def temp_bytes(fn, n):
        Qs = jax.ShapeDtypeStruct((q, kdim), jnp.float32)
        Xs = jax.ShapeDtypeStruct((n, kdim), jnp.float32)
        try:
            mem = jax.jit(fn).lower(Qs, Xs).compile().memory_analysis()
            return int(mem.temp_size_in_bytes)
        except Exception:
            return -1  # backend without memory_analysis support

    key = jax.random.PRNGKey(0)
    for n in sizes:
        X = jax.random.normal(key, (n, kdim), jnp.float32)
        X = X.at[:, -1].set(jnp.abs(X[:, -1]))
        Qb = X[:q] + 0.1 * jax.random.normal(
            jax.random.fold_in(key, 1), (q, kdim), jnp.float32
        )
        paths = {
            "dense": lambda Q_, X_: Z._dense_topk(Q_, X_, nn, "zen"),
            "stream": lambda Q_, X_: zt.zen_topk_scan(
                Q_, X_, nn, "zen", chunk=chunk
            ),
            "sharded": lambda Q_, X_: sharded_knn_search(
                Q_, X_, nn, "zen", mesh=mesh, chunk=chunk
            ),
        }
        for name, fn in paths.items():
            t = _timeit(lambda: fn(Qb, X)[0], repeat=2)
            tb = temp_bytes(fn, n)
            mb = f"{tb / 2**20:.2f}" if tb >= 0 else "n/a"
            _row(
                f"retrieval_topk_{name}_n{n}", t,
                f"qps={q / (t * 1e-6):.0f};peak_temp_mb={mb};"
                f"neighbors={nn};chunk={chunk}",
            )


def bench_retrieval_ivf(smoke: bool = False) -> None:
    """Clustered IVF vs flat streaming retrieval on the serving hot path:
    project a manifold corpus to (N, k) apex coordinates (the paper
    pipeline), build a k-means coarse quantizer over them, then sweep
    ``nprobe`` reporting QPS and recall@10 against the flat streaming scan
    over the same coordinates. Also reports the XLA peak temp allocation of
    the probe at two index sizes with the tile geometry fixed — like the
    flat streaming path, the probe's working set is one tile per query, flat
    in N."""
    from repro.core.projection import select_references
    from repro.core.quality import recall_at_k
    from repro.data import synthetic as syn
    from repro.index import IVFZenIndex
    from repro.kernels import ivf_probe as ip
    from repro.kernels import zen_topk as zt

    q, dim, kdim, nn, chunk = 32, 128, 16, 10, 4096
    n = 20_000 if smoke else 200_000
    n_clusters = max(64, int(round(4 * n**0.5)))
    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, n, dim, 8)
    tr = select_references(corpus, kdim, jax.random.fold_in(key, 1))
    X = tr.transform(corpus).astype(jnp.float32)
    Qb = tr.transform(
        syn.manifold_space(jax.random.fold_in(key, 3), q, dim, 8)
    ).astype(jnp.float32)

    t0 = time.perf_counter()
    index = IVFZenIndex.build(
        X, n_clusters, key=jax.random.fold_in(key, 2),
        n_iters=8 if smoke else 10,
    )
    _row(f"retrieval_ivf_build_n{n}", (time.perf_counter() - t0) * 1e6,
         f"clusters={index.n_clusters};tiles_per_cluster="
         f"{index.tiles_per_cluster};tile_rows={index.tile_rows}")

    flat = lambda: zt.zen_topk_scan(Qb, X, nn, "zen", chunk=chunk)
    flat_ids = np.asarray(flat()[1])  # also compiles ahead of the timing loop
    t_flat = _timeit(lambda: flat()[0], repeat=2)
    _row(f"retrieval_ivf_flat_n{n}", t_flat,
         f"qps={q / (t_flat * 1e-6):.0f};recall10=1.000;speedup=1.0x")

    for nprobe in (1, 2, 4, 8, 16, 32, 64):
        if nprobe > index.n_clusters:
            break
        fn = lambda: index.search(Qb, nn, nprobe=nprobe)
        rec = recall_at_k(flat_ids, np.asarray(fn()[1]))  # compiles too
        t = _timeit(lambda: fn()[0], repeat=2)
        _row(
            f"retrieval_ivf_nprobe{nprobe}_n{n}", t,
            f"qps={q / (t * 1e-6):.0f};recall10={rec:.3f};"
            f"speedup={t_flat / t:.1f}x;clusters={index.n_clusters}",
        )

    # memory flatness of the probe: fixed tile geometry, 8x the index rows
    nprobe_m, tile_rows, T = 8, 128, 2
    for label, n_rows in (("small", 16 * 1024), ("big", 128 * 1024)):
        n_c = n_rows // (T * tile_rows)
        shapes = (
            jax.ShapeDtypeStruct((q, kdim), jnp.float32),
            jax.ShapeDtypeStruct((n_c * T, tile_rows, kdim), jnp.float32),
            jax.ShapeDtypeStruct((n_c * T, tile_rows), jnp.int32),
            jax.ShapeDtypeStruct((q, nprobe_m), jnp.int32),
        )
        probe = lambda Q_, TC, TI, PR: ip.ivf_probe_scan(
            Q_, TC, TI, PR, nn, "zen", tiles_per_cluster=T
        )
        try:
            mem = jax.jit(probe).lower(*shapes).compile().memory_analysis()
            mb = f"{mem.temp_size_in_bytes / 2**20:.2f}"
        except Exception:
            mb = "n/a"
        _row(f"retrieval_ivf_probe_mem_{label}", 0.0,
             f"rows={n_rows};peak_temp_mb={mb}")


def bench_retrieval_churn(smoke: bool = False) -> None:
    """Mutable-corpus lifecycle on the IVF serving path: build at N, churn
    20% of the corpus (tombstone deletes + nearest-centroid upserts into
    spare tile capacity), compact if the thresholds trip, and report

      * update throughput — upserts/s and deletes/s of the control-plane
        mutation path (batched host repack + device upload);
      * recall-after-churn — recall@10 of the churned index vs a freshly
        built index over the same live corpus, at the same nprobe (the
        acceptance bar is |delta| <= 0.02);
      * QPS of the churned index, next to the fresh index's QPS;
      * a save -> load round-trip (must return identical neighbours).
    """
    from repro.core.quality import recall_at_k
    from repro.index import IVFZenIndex
    from repro.kernels import zen_topk as zt

    # synthetic apex coordinates (the test_index_mutation acceptance
    # protocol): isotropic data keeps the quantizer fit stable across seeds,
    # so the churned-vs-fresh recall delta isolates churn, not k-means++
    # seed noise (which dominates on tightly clustered corpora)
    # q=256 keeps the recall@10 sampling error (~1/sqrt(q*nn)) well under
    # the 0.02 acceptance bar
    q, kdim, nn = 256, 16, 10
    n = 20_000 if smoke else 100_000
    n_churn = n // 5
    batch = 2048
    n_clusters = max(64, int(round(4 * n**0.5)))
    key = jax.random.PRNGKey(0)

    def _coords(k_, m):
        x = jax.random.normal(k_, (m, kdim), jnp.float32)
        return x.at[:, -1].set(jnp.abs(x[:, -1]))

    X = _coords(key, n)
    Qb = X[:q] + 0.1 * jax.random.normal(
        jax.random.fold_in(key, 3), (q, kdim), jnp.float32)

    index = IVFZenIndex.build(
        X, n_clusters, key=jax.random.fold_in(key, 2), n_iters=10)

    rng = np.random.default_rng(0)
    dead = rng.choice(n, size=n_churn, replace=False)
    t0 = time.perf_counter()
    for lo in range(0, n_churn, batch):
        index = index.delete(dead[lo:lo + batch])
    t_del = time.perf_counter() - t0
    _row(f"retrieval_churn_delete_n{n}", t_del * 1e6 / (n_churn // batch + 1),
         f"deletes_per_s={n_churn / t_del:.0f};batch={batch}")

    Xnew = _coords(jax.random.fold_in(key, 4), n_churn)
    new_ids = np.arange(n, n + n_churn)
    t0 = time.perf_counter()
    for lo in range(0, n_churn, batch):
        index = index.upsert(new_ids[lo:lo + batch], Xnew[lo:lo + batch])
    t_up = time.perf_counter() - t0
    _row(f"retrieval_churn_upsert_n{n}", t_up * 1e6 / (n_churn // batch + 1),
         f"upserts_per_s={n_churn / t_up:.0f};batch={batch};"
         f"tiles_per_cluster={index.tiles_per_cluster}")

    # churn concentrates new points into the frozen quantizer's cells:
    # grow-by-tile inflates T for every cluster and the probe slows down.
    # The lifecycle answer is the re-cluster pass (ISSUE/ROADMAP): refit the
    # quantizer on the live corpus and repack minimal tiles.
    imb_pre, t_pre, ts_pre = (index.imbalance, index.tiles_per_cluster,
                              index.tombstone_ratio)
    t0 = time.perf_counter()
    index = index.compact(recluster=True, key=jax.random.fold_in(key, 6),
                          n_iters=10)
    _row(f"retrieval_churn_recluster_n{n}", (time.perf_counter() - t0) * 1e6,
         f"imbalance={imb_pre:.1f}->{index.imbalance:.1f};"
         f"tiles_per_cluster={t_pre}->{index.tiles_per_cluster};"
         f"tombstone_ratio_pre={ts_pre:.2f}")

    # ground truth over the live corpus; fresh rebuild for the recall bar
    live = np.setdiff1d(np.arange(n), dead)
    all_coords = jnp.concatenate([jnp.asarray(np.asarray(X)[live]), Xnew])
    all_ids = np.concatenate([live, new_ids])
    truth = all_ids[np.asarray(
        zt.zen_topk_scan(Qb, all_coords, nn, "zen")[1])]
    fresh = IVFZenIndex.build(
        all_coords, n_clusters, ids=all_ids,
        key=jax.random.fold_in(key, 5), n_iters=10)

    for nprobe in (8, 16):
        churn_fn = lambda: index.search(Qb, nn, nprobe=nprobe)
        fresh_fn = lambda: fresh.search(Qb, nn, nprobe=nprobe)
        rec_c = recall_at_k(truth, np.asarray(churn_fn()[1]))
        rec_f = recall_at_k(truth, np.asarray(fresh_fn()[1]))
        t_c = _timeit(lambda: churn_fn()[0], repeat=2)
        t_f = _timeit(lambda: fresh_fn()[0], repeat=2)
        _row(
            f"retrieval_churn_recall_nprobe{nprobe}_n{n}", t_c,
            f"qps={q / (t_c * 1e-6):.0f};recall10_churned={rec_c:.3f};"
            f"recall10_fresh={rec_f:.3f};delta={rec_c - rec_f:+.3f};"
            f"fresh_qps={q / (t_f * 1e-6):.0f}",
        )

    # persisted index: save -> load must return identical neighbours
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        index.save(os.path.join(td, "snap"))
        t_save = time.perf_counter() - t0
        t0 = time.perf_counter()
        back = IVFZenIndex.load(os.path.join(td, "snap"))
        t_load = time.perf_counter() - t0
        same = bool(np.array_equal(
            np.asarray(index.search(Qb, nn, nprobe=16)[1]),
            np.asarray(back.search(Qb, nn, nprobe=16)[1])))
    _row(f"retrieval_churn_checkpoint_n{n}", t_save * 1e6,
         f"save_s={t_save:.2f};load_s={t_load:.2f};roundtrip_identical={same}")


def bench_retrieval_quantized(smoke: bool = False) -> None:
    """Quantised index storage (bf16 / int8) vs fp32 on the serving hot
    path: the paper pipeline (manifold corpus -> apex coordinates), one IVF
    geometry per storage mode built from the *same* quantizer key, probed at
    matched nprobe. Reports, per storage mode:

      * resident tile bytes (tile_coords + scales) — int8 must come in at
        >= 2x below fp32 (the acceptance bar; with k=16 it is ~4x);
      * recall@10 against exact fp32 flat-scan ground truth — the delta to
        the fp32 index at the same nprobe must stay within 0.02;
      * QPS of the probe.

    The flat streaming scan gets the same treatment (per-row scales) at one
    index size, so both retrieval layouts are covered.
    """
    from repro.core.projection import select_references
    from repro.core.quality import recall_at_k
    from repro.data import synthetic as syn
    from repro.index import IVFZenIndex
    from repro.kernels import quantize as quant
    from repro.kernels import zen_topk as zt

    q, dim, kdim, nn = 32, 128, 16, 10
    n = 20_000 if smoke else 200_000
    n_clusters = max(64, int(round(4 * n**0.5)))
    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, n, dim, 8)
    tr = select_references(corpus, kdim, jax.random.fold_in(key, 1))
    X = tr.transform(corpus).astype(jnp.float32)
    Qb = tr.transform(
        syn.manifold_space(jax.random.fold_in(key, 3), q, dim, 8)
    ).astype(jnp.float32)

    # exact estimator ground truth over the f32 coordinates
    truth = np.asarray(zt.zen_topk_scan(Qb, X, nn, "zen")[1])

    # flat streaming scan: per-row scales
    for storage in quant.SCALAR_STORAGE_DTYPES:
        vals, scales = quant.encode_rows(np.asarray(X), storage)
        vj = jnp.asarray(vals)
        sj = None if scales is None else jnp.asarray(scales)
        fn = lambda: zt.zen_topk_scan(Qb, vj, nn, "zen", scales=sj)
        rec = recall_at_k(truth, np.asarray(fn()[1]))  # also compiles
        t = _timeit(lambda: fn()[0], repeat=2)
        nbytes = vals.nbytes + (scales.nbytes if scales is not None else 0)
        _row(
            f"retrieval_quant_flat_{storage}_n{n}", t,
            f"qps={q / (t * 1e-6):.0f};recall10={rec:.3f};"
            f"index_mb={nbytes / 2**20:.2f}",
        )

    # clustered IVF probe: per-cluster scales, matched nprobe sweep
    indexes = {}
    for storage in quant.SCALAR_STORAGE_DTYPES:
        t0 = time.perf_counter()
        index = IVFZenIndex.build(
            X, n_clusters, key=jax.random.fold_in(key, 2),
            n_iters=8 if smoke else 10, storage=storage,
        )
        dt = (time.perf_counter() - t0) * 1e6
        indexes[storage] = index
        nbytes = index.tile_coords.nbytes + (
            index.tile_scales.nbytes if index.tile_scales is not None else 0)
        _row(f"retrieval_quant_ivf_build_{storage}_n{n}", dt,
             f"tile_mb={nbytes / 2**20:.2f};clusters={index.n_clusters};"
             f"tiles_per_cluster={index.tiles_per_cluster}")

    for nprobe in (8, 16):
        recalls = {}
        for storage, index in indexes.items():
            fn = lambda: index.search(Qb, nn, nprobe=nprobe)
            recalls[storage] = recall_at_k(truth, np.asarray(fn()[1]))
            t = _timeit(lambda: fn()[0], repeat=2)
            _row(
                f"retrieval_quant_ivf_{storage}_nprobe{nprobe}_n{n}", t,
                f"qps={q / (t * 1e-6):.0f};recall10={recalls[storage]:.3f};"
                f"delta_vs_f32={recalls[storage] - recalls['float32']:+.3f}",
            )


def bench_retrieval_pq(smoke: bool = False) -> None:
    """Product-quantised IVF tier vs f32: tile bytes and end-to-end recall.

    Builds the same corpus/projection/coarse-quantizer twice — ``storage=
    "float32"`` and ``storage="pq"`` (default M = k/4 -> 16x smaller tiles)
    — and serves both through the full filter-and-refine pipeline: LUT
    probe of ``rerank x nn`` candidates, then ``exact_rerank`` against the
    original vectors. Recall@10 is measured against true original-space
    neighbours, so the acceptance bar is apples-to-apples: PQ tiles >= 8x
    smaller with end-to-end recall within 0.05 of f32 at matched nprobe.
    """
    from repro.core import metrics as metrics_lib
    from repro.core.projection import select_references
    from repro.core.quality import recall_at_k
    from repro.data import synthetic as syn
    from repro.index import IVFZenIndex, exact_rerank

    q, dim, kdim, nn, rerank = 32, 128, 16, 10, 4
    n = 20_000 if smoke else 100_000
    n_clusters = max(64, int(round(4 * n**0.5)))
    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, n, dim, 8)
    tr = select_references(corpus, kdim, jax.random.fold_in(key, 1))
    X = tr.transform(corpus).astype(jnp.float32)
    qv = syn.manifold_space(jax.random.fold_in(key, 3), q, dim, 8)
    Qb = tr.transform(qv).astype(jnp.float32)

    # ground truth: true original-space neighbours
    D_true = np.asarray(metrics_lib.euclidean_pdist(qv, corpus))
    truth = np.argsort(D_true, axis=1)[:, :nn]

    indexes, nbytes = {}, {}
    for storage in ("float32", "pq"):
        t0 = time.perf_counter()
        index = IVFZenIndex.build(
            X, n_clusters, key=jax.random.fold_in(key, 2),
            n_iters=8 if smoke else 10, storage=storage,
        )
        dt = (time.perf_counter() - t0) * 1e6
        indexes[storage] = index
        nbytes[storage] = index.tile_coords.nbytes + (
            index.codebooks.nbytes if index.codebooks is not None else 0)
        _row(f"retrieval_pq_build_{storage}_n{n}", dt,
             f"tile_mb={nbytes[storage] / 2**20:.2f};"
             f"clusters={index.n_clusters};"
             f"compression_vs_f32={nbytes['float32'] / nbytes[storage]:.1f}x")

    def serve(index, nprobe):
        _, cand = index.search(Qb, rerank * nn, nprobe=nprobe)
        return exact_rerank(qv, corpus, cand, nn)

    for nprobe in (8, 16):
        recalls = {}
        for storage, index in indexes.items():
            fn = lambda: serve(index, nprobe)
            ids = np.asarray(fn()[1])
            recalls[storage] = recall_at_k(truth, ids)
            t = _timeit(lambda: fn()[0], repeat=2)
            _row(
                f"retrieval_pq_{storage}_nprobe{nprobe}_n{n}", t,
                f"qps={q / (t * 1e-6):.0f};recall10={recalls[storage]:.3f};"
                f"delta_vs_f32={recalls[storage] - recalls['float32']:+.3f};"
                f"rerank={rerank}x",
            )


def bench_retrieval_frontend(smoke: bool = False) -> None:
    """Micro-batched serving frontend vs per-caller dispatch: an open-loop
    load of many single-query callers is served (a) directly — one kernel
    dispatch per caller, the pre-frontend behaviour — and (b) through the
    ``repro.serving`` scheduler, which coalesces the backlog into padded
    power-of-two dispatches of at most ``max_batch`` rows. Reports QPS,
    the frontend's p50/p99 submit-to-resolve latency, batch occupancy,
    and the jit-cache pressure (dispatch-shape count + live jit cache
    entries — the acceptance bar is a jit cache at most the bucket-menu
    size, with >= 2x the per-caller QPS). A third pass replays a
    skew-heavy trace against the LRU result cache.
    """
    from repro.data import synthetic as syn
    from repro.index.ivf import _ivf_search
    from repro.launch.serve import ZenServer, build_index

    n = 20_000 if smoke else 100_000
    n_callers = 256 if smoke else 512
    dim, kdim, nn, max_batch = 128, 16, 10, 64
    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, n, dim, 8)
    index = build_index(corpus, kdim, index="ivf",
                        key=jax.random.fold_in(key, 2))
    qs = np.asarray(syn.manifold_space(
        jax.random.fold_in(key, 3), n_callers, dim, 8), np.float32)

    # (a) per-caller dispatch: every caller pays its own kernel launch
    direct = ZenServer(index, nprobe=8)
    direct.query(qs[:1], nn)  # warm the (Q=2 bucket, w) compile
    t0 = time.perf_counter()
    for i in range(n_callers):
        direct.query(qs[i:i + 1], nn)
    t_direct = time.perf_counter() - t0
    qps_direct = n_callers / t_direct
    _row(f"retrieval_frontend_direct_n{n}", t_direct * 1e6 / n_callers,
         f"qps={qps_direct:.0f};callers={n_callers};per_caller_dispatch")

    # (b) micro-batched frontend: the same open-loop load coalesced
    fe = ZenServer(index, nprobe=8, frontend=True, max_batch=max_batch,
                   queue_limit=n_callers)
    # clear BEFORE warming: the timed region must be as warm as the direct
    # baseline's, and jit_entries then reports the steady-state cache size
    _ivf_search._clear_cache()
    fe.query(qs[:max_batch], nn)  # warm the full-bucket compile
    t0 = time.perf_counter()
    handles = [fe.frontend.submit(qs[i], nn) for i in range(n_callers)]
    fe.frontend.flush()
    for h in handles:
        h.result()
    t_fe = time.perf_counter() - t0
    qps_fe = n_callers / t_fe
    st_ = fe.frontend.stats
    pct = st_.latency_percentiles()
    _row(
        f"retrieval_frontend_batched_n{n}", t_fe * 1e6 / n_callers,
        f"qps={qps_fe:.0f};speedup={qps_fe / qps_direct:.1f}x;"
        f"p50_ms={pct['p50_ms']:.1f};p99_ms={pct['p99_ms']:.1f};"
        f"occupancy={st_.occupancy:.2f};compile_count={st_.compile_count};"
        f"jit_entries={_ivf_search._cache_size()};max_batch={max_batch}",
    )

    # (c) skew-heavy traffic against the LRU result cache: the same
    # n_callers requests drawn from a small hot set of unique queries,
    # arriving in waves (sustained traffic — later waves hit the entries
    # the first wave filled; one all-at-once burst could never hit)
    hot = qs[:32]
    fc = ZenServer(index, nprobe=8, frontend=True, max_batch=max_batch,
                   queue_limit=n_callers, cache_size=1024)
    fc.query(hot, nn)  # warm the wave-sized (32, w) compile
    fc.frontend.cache.clear()
    t0 = time.perf_counter()
    handles = []
    for wave in range(n_callers // 32):
        handles.extend(fc.frontend.submit(hot[i], nn) for i in range(32))
        fc.frontend.flush()
    for h in handles:
        h.result()
    t_fc = time.perf_counter() - t0
    _row(
        f"retrieval_frontend_cached_n{n}", t_fc * 1e6 / n_callers,
        f"qps={n_callers / t_fc:.0f};"
        f"hit_rate={fc.frontend.cache.hit_rate:.2f};"
        f"unique_queries=32;waves={n_callers // 32};cache_rows=1024",
    )


def bench_retrieval_offload(smoke: bool = False) -> None:
    """Tiered tile store: host-resident inverted lists vs all-resident, and
    degraded-shard serving under a dead heartbeat. Two phases:

    **Memory/recall flatness** — synthetic pre-clustered apex coordinates
    (known assignment; k-means would dominate the wall clock at 1e7 rows)
    are packed into the IVF tile layout, offloaded with a *fixed* hot set,
    and probed at matched nprobe. Reported per index size:

      * device-resident bytes (centroids + hot tiles + the double-buffered
        upload allowance) — the acceptance bar is the largest size staying
        within 2x of the smallest while the host pool grows ~linearly;
      * recall@10 of the tiered search against the all-resident index at
        equal nprobe (the same kernel scores the same tiles: 1.000);
      * QPS of the tiered probe and the upload traffic behind it.

    **Degraded serving** — a ``ZenServer`` over an offloaded index with
    fault tolerance enabled (fake clock): one logical shard's heartbeat
    stops mid-run; its clusters are masked, queries keep answering (no
    raise), and the row reports the recall drop + ``degraded_shards``.
    """
    from repro.core.quality import recall_at_k
    from repro.index.ivf import IVFZenIndex, TieredIVFZenIndex

    q, kdim, nn, nprobe = 32, 8, 10, 8
    tile_rows, T, hot = 128, 2, 64
    per_cluster = tile_rows * T
    # exact multiples of the tile capacity: every cluster packs full
    cs = (781, 3906) if smoke else (3906, 39062)  # ~2e5/1e6 or ~1e6/1e7
    rng = np.random.default_rng(0)

    device_bytes = []
    for C in cs:
        n = C * per_cluster
        centroids = rng.standard_normal((C, kdim)).astype(np.float32) * 8.0
        coords = np.repeat(centroids, per_cluster, axis=0)
        coords += 0.25 * rng.standard_normal(coords.shape).astype(np.float32)
        coords[:, -1] = np.abs(coords[:, -1])
        assign = np.repeat(np.arange(C, dtype=np.int64), per_cluster)
        ids = np.arange(n, dtype=np.int64)

        t0 = time.perf_counter()
        resident = IVFZenIndex.from_members(
            coords, ids, assign, jnp.asarray(centroids), C, tile_rows)
        tiered = TieredIVFZenIndex.from_index(
            resident, hot_clusters=hot, prefetch_cols=2)
        t_build = (time.perf_counter() - t0) * 1e6
        _row(f"retrieval_offload_build_n{n}", t_build,
             f"clusters={C};hot={hot};tile_rows={tile_rows}")

        pick = rng.choice(n, size=q, replace=False)
        Qb = jnp.asarray(coords[pick]
                         + 0.05 * rng.standard_normal((q, kdim)), jnp.float32)
        res_ids = np.asarray(resident.search(Qb, nn, nprobe=nprobe)[1])
        fn = lambda: tiered.search(Qb, nn, nprobe=nprobe)
        rec = recall_at_k(res_ids, np.asarray(fn()[1]))  # also warms
        t = _timeit(lambda: fn()[0], repeat=2)
        st = tiered.stats()
        # flatness is judged on the *provisioned* peak (resident arrays +
        # the analytic staging-buffer bound for this batch shape): the
        # observed mark depends on which slot bucket the traffic happened
        # to land in, which jumps by 2x at the bucketing boundaries.
        device_bytes.append(tiered.provisioned_device_bytes(q))
        _row(
            f"retrieval_offload_probe_n{n}", t,
            f"qps={q / (t * 1e-6):.0f};recall10_vs_resident={rec:.3f};"
            f"device_mb={st['device_bytes'] / 2**20:.2f};"
            f"provisioned_mb={device_bytes[-1] / 2**20:.2f};"
            f"host_mb={st['host_bytes'] / 2**20:.2f};"
            f"uploaded_mb={st['bytes_uploaded'] / 2**20:.2f};"
            f"cold_uploads={st['cold_uploads']};nprobe={nprobe}",
        )
        del resident, tiered, coords, ids, assign
    growth = device_bytes[-1] / device_bytes[0]
    n_growth = cs[-1] / cs[0]
    _row("retrieval_offload_device_mem_growth", 0.0,
         f"device_growth={growth:.2f}x_over_{n_growth:.0f}x_rows;"
         f"flat={'yes' if growth < 2.0 else 'NO'}")

    # degraded serving: kill one logical shard's heartbeat mid-run
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenServer, build_index

    n, dim, shards = (20_000 if smoke else 100_000), 64, 4

    class _Clock:
        t = 0.0

        def __call__(self):
            return self.t

    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, n, dim, 8)
    index = build_index(corpus, 16, index="ivf", offload=True,
                        hot_clusters=16, offload_shards=shards,
                        key=jax.random.fold_in(key, 2))
    srv = ZenServer(index, nprobe=16)
    clock = _Clock()
    srv.enable_fault_tolerance(deadline_s=5.0, clock=clock)
    for s in range(shards):
        srv.heartbeat(s)
    Qs = syn.manifold_space(jax.random.fold_in(key, 3), q, dim, 8)
    resident_srv = ZenServer(
        build_index(corpus, 16, index="ivf",
                    key=jax.random.fold_in(key, 2)), nprobe=16)
    truth = np.asarray(resident_srv.query(Qs, nn)[1])

    t_h = _timeit(lambda: srv.query(Qs, nn)[0], repeat=2)
    rec_h = recall_at_k(truth, np.asarray(srv.query(Qs, nn)[1]))
    clock.t = 6.0  # shard0's heartbeat goes silent; the rest keep beating
    for s in range(1, shards):
        srv.heartbeat(s)
    rec_d = recall_at_k(truth, np.asarray(srv.query(Qs, nn)[1]))  # no raise
    st = srv.stats()
    _row(
        f"retrieval_offload_degraded_n{n}", t_h,
        f"recall10_healthy={rec_h:.3f};recall10_degraded={rec_d:.3f};"
        f"degraded_shards={','.join(st['degraded_shards']) or 'none'};"
        f"masked_clusters={st['tier']['masked_clusters']};"
        f"shards={shards};queries_raised=0",
    )


def bench_retrieval_replicated(smoke: bool = False) -> None:
    """Replicated query-plane serving + the open-loop SLO harness. Three
    phases over one published index (``repro.launch.replicate``):

    **Hot-swap under churn** — the leader churns (batched upserts +
    deletes) and republishes; an mmap'd replica polls, hot-swaps, and its
    answers are compared bit-for-bit against a direct leader query every
    round. Reports swap latency, publish latency, poll errors (must be 0)
    and the parity verdict.

    **Latency vs offered load** — one replica behind the micro-batched
    frontend is driven *open-loop* (Poisson arrivals; latency measured
    from scheduled arrival time, so coordinated omission cannot hide the
    overload region) at multiples of its admission budget — ``max_batch``
    rows per ``tick_interval``, the production SLO knob. Every power-of-two
    query bucket is compiled *before* the sweep so XLA compile time never
    pollutes a percentile. Reports p50/p95/p99, achieved QPS and the
    reject-on-full shed rate per offered rate.

    **Replica scaling** — the same offered overload against fleets of 1
    and 3 replicas (round-robin, each ticked at its own cadence; this host
    has one core, so scaling is of the *admission budget* — see
    docs/benchmarks.md). The acceptance bar is >= 2x aggregate goodput at
    R=3, with every replica still answering bit-identically to the leader.
    """
    import shutil
    import tempfile

    from repro.data import synthetic as syn
    from repro.launch.replicate import IndexLeader, QueryReplica
    from repro.launch.serve import ZenServer, build_index
    from repro.serving.loadgen import run_open_loop

    n = 20_000 if smoke else 100_000
    dim, kdim, nn = 128, 16, 10
    max_batch, tick = 32, 0.05
    budget = max_batch / tick          # admission budget, queries/s/replica
    dur = 1.0 if smoke else 4.0
    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, n, dim, 8)
    index = build_index(corpus, kdim, index="ivf",
                        key=jax.random.fold_in(key, 2))
    qs = np.asarray(syn.manifold_space(
        jax.random.fold_in(key, 3), 64, dim, 8), np.float32)

    root = tempfile.mkdtemp(prefix="zen-bench-replicated-")
    try:
        leader_srv = ZenServer(index, nprobe=8)
        leader = IndexLeader(leader_srv, root, keep=2)
        leader.publish()

        # -- phase 1: churn -> publish -> hot-swap loop, bit parity ----------
        rep = QueryReplica(root, mmap=True, nprobe=8)
        rep.poll()
        rounds = 3 if smoke else 6
        rng = np.random.default_rng(0)
        parity = True
        t_pub = t_swap = 0.0
        batch = 64
        for r in range(rounds):
            new_ids = np.arange(n + r * batch, n + (r + 1) * batch)
            leader.upsert(new_ids, syn.manifold_space(
                jax.random.fold_in(key, 100 + r), batch, dim, 8))
            leader.delete(rng.choice(n, size=batch, replace=False))
            t0 = time.perf_counter()
            leader.publish()
            t_pub += time.perf_counter() - t0
            t0 = time.perf_counter()
            swapped = rep.poll()
            t_swap += time.perf_counter() - t0
            got = rep.query(qs, nn)
            want = leader_srv.query(qs, nn, direct=True)
            parity &= bool(swapped
                           and np.array_equal(np.asarray(got[0]),
                                              np.asarray(want[0]))
                           and np.array_equal(np.asarray(got[1]),
                                              np.asarray(want[1])))
        _row(
            f"retrieval_replicated_hotswap_n{n}", t_swap * 1e6 / rounds,
            f"rounds={rounds};publish_s={t_pub / rounds:.2f};"
            f"poll_errors={rep.poll_errors};swaps={rep.swaps};"
            f"generation={rep.generation};"
            f"parity={'bit' if parity else 'DIVERGED'}",
        )

        def make_fleet(n_replicas):
            # queue_limit == max_batch makes the admission budget exactly
            # max_batch rows per tick: a tick drains the whole backlog
            # (split at max_batch), so a deeper queue would quietly raise
            # the per-replica capacity above the budget being measured
            reps = [QueryReplica(root, name=f"r{i}", mmap=True, nprobe=8,
                                 frontend=True, cache_size=0,
                                 max_batch=max_batch,
                                 queue_limit=max_batch,
                                 tick_interval=tick)
                    for i in range(n_replicas)]
            for r_ in reps:
                r_.poll()
                # compile every power-of-two Q bucket up front: a cold
                # bucket's XLA compile (hundreds of ms) would otherwise
                # land in the middle of the sweep and pollute the p99
                b = 1
                while b <= max_batch:
                    hs = [r_.server.frontend.submit(qs[i % len(qs)], nn)
                          for i in range(b)]
                    r_.server.frontend.flush()
                    for h in hs:
                        h.result()
                    b *= 2
            return reps

        # -- phase 2: open-loop latency vs offered load (one replica) -------
        fleet1 = make_fleet(1)
        for mult in (0.25, 0.5, 1.0, 2.0, 4.0):
            rr = run_open_loop([r.server for r in fleet1], qs,
                               offered_qps=mult * budget, duration_s=dur,
                               n_neighbors=nn, seed=7)
            _row(
                f"retrieval_replicated_load_x{mult:g}_n{n}",
                rr.p99_ms * 1e3,
                f"offered_qps={rr.offered_qps:.0f};"
                f"achieved_qps={rr.achieved_qps:.0f};"
                f"p50_ms={rr.p50_ms:.1f};p95_ms={rr.p95_ms:.1f};"
                f"p99_ms={rr.p99_ms:.1f};reject_rate={rr.reject_rate:.2f};"
                f"timeouts={rr.timeouts};budget_qps={budget:.0f}",
            )

        # -- phase 3: aggregate goodput scaling with replica count ----------
        offered = 3.2 * budget  # saturates one replica's admission budget
        agg = {}
        for n_replicas in (1, 3):
            fleet = make_fleet(n_replicas)
            rr = run_open_loop([r.server for r in fleet], qs,
                               offered_qps=offered, duration_s=dur,
                               n_neighbors=nn, seed=11)
            agg[n_replicas] = rr
            want = leader_srv.query(qs, nn, direct=True)
            fleet_parity = all(
                np.array_equal(np.asarray(g[0]), np.asarray(want[0]))
                and np.array_equal(np.asarray(g[1]), np.asarray(want[1]))
                for g in (r.query(qs, nn) for r in fleet))
            _row(
                f"retrieval_replicated_fleet_r{n_replicas}_n{n}",
                rr.p99_ms * 1e3,
                f"offered_qps={offered:.0f};"
                f"aggregate_qps={rr.achieved_qps:.0f};"
                f"reject_rate={rr.reject_rate:.2f};p99_ms={rr.p99_ms:.1f};"
                f"failures={rr.failures};timeouts={rr.timeouts};"
                f"parity={'bit' if fleet_parity else 'DIVERGED'}",
            )
        speedup = agg[3].achieved_qps / max(agg[1].achieved_qps, 1e-9)
        _row(
            "retrieval_replicated_scaling", 0.0,
            f"aggregate_qps_r3_vs_r1={speedup:.2f}x;bar=2.0x;"
            f"met={'yes' if speedup >= 2.0 else 'NO'};"
            f"budget_per_replica_qps={budget:.0f}",
        )
    finally:
        shutil.rmtree(root, ignore_errors=True)


def bench_serving() -> None:
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenServer, build_index

    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, 20000, 256, 32)
    index = build_index(corpus, 16)
    server = ZenServer(index, rerank_factor=4)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 64, 256, 32)
    t = _timeit(lambda: server.query(q, 10)[0])
    _row("serve_zen_batch64_20k_index", t / 64,
         "per-query; zen topk + exact rerank")


def bench_retrieval_e2e(smoke: bool = False) -> None:
    """Learned-embeddings-to-Zen-retrieval pipeline (two-tower + LM legs);
    see ``benchmarks/retrieval_e2e.py`` for the full protocol."""
    from benchmarks.retrieval_e2e import run_e2e

    run_e2e(smoke=smoke, emit=_row)


_WORKLOADS = {
    "bounds": lambda a: bench_bounds(smoke=a.smoke),
    "euclidean": lambda a: bench_euclidean_spaces(smoke=a.smoke),
    "jsd": lambda a: bench_jsd_spaces(smoke=a.smoke),
    "recall": lambda a: bench_recall(smoke=a.smoke),
    "retrieval_e2e": lambda a: bench_retrieval_e2e(smoke=a.smoke),
    "runtime": lambda a: bench_runtime_fig21(),
    "ablations": lambda a: bench_ablations(smoke=a.smoke),
    "kernels": lambda a: bench_kernels(),
    "serving": lambda a: bench_serving(),
    "retrieval_topk": lambda a: bench_retrieval_topk(smoke=a.smoke),
    "retrieval_ivf": lambda a: bench_retrieval_ivf(smoke=a.smoke),
    "retrieval_churn": lambda a: bench_retrieval_churn(smoke=a.smoke),
    "retrieval_quantized": lambda a: bench_retrieval_quantized(smoke=a.smoke),
    "retrieval_pq": lambda a: bench_retrieval_pq(smoke=a.smoke),
    "retrieval_frontend": lambda a: bench_retrieval_frontend(smoke=a.smoke),
    "retrieval_offload": lambda a: bench_retrieval_offload(smoke=a.smoke),
    "retrieval_replicated":
        lambda a: bench_retrieval_replicated(smoke=a.smoke),
}


def main() -> None:
    import argparse
    import json
    import platform

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--workload", default="all",
                   choices=["all"] + sorted(_WORKLOADS))
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized shapes (retrieval_* and paper-quality "
                        "workloads)")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="also write the rows as a JSON snapshot (the "
                        "BENCH_*.json trajectory format, see "
                        "docs/benchmarks.md)")
    args = p.parse_args()

    print("name,us_per_call,derived")
    if args.workload == "all":
        for fn in _WORKLOADS.values():
            fn(args)
    else:
        _WORKLOADS[args.workload](args)

    if args.json:
        # backend/device/dtype context makes a snapshot comparable across
        # machines: the same workload on a TPU pod or under x64 is a
        # different experiment and must not diff silently against a CPU run
        dev = jax.devices()[0]
        snap = {
            "workload": args.workload,
            "smoke": args.smoke,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "device_kind": getattr(dev, "device_kind", str(dev)),
            "x64_enabled": bool(jax.config.jax_enable_x64),
            "default_matmul_precision":
                str(jax.config.jax_default_matmul_precision),
            "platform": platform.platform(),
            "jax": jax.__version__,
            "rows": _ROWS,
        }
        with open(args.json, "w") as f:
            json.dump(snap, f, indent=1)
        print(f"# wrote {len(_ROWS)} rows to {args.json}")


if __name__ == "__main__":
    main()
