"""Benchmark harness — one entry per paper table/figure plus kernel,
transform and retrieval micro-benchmarks. Prints ``name,us_per_call,derived``
CSV.  ``--workload retrieval_topk`` runs only the serving hot-path comparison
(dense vs streaming vs sharded top-k; QPS + XLA peak temp memory);
``--smoke`` shrinks it to a CI-sized index.

  figs 5-6   euclid_uniform_100   Kruskal/quality, 100d uniform -> 80/10d
  figs 7-8   euclid_uniform_500   500d uniform -> 400d
  figs 9-10  euclid_manifold      GloVe-like manifold (200d -> 120/16d)
  figs 11-12 recall_manifold      kNN DCG recall (CNN-feature-like)
  figs 13-16 cosine_relu          RELU'd features under cosine
  figs 17-20 jsd_generated/gist   coordinate-free JSD spaces vs LMDS
  fig 21     runtime_*            transform creation + per-object apply cost
  lemma C.2  bounds               Lwb <= d <= Upb validation
  kernels    kernel_*             pallas (interpret) vs jnp reference oracle

Scales are CPU-friendly (same protocol as the paper at reduced n); §Perf in
EXPERIMENTS.md documents the mapping to the paper's full-size runs.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp


def _timeit(fn, *args, repeat: int = 3, number: int = 1) -> float:
    """Best-of wall time per call in microseconds (jit-warmed)."""
    fn(*args)  # warmup / compile
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            r = fn(*args)
        if isinstance(r, jax.Array):
            r.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def bench_euclidean_spaces() -> None:
    from benchmarks.paper_quality import euclidean_comparison

    for name, space, m, ks in [
        ("euclid_uniform_100", "uniform", 100, (80, 10)),
        ("euclid_uniform_500", "uniform", 500, (400, 20)),
        ("euclid_manifold_200", "manifold", 200, (120, 16)),
        ("cosine_relu_256", "relu", 256, (64, 16)),
    ]:
        for k in ks:
            t0 = time.perf_counter()
            res = euclidean_comparison(space, n_witness=1000, n_eval=220,
                                       m=m, k=k)
            dt = (time.perf_counter() - t0) * 1e6
            derived = ";".join(
                f"{tr}_kruskal={res[tr]['kruskal']:.4f}" for tr in
                ("zen", "pca", "rp", "mds"))
            derived += f";zen_rho={res['zen']['spearman']:.4f}"
            _row(f"{name}_k{k}", dt, derived)


def bench_jsd_spaces() -> None:
    from benchmarks.paper_quality import jsd_comparison

    for name, m, k, manifold in [
        ("jsd_generated_100", 100, 20, False),
        ("jsd_gistlike_480", 480, 24, True),
    ]:
        t0 = time.perf_counter()
        res = jsd_comparison(n_eval=200, m=m, k=k, real_manifold=manifold)
        dt = (time.perf_counter() - t0) * 1e6
        _row(name, dt,
             f"zen_kruskal={res['zen']['kruskal']:.4f};"
             f"lmds_kruskal={res['lmds']['kruskal']:.4f};"
             f"zen_rho={res['zen']['spearman']:.4f};"
             f"lmds_rho={res['lmds']['spearman']:.4f}")


def bench_recall() -> None:
    from benchmarks.paper_quality import recall_comparison

    t0 = time.perf_counter()
    res = recall_comparison(n_corpus=20000, n_queries=20, m=256, k=16,
                            n_nn=100)
    dt = (time.perf_counter() - t0) * 1e6
    _row("recall_manifold_256_k16", dt,
         ";".join(f"{k}_dcg={v:.4f}" for k, v in res.items()))


def bench_bounds() -> None:
    from benchmarks.paper_quality import bounds_validation

    t0 = time.perf_counter()
    res = bounds_validation(n=400, m=128, k=12)
    dt = (time.perf_counter() - t0) * 1e6
    _row("bounds_lemma_c2", dt,
         ";".join(f"{k}={v}" for k, v in res.items()))


def bench_runtime_fig21() -> None:
    """Fig 21: creation + per-object application cost of each transform,
    1000-dim Euclidean -> k, PLUS the paper-faithful sequential nSimplex
    (the paper's own implementation gap this framework closes)."""
    from repro.core import (
        NSimplexTransform, PCATransform, RandomProjection,
    )
    from repro.core.simplex import apex_project_reference
    from repro.core import metrics as M
    from repro.data import synthetic as syn

    key = jax.random.PRNGKey(0)
    m, k, n_apply = 1000, 64, 2048
    witness = syn.uniform_space(key, 1024, m)
    X = syn.uniform_space(jax.random.fold_in(key, 1), n_apply, m)

    # creation costs
    t_pca = _timeit(lambda: PCATransform(k=k).fit(witness).components)
    t_rp = _timeit(lambda: RandomProjection(k=k).fit(m, key=key).matrix)
    t_ns = _timeit(lambda: NSimplexTransform(k=k).fit(witness[:k]).base.chol)
    _row("create_pca_1000d", t_pca, f"k={k}")
    _row("create_rp_1000d", t_rp, f"k={k}")
    _row("create_nsimplex_1000d", t_ns, f"k={k}")

    # application costs (per object)
    pca = PCATransform(k=k).fit(witness)
    rp = RandomProjection(k=k).fit(m, key=key)
    ns = NSimplexTransform(k=k).fit(witness[:k])
    apply_pca = jax.jit(pca.transform)
    apply_rp = jax.jit(rp.transform)
    apply_ns = jax.jit(ns.transform)
    t = _timeit(lambda: apply_pca(X)) / n_apply
    _row("apply_pca_per_obj", t, f"batch={n_apply}")
    t = _timeit(lambda: apply_rp(X)) / n_apply
    _row("apply_rp_per_obj", t, f"batch={n_apply}")
    t = _timeit(lambda: apply_ns(X)) / n_apply
    _row("apply_nsimplex_batched_per_obj", t,
         f"batch={n_apply};TPU-native Cholesky+triangular-solve path")

    # paper-faithful sequential ApexAddition (the paper's reported ~100x gap)
    D_refs = np.array(M.euclidean_pdist(ns.refs, ns.refs))
    np.fill_diagonal(D_refs, 0.0)
    dists = np.asarray(M.euclidean_pdist(X[:64], ns.refs))
    t0 = time.perf_counter()
    apex_project_reference(D_refs, dists)
    t_seq = (time.perf_counter() - t0) * 1e6 / 64
    _row("apply_nsimplex_paper_sequential_per_obj", t_seq,
         "verbatim Algorithm 2 loop (paper-faithful baseline)")


def bench_kernels() -> None:
    from repro.kernels import jsd as jsd_k
    from repro.kernels import pdist as pdist_k
    from repro.kernels import ref
    from repro.kernels import zen as zen_k

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    R = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    t = _timeit(lambda: ref.pdist_sq_ref(X, R))
    _row("kernel_pdist_ref_512x128x256", t, "jnp oracle (XLA:CPU)")
    t = _timeit(lambda: pdist_k.pdist_sq(X, R, interpret=True))
    _row("kernel_pdist_interp_512x128x256", t,
         "pallas interpret mode (correctness path; TPU is the perf target)")

    Xp = jnp.asarray(rng.normal(size=(512, 32)), jnp.float32)
    Yp = jnp.asarray(rng.normal(size=(512, 32)), jnp.float32)
    t = _timeit(lambda: ref.zen_estimate_ref(Xp, Yp))
    _row("kernel_zen_ref_512x512x32", t, "jnp oracle")
    t = _timeit(lambda: zen_k.zen_estimate(Xp, Yp, interpret=True))
    _row("kernel_zen_interp_512x512x32", t, "pallas interpret mode")

    P = jnp.asarray(rng.uniform(size=(128, 128)), jnp.float32)
    P = P / P.sum(1, keepdims=True)
    t = _timeit(lambda: ref.jsd_pdist_ref(P, P))
    _row("kernel_jsd_ref_128x128x128", t, "jnp oracle")
    t = _timeit(lambda: jsd_k.jsd_pdist(P, P, interpret=True))
    _row("kernel_jsd_interp_128x128x128", t, "pallas interpret mode")


def bench_ablations() -> None:
    """Paper §4.1 / §7.2 ablations: estimator choice, dim profile, ref choice."""
    import time as _t

    from benchmarks.ablations import (
        dimension_profile, estimator_ablation, reference_selection,
    )

    t0 = _t.perf_counter()
    res = estimator_ablation()
    _row("ablate_estimator_zen_vs_bounds", (_t.perf_counter() - t0) * 1e6,
         ";".join(f"{k}={v:.4f}" for k, v in res.items()))

    t0 = _t.perf_counter()
    res = dimension_profile()
    _row("ablate_dim_profile_100d", (_t.perf_counter() - t0) * 1e6,
         ";".join(f"{k}={v:.4f}" for k, v in res.items()))

    t0 = _t.perf_counter()
    res = reference_selection()
    _row("ablate_reference_choice", (_t.perf_counter() - t0) * 1e6,
         ";".join(f"{k}={v:.4f}" for k, v in res.items()))


def bench_retrieval_topk(smoke: bool = False) -> None:
    """Serving hot path at scale: dense (Q, N) materialisation vs the
    streaming fused top-k vs the sharded per-device search, on synthetic
    projected coordinates. Reports per-batch wall time, QPS and the XLA temp
    allocation (the peak transient working set) of each jitted search fn —
    the streaming path must stay flat in N while dense grows linearly."""
    import numpy as np_

    from jax.sharding import Mesh

    from repro.core import zen as Z
    from repro.distributed.retrieval import sharded_knn_search
    from repro.kernels import zen_topk as zt

    q, kdim, nn, chunk = 32, 16, 10, 4096
    sizes = [20_000] if smoke else [100_000, 1_000_000]
    mesh = Mesh(np_.asarray(jax.devices()), ("shard",))

    def temp_bytes(fn, n):
        Qs = jax.ShapeDtypeStruct((q, kdim), jnp.float32)
        Xs = jax.ShapeDtypeStruct((n, kdim), jnp.float32)
        try:
            mem = jax.jit(fn).lower(Qs, Xs).compile().memory_analysis()
            return int(mem.temp_size_in_bytes)
        except Exception:
            return -1  # backend without memory_analysis support

    key = jax.random.PRNGKey(0)
    for n in sizes:
        X = jax.random.normal(key, (n, kdim), jnp.float32)
        X = X.at[:, -1].set(jnp.abs(X[:, -1]))
        Qb = X[:q] + 0.1 * jax.random.normal(
            jax.random.fold_in(key, 1), (q, kdim), jnp.float32
        )
        paths = {
            "dense": lambda Q_, X_: Z._dense_topk(Q_, X_, nn, "zen"),
            "stream": lambda Q_, X_: zt.zen_topk_scan(
                Q_, X_, nn, "zen", chunk=chunk
            ),
            "sharded": lambda Q_, X_: sharded_knn_search(
                Q_, X_, nn, "zen", mesh=mesh, chunk=chunk
            ),
        }
        for name, fn in paths.items():
            t = _timeit(lambda: fn(Qb, X)[0], repeat=2)
            tb = temp_bytes(fn, n)
            mb = f"{tb / 2**20:.2f}" if tb >= 0 else "n/a"
            _row(
                f"retrieval_topk_{name}_n{n}", t,
                f"qps={q / (t * 1e-6):.0f};peak_temp_mb={mb};"
                f"neighbors={nn};chunk={chunk}",
            )


def bench_retrieval_ivf(smoke: bool = False) -> None:
    """Clustered IVF vs flat streaming retrieval on the serving hot path:
    project a manifold corpus to (N, k) apex coordinates (the paper
    pipeline), build a k-means coarse quantizer over them, then sweep
    ``nprobe`` reporting QPS and recall@10 against the flat streaming scan
    over the same coordinates. Also reports the XLA peak temp allocation of
    the probe at two index sizes with the tile geometry fixed — like the
    flat streaming path, the probe's working set is one tile per query, flat
    in N."""
    from repro.core.projection import select_references
    from repro.core.quality import recall_at_k
    from repro.data import synthetic as syn
    from repro.index import IVFZenIndex
    from repro.kernels import ivf_probe as ip
    from repro.kernels import zen_topk as zt

    q, dim, kdim, nn, chunk = 32, 128, 16, 10, 4096
    n = 20_000 if smoke else 200_000
    n_clusters = max(64, int(round(4 * n**0.5)))
    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, n, dim, 8)
    tr = select_references(corpus, kdim, jax.random.fold_in(key, 1))
    X = tr.transform(corpus).astype(jnp.float32)
    Qb = tr.transform(
        syn.manifold_space(jax.random.fold_in(key, 3), q, dim, 8)
    ).astype(jnp.float32)

    t0 = time.perf_counter()
    index = IVFZenIndex.build(
        X, n_clusters, key=jax.random.fold_in(key, 2),
        n_iters=8 if smoke else 10,
    )
    _row(f"retrieval_ivf_build_n{n}", (time.perf_counter() - t0) * 1e6,
         f"clusters={index.n_clusters};tiles_per_cluster="
         f"{index.tiles_per_cluster};tile_rows={index.tile_rows}")

    flat = lambda: zt.zen_topk_scan(Qb, X, nn, "zen", chunk=chunk)
    flat_ids = np.asarray(flat()[1])  # also compiles ahead of the timing loop
    t_flat = _timeit(lambda: flat()[0], repeat=2)
    _row(f"retrieval_ivf_flat_n{n}", t_flat,
         f"qps={q / (t_flat * 1e-6):.0f};recall10=1.000;speedup=1.0x")

    for nprobe in (1, 2, 4, 8, 16, 32, 64):
        if nprobe > index.n_clusters:
            break
        fn = lambda: index.search(Qb, nn, nprobe=nprobe)
        rec = recall_at_k(flat_ids, np.asarray(fn()[1]))  # compiles too
        t = _timeit(lambda: fn()[0], repeat=2)
        _row(
            f"retrieval_ivf_nprobe{nprobe}_n{n}", t,
            f"qps={q / (t * 1e-6):.0f};recall10={rec:.3f};"
            f"speedup={t_flat / t:.1f}x;clusters={index.n_clusters}",
        )

    # memory flatness of the probe: fixed tile geometry, 8x the index rows
    nprobe_m, tile_rows, T = 8, 128, 2
    for label, n_rows in (("small", 16 * 1024), ("big", 128 * 1024)):
        n_c = n_rows // (T * tile_rows)
        shapes = (
            jax.ShapeDtypeStruct((q, kdim), jnp.float32),
            jax.ShapeDtypeStruct((n_c * T, tile_rows, kdim), jnp.float32),
            jax.ShapeDtypeStruct((n_c * T, tile_rows), jnp.int32),
            jax.ShapeDtypeStruct((q, nprobe_m), jnp.int32),
        )
        probe = lambda Q_, TC, TI, PR: ip.ivf_probe_scan(
            Q_, TC, TI, PR, nn, "zen", tiles_per_cluster=T
        )
        try:
            mem = jax.jit(probe).lower(*shapes).compile().memory_analysis()
            mb = f"{mem.temp_size_in_bytes / 2**20:.2f}"
        except Exception:
            mb = "n/a"
        _row(f"retrieval_ivf_probe_mem_{label}", 0.0,
             f"rows={n_rows};peak_temp_mb={mb}")


def bench_serving() -> None:
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenServer, build_index

    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, 20000, 256, 32)
    index = build_index(corpus, 16)
    server = ZenServer(index, rerank_factor=4)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 64, 256, 32)
    t = _timeit(lambda: server.query(q, 10)[0])
    _row("serve_zen_batch64_20k_index", t / 64,
         "per-query; zen topk + exact rerank")


_WORKLOADS = {
    "bounds": lambda a: bench_bounds(),
    "euclidean": lambda a: bench_euclidean_spaces(),
    "jsd": lambda a: bench_jsd_spaces(),
    "recall": lambda a: bench_recall(),
    "runtime": lambda a: bench_runtime_fig21(),
    "ablations": lambda a: bench_ablations(),
    "kernels": lambda a: bench_kernels(),
    "serving": lambda a: bench_serving(),
    "retrieval_topk": lambda a: bench_retrieval_topk(smoke=a.smoke),
    "retrieval_ivf": lambda a: bench_retrieval_ivf(smoke=a.smoke),
}


def main() -> None:
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--workload", default="all",
                   choices=["all"] + sorted(_WORKLOADS))
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized shapes (retrieval_topk / retrieval_ivf)")
    args = p.parse_args()

    print("name,us_per_call,derived")
    if args.workload == "all":
        for fn in _WORKLOADS.values():
            fn(args)
    else:
        _WORKLOADS[args.workload](args)


if __name__ == "__main__":
    main()
