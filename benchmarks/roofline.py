"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e, per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI link bandwidth: ~50 GB/s/link (we budget ONE link per collective step —
                      conservative; a 3D-torus would overlap up to 3)

Terms (per device, per step; cost_analysis is already per-device for the
SPMD-partitioned module):
  compute_s    = flops / PEAK_FLOPS
  memory_s     = bytes_accessed / HBM_BW
  collective_s = collective_bytes / ICI_BW

The dominant term is the bottleneck; roofline fraction for the step =
compute_s / max(all terms) (how close the step is to being compute-bound at
peak). MODEL_FLOPS/HLO_FLOPS flags remat/redundancy waste.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

_FINAL = os.path.join(os.path.dirname(__file__), "artifacts_final")
_BASE = os.path.join(os.path.dirname(__file__), "artifacts")
# prefer the optimized-defaults sweep; fall back to the baseline sweep
ARTIFACT_DIR = _FINAL if os.path.isdir(_FINAL) and os.listdir(_FINAL) else _BASE


def load_artifacts(artifact_dir: str = ARTIFACT_DIR) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def roofline_row(rec: dict) -> dict:
    """Derive the three terms for one artifact."""
    if rec.get("status") != "ok":
        return {**{k: rec.get(k) for k in ("arch", "shape", "mesh")},
                "status": rec.get("status"), "skip": rec.get("skip_reason", "")}
    n_dev = rec["n_devices"]
    flops_dev = rec["cost"].get("flops", 0.0)
    bytes_dev = rec["cost"].get("bytes accessed", 0.0)
    coll_dev = rec["collectives"]["total_bytes"]
    corr = rec.get("corrected")
    if corr:  # scan-body trip-count correction (see launch/dryrun.py)
        flops_dev = corr["flops"]
        bytes_dev = corr["bytes_accessed"]
        coll_dev = corr["collective_bytes"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    dom = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )
    model_flops = rec.get("model_flops", {}).get("model_flops_global", 0.0)
    mf_per_dev = model_flops / n_dev if n_dev else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "status": "ok",
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dom[0],
        "step_s_bound": dom[1],
        "roofline_fraction": compute_s / dom[1] if dom[1] > 0 else 0.0,
        "useful_flops_ratio": (mf_per_dev / flops_dev) if flops_dev else 0.0,
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
        "coll_ops": rec["collectives"]["total_count"],
        "hlo_flops_dev": flops_dev,
        "model_flops_global": model_flops,
    }


def fmt_table(rows: list[dict], mesh: str = "pod") -> str:
    hdr = (f"| arch | shape | compute_s | memory_s | collective_s | dominant "
           f"| roofline | useful_flops | peak GiB |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| {r['dominant']} | {r['roofline_fraction']:.2f} "
            f"| {r['useful_flops_ratio']:.2f} | {r['peak_gib']:.2f} |")
    return "\n".join(lines)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--artifact-dir", default=ARTIFACT_DIR)
    p.add_argument("--mesh", default="pod")
    p.add_argument("--json", action="store_true")
    args = p.parse_args()
    rows = [roofline_row(r) for r in load_artifacts(args.artifact_dir)]
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(fmt_table(rows, args.mesh))


if __name__ == "__main__":
    main()
