"""End-to-end "learned embeddings in, Zen retrieval out" evaluation.

The pipeline the paper motivates but never wires together, as one workload
(``benchmarks/run.py --workload retrieval_e2e``):

1. **Train** a two-tower recsys model (``repro.models.recsys``) on synthetic
   Criteo-shaped click batches (in-batch sampled softmax, L2-normalised
   towers).
2. **Fit + serve**: fit the nSimplex on the item tower, build an IVF index,
   and serve it through the ``ZenServer`` micro-batched frontend.
3. **Churn live**: keep training, upsert the freshly trained item embeddings
   into the *serving* index mid-flight — exercising the generation counter,
   the frontend result cache's generation-keyed invalidation, and the
   scheduled-vs-direct bit-parity contract under churn.
4. **Quality curves** (paper §5 protocol, on the *learned* corpus): recall@10
   and Spearman/Kruskal vs reduced dimension k for Zen vs PCA vs RP vs LMDS
   through the uniform ``repro.core.reducers`` protocol.
5. **Hilbert/JSD leg** (paper §5.6): train the reduced LM
   (``examples/train_lm.py``), take softmax next-token rows — points on the
   probability simplex — and serve them through a ``metric="jsd"`` index
   with exact JSD re-rank; LMDS is the only baseline that can follow
   (distance-only), PCA/RP structurally cannot fit a coordinate-free space.

Scales are CPU-friendly; ``--smoke`` shrinks every phase to CI size.
"""
from __future__ import annotations

import importlib.util
import os
import time
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import make_reducer, quality
from repro.core import metrics as M
from repro.data import synthetic as syn
from repro.launch.serve import ZenServer, build_index
from repro.models import recsys
from repro.optim import AdamW

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: quality-curve reduced dimensions (paper figs use a k sweep; the two
#: lowest values carry the acceptance ordering Zen >= PCA and >= RP)
CURVE_KS = (4, 8, 16, 32)
CURVE_KS_SMOKE = (4, 8)


def _load_train_lm():
    """Import examples/train_lm.py by path (examples/ is not a package)."""
    spec = importlib.util.spec_from_file_location(
        "example_train_lm", os.path.join(_ROOT, "examples", "train_lm.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def train_two_tower(smoke: bool = False, *, steps=None, n_items=None,
                    batch: int = 256, embed_dim: int = 64, lr: float = 3e-3):
    """Train the two-tower model; returns (cfg, params, opt, opt_state,
    step_fn, losses). ``step_fn`` is reusable for the churn phase."""
    cfg = recsys.RecsysConfig(
        name="two_tower_e2e", model="dlrm", n_sparse=8, embed_dim=embed_dim,
        vocab_sizes=(96,) * 8)
    n_items = n_items or (2048 if smoke else 8192)
    steps = steps or (40 if smoke else 240)
    params = recsys.init_two_tower_params(cfg, jax.random.PRNGKey(0), n_items)
    opt = AdamW(learning_rate=lr)
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(params, opt_state, batch_):
        (loss, _), grads = jax.value_and_grad(
            lambda p: recsys.two_tower_loss(cfg, p, batch_),
            has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return (jax.tree.map(lambda a, b: a + b, params, updates),
                opt_state, loss)

    losses = []
    for s in range(steps):
        b = syn.two_tower_batch(0, s, batch, cfg.vocab_sizes, n_items)
        params, opt_state, loss = step_fn(params, opt_state, b)
        losses.append(float(loss))
    return cfg, params, opt, opt_state, step_fn, losses


def _recall10(truth_ids: np.ndarray, pred_ids: np.ndarray) -> float:
    return float(quality.recall_at_k(truth_ids[:, :10], pred_ids[:, :10]))


def quality_curves(corpus, queries, *, ks, emit: Callable, n_pairs_eval=256,
                   reducer_names=("zen", "pca", "rp", "lmds")):
    """Paper-style curves on a learned corpus: one row per (k, reducer).

    ``queries`` must come from the same space as ``corpus`` (the e2e
    workload holds out corpus rows — the related-items task), so every
    method is measured in-distribution the way the paper's §5 recall
    experiments are."""
    corpus = jnp.asarray(corpus, jnp.float32)
    queries = jnp.asarray(queries, jnp.float32)
    d_true = np.asarray(M.euclidean_pdist(queries, corpus))
    truth = np.argsort(d_true, axis=1)[:, :10]
    ev = corpus[: min(n_pairs_eval, corpus.shape[0])]
    d_ev = np.asarray(M.euclidean_pdist(ev, ev))
    iu = np.triu_indices(d_ev.shape[0], 1)
    delta = d_ev[iu]

    results = {}
    for k in ks:
        for name in reducer_names:
            t0 = time.perf_counter()
            r = make_reducer(name, k).fit(
                corpus, key=jax.random.fold_in(jax.random.PRNGKey(5), k))
            cr, qr = r.transform(corpus), r.transform(queries)
            pred = np.argsort(np.asarray(r.pdist(qr, cr)), axis=1)[:, :10]
            rec = _recall10(truth, pred)
            evr = r.transform(ev)
            zeta = np.asarray(r.pdist(evr, evr))[iu]
            rho = float(quality.spearman_rho(delta, zeta))
            stress = float(quality.kruskal_stress(delta, zeta))
            dt = (time.perf_counter() - t0) * 1e6
            results[(k, name)] = rec
            emit(f"e2e_curve_{name}_k{k}", dt,
                 f"recall10={rec:.4f};spearman={rho:.4f};"
                 f"kruskal={stress:.4f};dim={corpus.shape[1]}")
    # the paper's qualitative ordering at the lowest two k values
    for k in sorted(ks)[:2]:
        z, p, rp_ = (results[(k, n)] for n in ("zen", "pca", "rp"))
        emit(f"e2e_ordering_k{k}", 0.0,
             f"zen={z:.4f};pca={p:.4f};rp={rp_:.4f};"
             f"zen_ge_pca={'yes' if z >= p else 'NO'};"
             f"zen_ge_rp={'yes' if z >= rp_ else 'NO'}")
    return results


def serve_with_churn(cfg, params, opt, opt_state, step_fn, *, smoke,
                     emit: Callable, k_serve: int = 24, nn: int = 10):
    """Build -> serve through the frontend -> churn mid-serving -> verify."""
    n_items = params["items"].shape[0]
    batch = 256
    rounds = 2 if smoke else 4
    extra_steps = 10 if smoke else 30
    start_step = 100_000  # disjoint from the training stream

    qbatch = syn.two_tower_batch(0, 10_007, 64, cfg.vocab_sizes, n_items)
    users, items = recsys.two_tower_towers(cfg, params, qbatch)
    users = np.asarray(users, np.float32)

    t0 = time.perf_counter()
    index = build_index(jnp.asarray(items), k_serve, index="ivf",
                        key=jax.random.PRNGKey(11))
    nprobe = max(8, index.ivf.n_clusters // 3)
    server = ZenServer(index, nprobe=nprobe, rerank_factor=8, frontend=True,
                       max_batch=64, cache_size=512, queue_limit=1024)
    t_build = (time.perf_counter() - t0) * 1e6
    emit(f"e2e_serve_build_n{n_items}", t_build,
         f"k={k_serve};clusters={index.ivf.n_clusters};"
         f"generation={index.generation}")

    # scheduled vs direct bit parity before any churn
    d_s, i_s = server.query(users, nn)
    d_d, i_d = server.query(users, nn, direct=True)
    parity = bool(np.array_equal(np.asarray(d_s), np.asarray(d_d))
                  and np.array_equal(np.asarray(i_s), np.asarray(i_d)))

    # churn: keep training, push the refreshed item tower into the live index
    chunk = n_items // rounds
    gen0 = server.index.generation
    hits_pre = hits_post = 0
    t_upsert = 0.0
    step_cursor = start_step
    for r in range(rounds):
        for s in range(extra_steps):
            b = syn.two_tower_batch(0, step_cursor, batch, cfg.vocab_sizes,
                                    n_items)
            params, opt_state, _ = step_fn(params, opt_state, b)
            step_cursor += 1
        _, items = recsys.two_tower_towers(cfg, params, qbatch)
        ids = np.arange(r * chunk, (r + 1) * chunk)
        # warm the cache at this generation, then churn: the generation
        # bump must invalidate those entries (the cache key includes it)
        server.query(users[:8], nn)
        hits_pre += server.frontend.cache.info().get("hits", 0)
        t0 = time.perf_counter()
        server.upsert(ids, np.asarray(items)[ids])
        t_upsert += time.perf_counter() - t0
        d_s, i_s = server.query(users, nn)
        d_d, i_d = server.query(users, nn, direct=True)
        parity &= bool(np.array_equal(np.asarray(d_s), np.asarray(d_d))
                       and np.array_equal(np.asarray(i_s), np.asarray(i_d)))
        hits_post += server.frontend.cache.info().get("hits", 0)
    gen1 = server.index.generation
    emit(f"e2e_serve_churn_n{n_items}", t_upsert * 1e6 / rounds,
         f"rounds={rounds};upserts_per_s={n_items / max(t_upsert, 1e-9):.0f};"
         f"generation={gen0}->{gen1};"
         f"parity={'bit' if parity else 'DIVERGED'}")

    # final serving quality + QPS vs exact search over the served corpus
    corpus_live = np.asarray(server.index.corpus, np.float32)
    d_true = np.asarray(M.euclidean_pdist(jnp.asarray(users),
                                          jnp.asarray(corpus_live)))
    truth = np.argsort(d_true, axis=1)[:, :nn]
    d_s, i_s = server.query(users, nn)
    rec = _recall10(truth, np.asarray(i_s))
    t = _time_queries(server, users, nn)
    emit(f"e2e_serve_final_n{n_items}", t * 1e6 / len(users),
         f"qps={len(users) / t:.0f};recall10={rec:.4f};nprobe={nprobe};"
         f"rerank=8x;cache_hits={hits_post};"
         f"generation={gen1}")
    return params, server, users


def _time_queries(server, users, nn, repeat: int = 3) -> float:
    server.query(users, nn)  # warm
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        server.query(users, nn)
        best = min(best, time.perf_counter() - t0)
    return best


def jsd_lm_leg(smoke: bool, emit: Callable, *, k: int = 16, nn: int = 10,
               temperature: float = 6.0):
    """LM next-token rows -> probability simplex -> metric="jsd" serving.

    The LM trains on *Markov* token streams (``syn.lm_markov_batch``): on
    i.i.d.-uniform tokens the learned next-token distribution is context-
    independent and the JSD space degenerates to near-duplicates.
    ``temperature`` smooths the rows away from the one-hot corners where
    pairwise JSD saturates at its maximum (see
    ``next_token_distributions``)."""
    mod = _load_train_lm()
    lm_steps = 12 if smoke else 40
    n_corpus = 256 if smoke else 1024
    n_queries = 32 if smoke else 64
    seq = 32

    t0 = time.perf_counter()
    cfg, params, losses = mod.train_lm(lm_steps, batch=8, seq=64,
                                       data="markov")
    t_train = (time.perf_counter() - t0) * 1e6
    emit("e2e_jsd_lm_train", t_train,
         f"steps={lm_steps};loss={losses[0]:.3f}->{losses[-1]:.3f};"
         f"data=markov")

    toks = syn.lm_markov_batch(1, 0, n_corpus + n_queries, seq,
                               cfg.vocab_size)
    rows = []
    tokens = toks["tokens"]
    for lo in range(0, tokens.shape[0], 128):
        rows.append(np.asarray(mod.next_token_distributions(
            cfg, params, tokens[lo:lo + 128], temperature=temperature)))
    P = np.concatenate(rows)  # (N, vocab) probability rows
    corpus_p, queries_p = P[:n_corpus], P[n_corpus:]

    # simplex-domain invariants through the pipeline
    row_sum_err = float(np.abs(P.sum(axis=1) - 1.0).max())
    self_d = float(np.abs(np.asarray(M.jsd_pdist(
        jnp.asarray(corpus_p[:16]), jnp.asarray(corpus_p[:16]),
        assume_normalized=True))).diagonal().max())
    emit("e2e_jsd_domain", 0.0,
         f"rows={P.shape[0]};vocab={P.shape[1]};"
         f"max_row_sum_err={row_sum_err:.2e};max_self_dist={self_d:.2e}")

    d_true = np.asarray(M.jsd_pdist(jnp.asarray(queries_p),
                                    jnp.asarray(corpus_p),
                                    assume_normalized=True))
    truth = np.argsort(d_true, axis=1)[:, :nn]

    # learned JSD rows are far more concentrated than synthetic simplex
    # draws (mean pairwise ~0.77, spread ~0.09), so the approximate stage
    # needs a deeper exact-rerank pool than the Euclidean legs: 16x the
    # requested nn clears the >=0.9 recall bar with margin at n=1024
    rerank = 16
    for index_kind in ("ivf", "flat"):
        index = build_index(jnp.asarray(corpus_p), k, metric="jsd",
                            index=index_kind, key=jax.random.PRNGKey(3))
        nprobe = (max(8, index.ivf.n_clusters // 2)
                  if index_kind == "ivf" else 0)
        server = ZenServer(index, rerank_factor=rerank,
                           **({"nprobe": nprobe} if nprobe else {}))
        ids = np.asarray(server.query(jnp.asarray(queries_p), nn)[1])
        rec = _recall10(truth, ids)
        t = _time_queries(server, jnp.asarray(queries_p), nn)
        emit(f"e2e_jsd_serve_{index_kind}_n{n_corpus}",
             t * 1e6 / n_queries,
             f"qps={n_queries / t:.0f};recall10_vs_exact_jsd={rec:.4f};"
             + (f"nprobe={nprobe};" if index_kind == "ivf" else "")
             + f"rerank={rerank}x;k={k}")

    # the distance-only baseline can follow Zen into the Hilbert space;
    # the coordinate baselines cannot (structural, not a tuning gap)
    r = make_reducer("lmds", k, metric="jsd").fit(
        jnp.asarray(corpus_p), key=jax.random.PRNGKey(4))
    pred = np.argsort(np.asarray(
        r.pdist(r.transform(jnp.asarray(queries_p)),
                r.transform(jnp.asarray(corpus_p)))), axis=1)[:, :nn]
    rec_lmds = _recall10(truth, pred)
    try:
        make_reducer("pca", k, metric="jsd").fit(jnp.asarray(corpus_p))
        pca_refuses = "NO"
    except ValueError:
        pca_refuses = "yes"
    emit(f"e2e_jsd_lmds_n{n_corpus}", 0.0,
         f"recall10={rec_lmds:.4f};pca_structurally_excluded={pca_refuses}")


def run_e2e(smoke: bool = False, emit: Callable = None) -> None:
    """The full workload; ``emit(name, us, derived)`` collects rows."""
    if emit is None:
        emit = lambda name, us, derived: print(f"{name},{us:.1f},{derived}")

    # phase 1: train the two-tower model
    t0 = time.perf_counter()
    cfg, params, opt, opt_state, step_fn, losses = train_two_tower(smoke)
    dt = (time.perf_counter() - t0) * 1e6
    n_items = params["items"].shape[0]
    emit(f"e2e_train_two_tower_n{n_items}", dt / len(losses),
         f"steps={len(losses)};loss={losses[0]:.3f}->{losses[-1]:.3f};"
         f"decreased={'yes' if losses[-1] < losses[0] else 'NO'};"
         f"dim={cfg.embed_dim}")

    # phases 2-3: serve with live churn through the frontend
    params, server, users = serve_with_churn(
        cfg, params, opt, opt_state, step_fn, smoke=smoke, emit=emit)

    # phase 4: quality curves on the final learned item tower (held-out
    # item rows as queries — the related-items task, in-distribution)
    corpus_live = np.asarray(server.index.corpus, np.float32)
    rng = np.random.default_rng(17)
    qi = rng.choice(corpus_live.shape[0],
                    min(256, corpus_live.shape[0] // 4), replace=False)
    mask = np.ones(corpus_live.shape[0], bool)
    mask[qi] = False
    quality_curves(corpus_live[mask], corpus_live[qi],
                   ks=CURVE_KS_SMOKE if smoke else CURVE_KS, emit=emit)

    # phase 5: the Hilbert/JSD leg over LM next-token distributions
    jsd_lm_leg(smoke, emit)


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true")
    args = p.parse_args()
    print("name,us_per_call,derived")
    run_e2e(smoke=args.smoke)
