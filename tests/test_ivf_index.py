"""Clustered (IVF) Zen index: packed-layout invariants, exactness at
nprobe = n_clusters against the flat search, recall monotonicity in nprobe,
Pallas-kernel vs scan-fallback parity (padded-tile and single-cluster edge
shapes), sharded probes, serving integration, n_neighbors clamping, and the
flat-in-N memory bound of the probe. All CPU (interpret=True for Pallas)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import zen as Z
from repro.core.quality import recall_at_k
from repro.index import IVFZenIndex
from repro.kernels import ivf_probe as ip
from repro.kernels import ops


def _coords(seed, n, k):
    """Synthetic projected coords (non-negative altitude column)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, k)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    return jnp.asarray(X)


def _queries(seed, X, q, noise=0.05):
    rng = np.random.default_rng(seed)
    Q = np.asarray(X[:q]) + noise * rng.normal(size=(q, X.shape[1]))
    return jnp.asarray(Q.astype(np.float32))


# -- packed layout invariants --------------------------------------------------


def test_build_packs_every_row_exactly_once():
    X = _coords(0, 777, 9)  # ragged vs tile_rows=128
    idx = IVFZenIndex.build(X, 12, key=jax.random.PRNGKey(0))
    ids = np.asarray(idx.tile_ids).ravel()
    valid = ids[ids >= 0]
    assert sorted(valid.tolist()) == list(range(777))  # each row once
    assert idx.tile_coords.shape == (
        12 * idx.tiles_per_cluster, idx.tile_rows, 9
    )
    # packed coordinates match the source rows; padding slots are zero
    packed = np.asarray(idx.tile_coords).reshape(-1, 9)
    flat_ids = np.asarray(idx.tile_ids).ravel()
    np.testing.assert_array_equal(
        packed[flat_ids >= 0], np.asarray(X)[flat_ids[flat_ids >= 0]]
    )
    assert (packed[flat_ids < 0] == 0).all()


def test_build_members_assigned_to_their_cluster():
    X = _coords(1, 400, 7)
    idx = IVFZenIndex.build(X, 8, key=jax.random.PRNGKey(1))
    cents = np.asarray(idx.centroids)
    T, tr = idx.tiles_per_cluster, idx.tile_rows
    ids = np.asarray(idx.tile_ids).reshape(8, T * tr)
    for c in range(8):
        members = ids[c][ids[c] >= 0]
        if members.size == 0:
            continue
        d2 = ((np.asarray(X)[members][:, None, :] - cents[None]) ** 2).sum(-1)
        assert (d2.argmin(1) == c).all()


# -- exactness at nprobe = n_clusters ------------------------------------------

EXACT_SHAPES = [
    # (n, k, n_clusters, n_neighbors): padded tails, single cluster, big k,
    # n_neighbors exceeding the smallest cluster
    (700, 12, 10, 10),
    (513, 8, 1, 5),      # single cluster: pure padded-tile scan
    (300, 17, 50, 25),   # n_neighbors > typical cluster size
    (64, 6, 64, 3),      # one point per cluster
    (129, 9, 4, 1),
]


@pytest.mark.parametrize("n,k,c,nn", EXACT_SHAPES)
@pytest.mark.parametrize("mode", ["zen", "lwb", "upb"])
def test_full_probe_matches_flat_search(n, k, c, nn, mode):
    X = _coords(n + k, n, k)
    Q = _queries(n, X, 7)
    idx = IVFZenIndex.build(X, c, key=jax.random.PRNGKey(2))
    want_d, want_i = Z.knn_search(Q, X, nn, mode)
    got_d, got_i = idx.search(Q, nn, nprobe=idx.n_clusters, mode=mode)
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-4
    )
    assert (np.asarray(got_i) == np.asarray(want_i)).all()


def test_full_probe_matches_flat_on_projected_coords():
    from repro.core.projection import NSimplexTransform

    rng = np.random.default_rng(11)
    refs = rng.normal(size=(10, 48))
    tr = NSimplexTransform(k=10).fit(jnp.asarray(refs, jnp.float32))
    X = jnp.asarray(
        tr.transform(jnp.asarray(rng.normal(size=(500, 48)), jnp.float32)),
        jnp.float32,
    )
    Q = X[:9]
    idx = IVFZenIndex.build(X, 16, key=jax.random.PRNGKey(3))
    want_d, want_i = Z.knn_search(Q, X, 8, "zen")
    got_d, got_i = idx.search(Q, 8, nprobe=16)
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-4
    )
    assert (np.asarray(got_i) == np.asarray(want_i)).all()


# -- recall monotonicity in nprobe ---------------------------------------------


def test_recall_monotone_in_nprobe():
    X = _coords(21, 3000, 10)
    Q = _queries(22, X, 16)
    idx = IVFZenIndex.build(X, 32, key=jax.random.PRNGKey(4))
    flat_ids = np.asarray(Z.knn_search(Q, X, 10, "zen")[1])
    last = -1.0
    for nprobe in (1, 2, 4, 8, 16, 32):
        _, ids = idx.search(Q, 10, nprobe=nprobe)
        rec = recall_at_k(flat_ids, np.asarray(ids))
        assert rec >= last - 1e-9, (nprobe, rec, last)
        last = rec
    assert last == 1.0  # nprobe = n_clusters is exact


# -- kernel vs fallback parity -------------------------------------------------

PARITY_CASES = [
    # (n, k, n_clusters, nprobe): padded tiles, single cluster (nprobe=1=C),
    # multi-tile clusters (T > 1), ragged k
    (600, 12, 8, 3),
    (513, 8, 1, 1),       # single cluster edge
    (900, 5, 4, 2),       # clusters > tile_rows: T >= 2
    (150, 18, 30, 30),    # tiny clusters, all probed
]


@pytest.mark.parametrize("n,k,c,nprobe", PARITY_CASES)
@pytest.mark.parametrize("mode", ["zen", "lwb", "upb"])
def test_probe_kernel_matches_scan(n, k, c, nprobe, mode):
    X = _coords(n * 3 + k, n, k)
    Q = _queries(n * 3, X, 6)
    idx = IVFZenIndex.build(X, c, key=jax.random.PRNGKey(5))
    probes = idx.probe_clusters(Q, nprobe, mode)
    scan_d, scan_i = ip.ivf_probe_scan(
        Q, idx.tile_coords, idx.tile_ids, probes, 9, mode,
        tiles_per_cluster=idx.tiles_per_cluster,
    )
    kern_d, kern_i = ip.ivf_probe(
        Q, idx.tile_coords, idx.tile_ids, probes, 9, mode,
        tiles_per_cluster=idx.tiles_per_cluster, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(kern_d), np.asarray(scan_d), rtol=1e-5, atol=1e-5
    )
    assert (np.asarray(kern_i) == np.asarray(scan_i)).all()


def test_probe_multi_tile_cluster_layout():
    # force T > 1 and verify against brute force over the probed clusters
    X = _coords(40, 1000, 6)
    idx = IVFZenIndex.build(X, 3, key=jax.random.PRNGKey(6))
    assert idx.tiles_per_cluster >= 2  # ~333 rows per cluster vs 128-row tiles
    Q = _queries(41, X, 5)
    probes = idx.probe_clusters(Q, 2)
    got_d, got_i = ops.ivf_probe(
        Q, idx.tile_coords, idx.tile_ids, probes, 12, "zen",
        tiles_per_cluster=idx.tiles_per_cluster,
    )
    # oracle: dense distances restricted to each query's probed clusters
    T, tr = idx.tiles_per_cluster, idx.tile_rows
    ids_by_cluster = np.asarray(idx.tile_ids).reshape(idx.n_clusters, T * tr)
    dense = np.asarray(Z.estimate_pdist(Q, X, "zen"))
    for qi in range(5):
        member = np.concatenate(
            [ids_by_cluster[c][ids_by_cluster[c] >= 0]
             for c in np.asarray(probes)[qi]]
        )
        want = member[np.argsort(dense[qi][member], kind="stable")][:12]
        got = np.asarray(got_i)[qi]
        assert set(got.tolist()) == set(want.tolist())


def test_probe_returns_padding_when_pool_too_small():
    # nprobe=1 on a tiny cluster: unfillable slots must be (+inf, -1)
    X = _coords(60, 64, 6)
    idx = IVFZenIndex.build(X, 64, key=jax.random.PRNGKey(7))  # 1 row/cluster
    Q = _queries(61, X, 4)
    d, ids = idx.search(Q, 10, nprobe=1)
    d, ids = np.asarray(d), np.asarray(ids)
    assert (ids[:, 0] >= 0).all()  # the probed cluster's row is returned
    assert (ids[:, 1:] == -1).all() and np.isinf(d[:, 1:]).all()
    # and valid ids are never padding rows
    assert ids.max() < 64


# -- ops dispatch --------------------------------------------------------------


def test_ops_dispatch_scan_vs_interpret_kernel():
    X = _coords(70, 500, 11)
    idx = IVFZenIndex.build(X, 10, key=jax.random.PRNGKey(8))
    Q = _queries(71, X, 6)
    probes = idx.probe_clusters(Q, 4)
    a = ops.ivf_probe(Q, idx.tile_coords, idx.tile_ids, probes, 8,
                      tiles_per_cluster=idx.tiles_per_cluster)
    b = ops.ivf_probe(Q, idx.tile_coords, idx.tile_ids, probes, 8,
                      tiles_per_cluster=idx.tiles_per_cluster,
                      force_kernel=True)
    np.testing.assert_allclose(
        np.asarray(a[0]), np.asarray(b[0]), rtol=1e-5, atol=1e-5
    )
    assert (np.asarray(a[1]) == np.asarray(b[1])).all()


def test_ivf_search_force_kernel_matches_scan():
    X = _coords(80, 700, 9)
    idx = IVFZenIndex.build(X, 12, key=jax.random.PRNGKey(9))
    Q = _queries(81, X, 5)
    d0, i0 = idx.search(Q, 7, nprobe=5)
    d1, i1 = idx.search(Q, 7, nprobe=5, force_kernel=True)
    np.testing.assert_allclose(
        np.asarray(d0), np.asarray(d1), rtol=1e-5, atol=1e-5
    )
    assert (np.asarray(i0) == np.asarray(i1)).all()


# -- n_neighbors clamping (regression: nn > N / > cluster pool) ----------------


def test_knn_search_clamps_n_neighbors_regression():
    X = _coords(90, 7, 5)
    Q = X[:2]
    for kw in (dict(), dict(chunk=4), dict(force_kernel=True),
               dict(stream=True)):
        d, ids = Z.knn_search(Q, X, n_neighbors=20, **kw)
        assert d.shape == (2, 7) and ids.shape == (2, 7)
        ids = np.asarray(ids)
        assert (ids >= 0).all() and (ids < 7).all()
        assert sorted(ids[0].tolist()) == list(range(7))  # valid ids only


def test_kernel_level_topk_clamps_n_neighbors_regression():
    from repro.kernels import zen_topk as zt

    X = _coords(91, 9, 6)
    Q = X[:3]
    for fn in (lambda: zt.zen_topk_scan(Q, X, 25, "zen", chunk=4),
               lambda: zt.zen_topk(Q, X, 25, "zen", interpret=True),
               lambda: ops.zen_topk(Q, X, 25)):
        d, ids = fn()
        assert d.shape == (3, 9) and ids.shape == (3, 9)
        assert (np.asarray(ids) >= 0).all()


def test_sharded_knn_search_clamps_n_neighbors_regression():
    from jax.sharding import Mesh

    from repro.distributed.retrieval import sharded_knn_search

    mesh = Mesh(np.array(jax.devices()[:1]), ("shard",))
    X = _coords(92, 11, 6)
    Q = X[:2]
    d, ids = sharded_knn_search(Q, X, 30, mesh=mesh)
    assert ids.shape == (2, 11)
    assert (np.asarray(ids) >= 0).all() and (np.asarray(ids) < 11).all()
    # with pre-padded rows: clamp to n_valid, padded rows never returned
    Xp = jnp.pad(X, ((0, 5), (0, 0)))
    d, ids = sharded_knn_search(Q, Xp, 30, mesh=mesh, n_valid=11)
    assert ids.shape == (2, 11)
    assert (np.asarray(ids) < 11).all()


def test_ivf_search_clamps_n_neighbors():
    X = _coords(93, 40, 5)
    idx = IVFZenIndex.build(X, 5, key=jax.random.PRNGKey(10))
    Q = X[:2]
    d, ids = idx.search(Q, 99, nprobe=5)
    assert ids.shape == (2, 40)
    assert sorted(np.asarray(ids)[0].tolist()) == list(range(40))


# -- sharded IVF ---------------------------------------------------------------


def test_sharded_ivf_single_device_exact():
    from jax.sharding import Mesh

    from repro.index import ShardedIVFZenIndex

    mesh = Mesh(np.array(jax.devices()[:1]), ("shard",))
    X = _coords(100, 800, 10)
    Q = _queries(101, X, 6)
    sidx = ShardedIVFZenIndex.build(X, 12, mesh=mesh,
                                    key=jax.random.PRNGKey(11))
    want_d, want_i = Z.knn_search(Q, X, 9, "zen")
    got_d, got_i = sidx.search(Q, 9, nprobe=sidx.n_clusters)
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-4, atol=1e-4
    )
    assert (np.asarray(got_i) == np.asarray(want_i)).all()


_SHARDED_IVF_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import zen as Z
    from repro.index import ShardedIVFZenIndex

    mesh = Mesh(np.array(jax.devices()[:4]), ("shard",))
    rng = np.random.default_rng(2)
    for n in [1000, 1001, 37]:  # ragged shard splits + n < shards * tile
        X = rng.normal(size=(n, 12)).astype(np.float32)
        X[:, -1] = np.abs(X[:, -1])
        X = jnp.asarray(X)
        Q = jnp.asarray(rng.normal(size=(6, 12)), jnp.float32)
        C = min(16, n)
        sidx = ShardedIVFZenIndex.build(X, C, mesh=mesh,
                                        key=jax.random.PRNGKey(0))
        want_d, want_i = Z.knn_search(Q, X, min(10, n), "zen")
        got_d, got_i = sidx.search(Q, 10, nprobe=sidx.n_clusters)
        assert np.allclose(np.asarray(got_d), np.asarray(want_d),
                           atol=1e-4), n
        assert (np.asarray(got_i) == np.asarray(want_i)).all(), n
        # partial probes still return only valid (or -1 padding) ids
        _, ids = sidx.search(Q, 10, nprobe=2)
        ids = np.asarray(ids)
        assert ((ids >= -1) & (ids < n)).all(), n
    print("SHARDED_IVF_OK")
""")


def test_sharded_ivf_multi_device_merge():
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
    )
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_IVF_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED_IVF_OK" in r.stdout


# -- serving integration -------------------------------------------------------


def test_zen_server_ivf_full_probe_matches_flat():
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenIndex, ZenServer, build_index

    key = jax.random.PRNGKey(5)
    corpus = syn.uniform_space(key, 2000, 64)
    ivf_index = build_index(corpus, 8, index="ivf", n_clusters=24)
    assert ivf_index.ivf is not None
    flat_index = ZenIndex(transform=ivf_index.transform,
                          coords=ivf_index.coords, corpus=ivf_index.corpus)
    q = syn.uniform_space(jax.random.fold_in(key, 1), 5, 64)
    d0, i0 = ZenServer(flat_index, chunk=256).query(q, 5)
    d1, i1 = ZenServer(ivf_index, nprobe=24).query(q, 5)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-4,
                               atol=1e-4)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    # rerank over the IVF candidate pool returns valid ids
    d2, i2 = ZenServer(ivf_index, nprobe=6, rerank_factor=4).query(q, 5)
    assert (np.asarray(i2) >= 0).all() and (np.asarray(i2) < 2000).all()


def test_build_index_rejects_unknown_mode():
    from repro.data import synthetic as syn
    from repro.launch.serve import build_index

    corpus = syn.uniform_space(jax.random.PRNGKey(0), 200, 16)
    with pytest.raises(ValueError):
        build_index(corpus, 4, index="hnsw")


# -- the memory bound ----------------------------------------------------------


def test_probe_memory_flat_in_index_size():
    """XLA temp allocation of the probe scan: fixed tile geometry, 8x the
    index rows -> flat working set (the clustered analogue of
    test_topk_retrieval.py::test_streaming_memory_flat_in_index_size)."""
    q, kdim, nn, nprobe, tile_rows, T = 8, 16, 10, 8, 128, 2

    def temp_bytes(n_rows):
        n_c = n_rows // (T * tile_rows)
        shapes = (
            jax.ShapeDtypeStruct((q, kdim), jnp.float32),
            jax.ShapeDtypeStruct((n_c * T, tile_rows, kdim), jnp.float32),
            jax.ShapeDtypeStruct((n_c * T, tile_rows), jnp.int32),
            jax.ShapeDtypeStruct((q, nprobe), jnp.int32),
        )
        fn = lambda Q_, TC, TI, PR: ip.ivf_probe_scan(
            Q_, TC, TI, PR, nn, "zen", tiles_per_cluster=T
        )
        mem = jax.jit(fn).lower(*shapes).compile().memory_analysis()
        return mem.temp_size_in_bytes

    small, big = temp_bytes(16 * 1024), temp_bytes(128 * 1024)
    assert big <= 2 * max(small, 1), (small, big)
    assert big < q * 128 * 1024 * 4  # tile-sized, not index-sized


# -- tiered (host-offloaded) tile store ---------------------------------------


def _tiered_fixture(seed=17, n=1500, k=8, c=16, storage="float32"):
    from repro.index.ivf import TieredIVFZenIndex

    X = _coords(seed, n, k)
    idx = IVFZenIndex.build(X, c, key=jax.random.PRNGKey(seed),
                            storage=storage)
    tiered = TieredIVFZenIndex.from_index(idx, hot_clusters=3,
                                          prefetch_cols=2)
    return X, idx, tiered


@pytest.mark.parametrize("storage", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("nprobe", [1, 4, 16])
def test_tiered_search_matches_resident(storage, nprobe):
    """Hot-pass + streamed-cold-chunk search returns exactly the resident
    index's results at every nprobe: same kernel over the same tiles, only
    partitioned into device-resident and staged passes."""
    X, idx, tiered = _tiered_fixture(storage=storage)
    Q = _queries(1, X, 12)
    want_d, want_i = idx.search(Q, n_neighbors=10, nprobe=nprobe)
    got_d, got_i = tiered.search(Q, n_neighbors=10, nprobe=nprobe)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-5, atol=1e-5)


def test_tiered_all_hot_and_all_cold_extremes():
    from repro.index.ivf import TieredIVFZenIndex

    X = _coords(18, 900, 8)
    idx = IVFZenIndex.build(X, 12, key=jax.random.PRNGKey(18))
    Q = _queries(2, X, 8)
    want = idx.search(Q, n_neighbors=10, nprobe=12)
    for hot in (0, 12):  # pure streaming vs fully resident
        t = TieredIVFZenIndex.from_index(idx, hot_clusters=hot)
        got = t.search(Q, n_neighbors=10, nprobe=12)
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(want[1])), hot
        st = t.stats()
        if hot == 0:
            assert st["cold_uploads"] > 0 and st["hot_hits"] == 0
        else:
            assert st["cold_uploads"] == 0 and st["hot_hits"] > 0
        # the analytic provisioning bound dominates the observed mark
        assert t.provisioned_device_bytes(Q.shape[0]) >= st["device_bytes"]


def test_tiered_stage_kernel_interpret_parity():
    """The Pallas double-buffered DMA staging path (interpret mode on CPU)
    produces the same device blocks — and therefore the same search
    results — as the device_put fallback."""
    from repro.index.ivf import TieredIVFZenIndex

    X = _coords(19, 800, 8)
    idx = IVFZenIndex.build(X, 10, key=jax.random.PRNGKey(19))
    Q = _queries(3, X, 6)
    plain = TieredIVFZenIndex.from_index(idx, hot_clusters=2)
    forced = TieredIVFZenIndex.from_index(idx, hot_clusters=2,
                                          force_stage_kernel=True)
    d0, i0 = plain.search(Q, n_neighbors=8, nprobe=10)
    d1, i1 = forced.search(Q, n_neighbors=8, nprobe=10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_dma_copy_blocks_roundtrip_dtypes():
    from repro.kernels import tile_stage

    rng = np.random.default_rng(20)
    for dtype in (np.float32, np.int32):
        src = rng.normal(size=(5, 4, 8)).astype(dtype)
        out = tile_stage.dma_copy_blocks(jnp.asarray(src), interpret=True)
        np.testing.assert_array_equal(np.asarray(out), src)


def test_tiered_tile_pool_snapshot_mmap_roundtrip(tmp_path):
    """save() persists the packed pool; load(mmap=True) serves straight
    off the snapshot (cold tiles stay on disk) with identical results."""
    from repro.index.ivf import TieredIVFZenIndex

    for storage in ("float32", "int8"):
        X, idx, tiered = _tiered_fixture(seed=21, storage=storage)
        Q = _queries(4, X, 8)
        want = tiered.search(Q, n_neighbors=10, nprobe=16)
        path = str(tmp_path / f"pool-{storage}")
        tiered.save(path)
        back = TieredIVFZenIndex.load(path, mmap=True, hot_clusters=3)
        assert isinstance(back.host_coords, np.memmap)
        got = back.search(Q, n_neighbors=10, nprobe=16)
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(want[1]))
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]))
        assert back.size == tiered.size and back.storage == storage


def test_tiered_refresh_hot_follows_traffic():
    """refresh_hot() re-picks the device-resident set from observed probe
    traffic; results stay identical (residency is a placement decision)."""
    X, idx, tiered = _tiered_fixture(seed=22)
    Q = _queries(5, X, 16)
    want = tiered.search(Q, n_neighbors=10, nprobe=4)
    before = tiered.stats()["cold_uploads"]
    tiered.refresh_hot()
    got = tiered.search(Q, n_neighbors=10, nprobe=4)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    # the re-picked hot set covers this query mix at least as well
    assert tiered.stats()["cold_uploads"] - before <= before


def test_tiered_dead_shard_masks_members():
    from repro.index.ivf import TieredIVFZenIndex

    X = _coords(23, 1200, 8)
    idx = IVFZenIndex.build(X, 16, key=jax.random.PRNGKey(23))
    tiered = TieredIVFZenIndex.from_index(idx, hot_clusters=4, n_shards=4)
    Q = _queries(6, X, 12)
    tiered.set_dead_shards([1])
    d, ids = tiered.search(Q, n_neighbors=10, nprobe=16)
    dead_clusters = np.flatnonzero(tiered.shard_of_cluster() == 1)
    dead_members = set(np.asarray(
        idx.tile_ids).reshape(16, -1)[dead_clusters].ravel().tolist()) - {-1}
    assert not (set(np.asarray(ids).ravel().tolist()) & dead_members)
    assert tiered.stats()["masked_clusters"] == 4
    tiered.set_dead_shards([])  # recovery restores exactness
    _, ids2 = tiered.search(Q, n_neighbors=10, nprobe=16)
    want = idx.search(Q, n_neighbors=10, nprobe=16)
    np.testing.assert_array_equal(np.asarray(ids2), np.asarray(want[1]))
