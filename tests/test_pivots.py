"""Pivot (base-simplex) selection strategies: determinism, the menu
contract, metric generality, documented degenerate fallbacks, and the
bit-identity of ``strategy="random"`` with the paper's original redraw
loop (``core.projection.select_references``)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import pivots as pivots_lib
from repro.core import projection as projection_lib
from repro.core.projection import fit_transform
from repro.data import synthetic as syn


def _corpus(seed=0, n=300, m=32):
    return syn.manifold_space(jax.random.PRNGKey(seed), n, m, m // 8)


# -- the menu ------------------------------------------------------------------


def test_unknown_strategy_rejected_everywhere():
    X = _corpus()
    D = np.zeros((4, 4))
    with pytest.raises(ValueError, match="pivot strategy"):
        pivots_lib.check_strategy("spectral")
    with pytest.raises(ValueError, match="pivot strategy"):
        pivots_lib.select_pivot_indices(D, 2, "spectral")
    with pytest.raises(ValueError, match="pivot strategy"):
        pivots_lib.select_references(X, 4, jax.random.PRNGKey(0),
                                     strategy="spectral")
    with pytest.raises(ValueError, match="pivot strategy"):
        fit_transform(X, 4, jax.random.PRNGKey(0), pivots="spectral")


def test_pivot_count_validated():
    D = np.zeros((5, 5))
    for bad_k in (0, 6):
        with pytest.raises(ValueError, match="pivots"):
            pivots_lib.select_pivot_indices(D, bad_k, "farthest_first")


# -- determinism + basic shape of the selection --------------------------------


@pytest.mark.parametrize("strategy", pivots_lib.PIVOT_STRATEGIES)
def test_selection_deterministic_distinct_in_range(strategy):
    X = _corpus(1)
    key = jax.random.PRNGKey(3)
    ids1 = pivots_lib.pivot_ids(X, 8, key, strategy=strategy)
    ids2 = pivots_lib.pivot_ids(X, 8, key, strategy=strategy)
    np.testing.assert_array_equal(ids1, ids2)
    assert len(set(ids1.tolist())) == 8
    assert ids1.min() >= 0 and ids1.max() < X.shape[0]


@pytest.mark.parametrize("strategy", pivots_lib.PIVOT_STRATEGIES)
def test_fitted_transform_usable(strategy):
    """Every strategy yields a non-degenerate base on a healthy corpus and
    the fitted transform produces finite apex coordinates."""
    X = _corpus(2)
    tr = pivots_lib.select_references(X, 6, jax.random.PRNGKey(1),
                                      strategy=strategy)
    assert not bool(tr.degenerate())
    Xp = np.asarray(tr.transform(X[:50]))
    assert Xp.shape == (50, 6) and np.isfinite(Xp).all()
    assert (Xp[:, -1] >= 0).all()  # altitudes are non-negative


def test_random_delegates_bit_identical():
    """strategy="random" must consume the same key stream as the paper's
    redraw loop — identical references, identical coordinates."""
    X = _corpus(3)
    key = jax.random.PRNGKey(7)
    t_old = projection_lib.select_references(X, 8, key)
    t_new = pivots_lib.select_references(X, 8, key, strategy="random")
    np.testing.assert_array_equal(np.asarray(t_old.transform(X[:64])),
                                  np.asarray(t_new.transform(X[:64])))


def test_fit_transform_pivots_knob():
    X = _corpus(4)
    key = jax.random.PRNGKey(2)
    tr_r, Xp_r = fit_transform(X, 8, key)
    tr_f, Xp_f = fit_transform(X, 8, key, pivots="farthest_first")
    assert Xp_r.shape == Xp_f.shape == (X.shape[0], 8)
    # different strategies pick different bases (same key, same corpus)
    assert not np.array_equal(np.asarray(Xp_r), np.asarray(Xp_f))
    tr_r2, Xp_r2 = fit_transform(X, 8, key, pivots="random")
    np.testing.assert_array_equal(np.asarray(Xp_r), np.asarray(Xp_r2))


# -- the strategies' defining properties ---------------------------------------


def test_farthest_first_is_maxmin_greedy():
    """Each appended pivot is exactly argmax of the min-distance to the
    chosen prefix (replayed step by step against the implementation)."""
    rng = np.random.default_rng(5)
    P = rng.normal(size=(60, 4))
    D = np.sqrt(((P[:, None] - P[None]) ** 2).sum(-1))
    got = pivots_lib.farthest_first_indices(D, 6)
    chosen = [int(np.argmax(D.mean(axis=1)))]
    while len(chosen) < 6:
        mind = D[:, chosen].min(axis=1)
        mind[chosen] = -np.inf
        chosen.append(int(np.argmax(mind)))
    np.testing.assert_array_equal(got, chosen)


def test_farthest_first_spreads_more_than_random():
    X = _corpus(6, n=400)
    D = np.asarray(jnp.sqrt(jnp.maximum(
        ((X[:, None] - X[None]) ** 2).sum(-1), 0.0)))
    ff = pivots_lib.farthest_first_indices(D, 8)
    rnd = pivots_lib.select_pivot_indices(D, 8, "random",
                                          key=jax.random.PRNGKey(0))

    def min_sep(ids):
        sub = D[np.ix_(ids, ids)]
        return sub[np.triu_indices(8, 1)].min()

    assert min_sep(ff) >= min_sep(rnd)


def test_maxvol_grows_altitude():
    """maxvol's k-th pivot has the largest altitude over the simplex of the
    first k-1 — replay the last greedy step."""
    rng = np.random.default_rng(7)
    P = rng.normal(size=(80, 6))
    D = np.sqrt(((P[:, None] - P[None]) ** 2).sum(-1))
    k = 5
    ids = pivots_lib.maxvol_indices(D, k)
    prefix = list(ids[:-1])
    from repro.core import simplex as simplex_lib
    base = simplex_lib.build_base_simplex(
        jnp.asarray(D[np.ix_(prefix, prefix)], jnp.float32))
    coords = simplex_lib.apex_project(
        base, jnp.asarray(D[:, prefix], jnp.float32))
    alt = np.asarray(coords[:, -1], np.float64)
    alt[~np.isfinite(alt)] = -np.inf
    alt[prefix] = -np.inf
    assert int(np.argmax(alt)) == ids[-1]


# -- metric generality (coordinate-free spaces) --------------------------------


@pytest.mark.parametrize("strategy", pivots_lib.PIVOT_STRATEGIES)
def test_strategies_under_jsd_metric(strategy):
    X = syn.probability_space(jax.random.PRNGKey(11), 200, 32, 4)
    tr = pivots_lib.select_references(X, 5, jax.random.PRNGKey(1),
                                      metric="jsd", strategy=strategy)
    Xp = np.asarray(tr.transform(X[:20]))
    assert Xp.shape == (20, 5) and np.isfinite(Xp).all()


# -- degenerate corners (documented fallbacks) ---------------------------------


def test_kmeanspp_all_duplicates_deterministic_fill():
    D = np.zeros((6, 6))  # every witness identical
    ids = pivots_lib.kmeanspp_indices(D, 4, jax.random.PRNGKey(0))
    assert len(set(ids.tolist())) == 4


def test_maxvol_all_duplicates_and_k1():
    D = np.zeros((5, 5))
    ids = pivots_lib.maxvol_indices(D, 3)
    assert len(set(ids.tolist())) == 3
    rng = np.random.default_rng(8)
    P = rng.normal(size=(30, 3))
    D = np.sqrt(((P[:, None] - P[None]) ** 2).sum(-1))
    (only,) = pivots_lib.maxvol_indices(D, 1)
    assert only == int(np.argmax(D.mean(axis=1)))


def test_witness_subsample_bounds_matrix():
    """n > max_witness: selection runs on the deterministic subsample and
    the returned ids map back into the full corpus."""
    X = _corpus(9, n=500)
    ids = pivots_lib.pivot_ids(X, 6, jax.random.PRNGKey(4),
                               strategy="farthest_first", max_witness=64)
    assert len(set(ids.tolist())) == 6
    assert ids.max() < 500
    ids2 = pivots_lib.pivot_ids(X, 6, jax.random.PRNGKey(4),
                                strategy="farthest_first", max_witness=64)
    np.testing.assert_array_equal(ids, ids2)


def test_degenerate_principled_fit_falls_back_to_random():
    """A corpus whose principled pivots give a degenerate simplex (mass
    duplication) still returns a usable transform via the random redraw
    fallback instead of serving a broken base."""
    rng = np.random.default_rng(10)
    row = rng.normal(size=(1, 16)).astype(np.float32)
    X = jnp.asarray(np.concatenate([np.repeat(row, 40, 0),
                                    rng.normal(size=(4, 16)).astype(
                                        np.float32)]))
    key = jax.random.PRNGKey(0)
    # 4 distinct points + mass duplication: any 6 pivots repeat a vertex,
    # so farthest_first's fit is degenerate and must hand over to the
    # random redraw loop — byte-identical to calling it directly
    tr_fb = pivots_lib.select_references(X, 6, key,
                                         strategy="farthest_first")
    tr_rand = projection_lib.select_references(X, 6, key)
    np.testing.assert_array_equal(np.asarray(tr_fb.transform(X[:8])),
                                  np.asarray(tr_rand.transform(X[:8])))
