"""Simulation-driven tests of the micro-batching serving frontend.

The scheduler never sleeps on its own: ``tick()`` is a plain synchronous
function and the clock is injected, so every test here drives the frontend
step by step — submit, advance the fake clock, tick, observe — with no
real threads and no timing flakiness. The contract under test is the
acceptance bar of the frontend PR: every scheduled, coalesced, padded, or
cached response is **bit-identical** to the same query served directly,
for every estimator mode, flat and IVF, with and without re-rank, across
arbitrary interleavings of queries and churn.
"""
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fixed-seed replay keeps the suite green
    from _hypothesis_fallback import given, settings, st

from repro.data import synthetic as syn
from repro.launch.serve import ZenIndex, ZenServer, build_index
from repro.serving import (
    FrontendOverloadError,
    LRUCache,
    bucket_neighbors,
    bucket_q,
    query_fingerprint,
)

N, DIM, K = 600, 48, 10
N_CLUSTERS = 24


@pytest.fixture(scope="module", autouse=True)
def _x32():
    """The frontend serves the stack's default f32 numerics; some sibling
    modules flip ``jax_enable_x64`` globally at import time, so pin it off
    for this module (autouse + module scope: applies before the corpus /
    index fixtures build anything)."""
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    yield
    jax.config.update("jax_enable_x64", prev)


class FakeClock:
    """Deterministic injectable time source."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def corpus():
    return syn.manifold_space(jax.random.PRNGKey(0), N, DIM, 8)


@pytest.fixture(scope="module")
def queries():
    return np.asarray(
        syn.manifold_space(jax.random.PRNGKey(1), 32, DIM, 8), np.float32)


@pytest.fixture(scope="module")
def base_index(corpus):
    return {
        "flat": build_index(corpus, K, index="flat"),
        "ivf": build_index(corpus, K, index="ivf", n_clusters=N_CLUSTERS),
    }


def _frontend_server(index, **kw):
    kw.setdefault("nprobe", 8)
    kw.setdefault("frontend", True)
    kw.setdefault("clock", kw.pop("clock", None) or FakeClock())
    return ZenServer(index, **kw)


def _rows_equal(a, b):
    return (np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
            and np.array_equal(np.asarray(a[1]), np.asarray(b[1])))


# -- bucket helpers -----------------------------------------------------------


def test_bucket_q_power_of_two_floor_two():
    assert [bucket_q(q) for q in (1, 2, 3, 4, 5, 8, 9, 100)] == \
        [2, 2, 4, 4, 8, 8, 16, 128]
    assert bucket_q(100, max_batch=32) == 32


def test_bucket_neighbors_menu_then_pow2():
    assert [bucket_neighbors(n) for n in (1, 8, 9, 16, 100, 128)] == \
        [8, 8, 16, 16, 128, 128]
    assert bucket_neighbors(129) == 256  # off-menu stays bounded
    assert bucket_neighbors(5, menu=(4, 32)) == 32


# -- coalescing / splitting ---------------------------------------------------


def test_coalescing_k_submitters_one_dispatch(base_index, queries):
    """K concurrent single-row submitters collapse into one dispatch."""
    server = _frontend_server(base_index["flat"])
    sched = server.frontend
    handles = [sched.submit(queries[i], 10) for i in range(5)]
    assert sched.backlog == 5
    assert not any(h.done() for h in handles)
    assert sched.tick() == 1                      # one coalesced dispatch
    assert sched.backlog == 0
    st_ = sched.stats
    assert st_.dispatches == 1
    assert st_.dispatched_rows == 5 and st_.padded_rows == 8  # bucket 8
    assert st_.occupancy == pytest.approx(5 / 8)
    for i, h in enumerate(handles):
        assert h.done()
        assert _rows_equal(h.result(),
                           server.query(queries[i][None], 10, direct=True))


def test_split_at_max_batch(base_index, queries):
    """Oversized coalesced groups split into max_batch-row dispatches."""
    server = _frontend_server(base_index["flat"], max_batch=4)
    sched = server.frontend
    handles = [sched.submit(queries[i], 10) for i in range(11)]
    assert sched.tick() == 3                      # ceil(11 / 4)
    assert sched.stats.dispatches == 3
    assert max(s[0] for s in sched.stats.dispatch_shapes) <= 4
    for i, h in enumerate(handles):
        assert _rows_equal(h.result(),
                           server.query(queries[i][None], 10, direct=True))


def test_mixed_n_neighbors_group_by_geometry(base_index, queries):
    """Requests with different bucketed widths dispatch separately — each
    row computes at exactly the geometry its direct call would use."""
    server = _frontend_server(base_index["flat"])
    sched = server.frontend
    h10 = sched.submit(queries[0], 10)   # n_bucket 16
    h9 = sched.submit(queries[1], 9)     # n_bucket 16 — same group
    h40 = sched.submit(queries[2], 40)   # n_bucket 64 — separate group
    assert sched.tick() == 2
    assert _rows_equal(h10.result(),
                       server.query(queries[0][None], 10, direct=True))
    assert _rows_equal(h9.result(),
                       server.query(queries[1][None], 9, direct=True))
    assert _rows_equal(h40.result(),
                       server.query(queries[2][None], 40, direct=True))


@pytest.mark.parametrize("kind", ["flat", "ivf"])
@pytest.mark.parametrize("mode", ["zen", "lwb", "upb"])
def test_bucket_padding_parity(base_index, queries, kind, mode):
    """Padded coalesced dispatches are bit-identical to per-query direct
    calls — every estimator mode, flat and IVF."""
    server = _frontend_server(base_index[kind], mode=mode)
    sched = server.frontend
    handles = [sched.submit(queries[i], 10) for i in range(7)]  # pads to 8
    sched.tick()
    for i, h in enumerate(handles):
        direct = server.query(queries[i][None], 10, direct=True)
        assert _rows_equal(h.result(), direct), (kind, mode, i)


@pytest.mark.parametrize("kind", ["flat", "ivf"])
def test_bucket_padding_parity_with_rerank(base_index, queries, kind):
    """Parity survives the exact re-rank stage (wider bucketed pools)."""
    server = _frontend_server(base_index[kind], rerank_factor=4)
    handles = [server.frontend.submit(queries[i], 10) for i in range(5)]
    server.frontend.tick()
    for i, h in enumerate(handles):
        assert _rows_equal(h.result(),
                           server.query(queries[i][None], 10, direct=True))


def test_query_through_frontend_matches_direct(base_index, queries):
    """ZenServer.query as a thin scheduler client (inline ticking)."""
    server = _frontend_server(base_index["flat"])
    got = server.query(queries[:6], 10)
    want = server.query(queries[:6], 10, direct=True)
    assert _rows_equal(got, want)
    assert server.frontend.stats.completed >= 6


def test_direct_escape_hatch_bypasses_scheduler(base_index, queries):
    server = _frontend_server(base_index["flat"])
    before = server.frontend.stats.submitted
    server.query(queries[:3], 10, direct=True)
    assert server.frontend.stats.submitted == before
    assert server.frontend.backlog == 0


# -- backpressure -------------------------------------------------------------


def test_reject_on_full_backpressure(base_index, queries):
    server = _frontend_server(base_index["flat"], queue_limit=4)
    sched = server.frontend
    for i in range(4):
        sched.submit(queries[i], 10)
    with pytest.raises(FrontendOverloadError):
        sched.submit(queries[4], 10)
    assert sched.stats.rejected == 1
    assert sched.backlog == 4                 # reject enqueued nothing
    # a multi-row submit that cannot fully fit is rejected atomically
    sched.tick()
    sched.submit(queries[:3], 10)
    with pytest.raises(FrontendOverloadError):
        sched.submit(queries[3:6], 10)        # 3 rows, 1 slot free
    assert sched.backlog == 3
    sched.flush()
    assert sched.backlog == 0


# -- cache --------------------------------------------------------------------


def test_lru_cache_eviction_order():
    c = LRUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1        # refreshes "a" -> "b" is now LRU
    c.put("c", 3)                 # evicts "b"
    assert c.get("b") is None and c.get("a") == 1 and c.get("c") == 3
    assert c.evictions == 1
    assert len(c) == 2


def test_lru_cache_disabled_at_zero_capacity():
    c = LRUCache(0)
    c.put("a", 1)
    assert c.get("a") is None and len(c) == 0


def test_query_fingerprint_canonicalises():
    row64 = np.arange(4, dtype=np.float64)
    assert query_fingerprint(row64) == query_fingerprint(
        row64.astype(np.float32))
    assert query_fingerprint(row64) != query_fingerprint(row64 + 1e-6)


def test_cache_hit_resolves_without_tick(base_index, queries):
    server = _frontend_server(base_index["flat"], cache_size=64)
    sched = server.frontend
    h1 = sched.submit(queries[0], 10)
    sched.tick()
    h2 = sched.submit(queries[0], 10)
    assert h2.done()                          # no tick needed
    assert sched.stats.cache_hits == 1
    assert _rows_equal(h1.result(), h2.result())
    # a different n_neighbors in the same bucket also hits, sliced
    h3 = sched.submit(queries[0], 9)
    assert h3.done() and sched.stats.cache_hits == 2
    d9, i9 = h3.result()
    d10, i10 = h1.result()
    assert np.array_equal(i9[0], i10[0, :9])
    assert np.array_equal(d9[0], d10[0, :9])


def test_cache_miss_on_new_query(base_index, queries):
    server = _frontend_server(base_index["flat"], cache_size=64)
    sched = server.frontend
    sched.submit(queries[0], 10)
    sched.tick()
    h = sched.submit(queries[1], 10)
    assert not h.done()                       # genuinely new row: a miss
    assert sched.stats.cache_misses == 2
    sched.flush()


@pytest.mark.parametrize("churn", ["upsert", "delete", "compact"])
def test_cache_invalidation_on_churn(base_index, queries, corpus, churn):
    """upsert/delete/compact bump the index generation; stale entries can
    no longer be looked up, and the re-served answer matches a fresh
    direct query of the churned index."""
    server = _frontend_server(base_index["flat"], cache_size=64)
    sched = server.frontend
    sched.submit(queries[0], 10)
    sched.tick()
    assert sched.stats.cache_misses == 1
    gen0 = server.index.generation
    if churn == "upsert":
        server.upsert([N + 1], np.asarray(corpus)[:1] * 0.5)
    elif churn == "delete":
        server.delete([int(np.asarray(sched.submit(queries[0], 10)
                                      .result()[1])[0, 0])])
    else:
        server.delete([3])                    # make compact non-trivial
        server.compact()
    assert server.index.generation > gen0
    h = sched.submit(queries[0], 10)
    assert not h.done()                       # old-generation entry ignored
    sched.tick()
    assert _rows_equal(h.result(),
                       server.query(queries[0][None], 10, direct=True))


def test_generation_counter_no_bump_on_noop(base_index):
    idx = base_index["flat"]
    assert idx.generation == 0
    assert idx.delete([10 ** 6]).generation == 0        # unknown id: no-op
    assert idx.upsert([], np.zeros((0, K))).generation == 0
    assert idx.compact().generation == 0                # untouched index
    # a compaction with nothing to reclaim is a no-op on IVF too — a
    # periodic compact() must not invalidate the result cache
    ivf = base_index["ivf"]
    assert ivf.compact() is ivf


@pytest.mark.parametrize("kind", ["flat", "ivf"])
def test_generation_counter_bumps(base_index, kind):
    idx = base_index[kind]
    rows = np.ones((1, K), np.float32)
    up = idx.upsert([N + 7], rows)
    assert up.generation == idx.generation + 1
    de = up.delete([N + 7])
    assert de.generation > up.generation
    co = de.compact()
    assert co.generation > de.generation
    if kind == "ivf":  # the counter is threaded through IVFZenIndex too
        assert up.ivf.generation == idx.ivf.generation + 1
        assert co.ivf.generation > de.ivf.generation


def test_empty_index_through_frontend(base_index, queries):
    server = _frontend_server(base_index["flat"])
    server.delete(np.arange(N))
    assert server.index.size == 0
    d, ids = server.query(queries[:3], 10)
    assert d.shape == (3, 10) and bool(jnp.isinf(d).all())
    assert bool((np.asarray(ids) == -1).all())


def test_cache_stores_row_copies_not_views(base_index, queries):
    """Entries are per-row copies — a view would pin the whole (Qp,
    n_bucket) dispatch arrays for as long as one row survives the LRU."""
    server = _frontend_server(base_index["flat"], cache_size=8)
    sched = server.frontend
    sched.submit(queries[0], 10)
    sched.tick()
    ((d_row, id_row),) = list(sched.cache._data.values())
    assert d_row.base is None and id_row.base is None
    assert d_row.shape == (16,)               # stored at the bucketed width


# -- dispatch failures --------------------------------------------------------


def test_dispatch_failure_resolves_waiters_and_ticker_survives(
        base_index, queries):
    """A raising dispatch fails its waiters (result() re-raises) instead
    of hanging them, and the scheduler keeps serving afterwards."""
    server = _frontend_server(base_index["flat"])
    sched = server.frontend
    good = sched.submit(queries[0], 10)
    bad = sched.submit(np.ones(7, np.float32), 10)  # wrong query dim
    sched.tick()                                    # ragged group: raises
    assert good.done() and bad.done()               # resolved, not hung
    with pytest.raises(Exception):
        bad.result(timeout=1)
    with pytest.raises(Exception):                  # same failed chunk
        good.result(timeout=1)
    assert sched.stats.failures == 2
    h = sched.submit(queries[1], 10)                # scheduler still alive
    sched.tick()
    assert _rows_equal(h.result(),
                       server.query(queries[1][None], 10, direct=True))


# -- clock / latency instrumentation ------------------------------------------


def test_fake_clock_drives_latency_stats(base_index, queries):
    clock = FakeClock()
    server = _frontend_server(base_index["flat"], clock=clock)
    sched = server.frontend
    h = sched.submit(queries[0], 10)
    clock.advance(0.25)                       # request waits a quarter second
    sched.tick()
    assert h.latency_s == pytest.approx(0.25)
    pct = sched.stats.latency_percentiles()
    assert pct["p50_ms"] == pytest.approx(250.0)
    assert pct["p99_ms"] == pytest.approx(250.0)
    # a cache-free second request resolved in the same tick shares the bill
    h2 = sched.submit(queries[1], 10)
    clock.advance(0.05)
    sched.tick()
    assert h2.latency_s == pytest.approx(0.05)


def test_stats_snapshot_keys(base_index, queries):
    server = _frontend_server(base_index["flat"], cache_size=8)
    server.query(queries[:4], 10)
    out = server.stats()
    fe = out["frontend"]
    for key in ("submitted", "completed", "rejected", "dispatches",
                "batch_occupancy", "cache_hit_rate", "compile_count",
                "p50_ms", "p95_ms", "p99_ms"):
        assert key in fe, key
    assert out["cache"]["capacity"] == 8
    assert fe["submitted"] == 4 and fe["completed"] == 4


# -- jit-cache bounding (the direct-path fix rides the same buckets) ----------


def test_jit_cache_bounded_over_odd_shapes_flat(base_index, queries):
    """20 odd-shaped (Q, n_neighbors) batches compile only a handful of
    bucketed entries — the direct-path recompile fix."""
    from repro.core import zen as Z

    server = ZenServer(base_index["flat"])    # no frontend: direct path
    Z._dense_topk._clear_cache()
    shapes = set()
    for i in range(20):
        q_rows, nn = 1 + i, 3 + (i % 9)       # 20 distinct caller shapes
        server.query(queries[:q_rows], nn)
        nb, w = server._query_geometry(nn)
        shapes.add((bucket_q(q_rows), w))
    assert len(shapes) <= 10                  # 5 Q buckets x 2 widths
    assert Z._dense_topk._cache_size() <= len(shapes)
    assert Z._dense_topk._cache_size() < 20   # strictly fewer than callers


def test_jit_cache_bounded_over_odd_shapes_ivf(base_index, queries):
    from repro.index.ivf import _ivf_search

    server = ZenServer(base_index["ivf"], nprobe=8)
    _ivf_search._clear_cache()
    for i in range(20):
        server.query(queries[:1 + i], 3 + (i % 9))
    # Q buckets {2..32} x one n_bucket span — far below 20 caller shapes
    assert _ivf_search._cache_size() <= 8


def test_ivf_jit_cache_stable_under_inplace_refresh(
        base_index, corpus, queries):
    """The generation counter must not ride in the jit-static aux: an
    in-place refresh (upsert replacing an id; n_valid/n_deleted round-trip
    to their prior values) re-hits the existing `_ivf_search` entry
    instead of recompiling once per churn event."""
    from repro.index.ivf import _ivf_search

    server = ZenServer(base_index["ivf"], nprobe=8)
    _ivf_search._clear_cache()
    server.query(queries[:4], 10)
    base_size = _ivf_search._cache_size()
    for _ in range(3):
        server.upsert([5], np.asarray(corpus)[5:6])   # in-place refresh
        server.query(queries[:4], 10)
    assert server.index.generation == 3               # cache keys moved on
    assert _ivf_search._cache_size() == base_size     # ...but no recompile


# -- ticker thread ------------------------------------------------------------


def test_ticker_thread_serves_concurrent_callers(base_index, queries):
    """Real threads + the background ticker: concurrent ZenServer.query
    calls coalesce and every caller gets its direct-path bits."""
    server = ZenServer(base_index["flat"], frontend=True,
                       tick_interval=0.001)
    server.frontend.start()
    try:
        results = {}

        def caller(i):
            results[i] = server.query(queries[i][None], 10)

        threads = [threading.Thread(target=caller, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert len(results) == 8
        for i in range(8):
            assert _rows_equal(results[i],
                               server.query(queries[i][None], 10,
                                            direct=True))
    finally:
        server.frontend.stop()
    assert not server.frontend.running


# -- property: random submit/churn interleavings ------------------------------


_PROP_STATE = {}


def _prop_server(kind):
    """Module-cached small server base for the property examples."""
    if kind not in _PROP_STATE:
        corpus = syn.manifold_space(jax.random.PRNGKey(5), 300, 24, 6)
        _PROP_STATE[kind] = build_index(
            corpus, 8, index=kind,
            n_clusters=12 if kind == "ivf" else None)
    return _PROP_STATE[kind]


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_random_interleaving_matches_direct(seed):
    """Any interleaving of submits, churn, and ticks: every response is
    bit-identical to a fresh direct query at resolution time."""
    rng = np.random.default_rng(seed)
    kind = "ivf" if seed % 2 else "flat"
    server = ZenServer(_prop_server(kind), frontend=True, cache_size=32,
                       nprobe=6, clock=FakeClock())
    sched = server.frontend
    qpool = rng.normal(size=(16, 24)).astype(np.float32)
    pending = []          # (handle, qrow, n_neighbors), not yet verified
    next_id = 10_000

    def verify_resolved():
        still = []
        for h, qrow, nn in pending:
            if h.done():
                direct = server.query(qrow[None], nn, direct=True)
                assert _rows_equal(h.result(), direct)
            else:
                still.append((h, qrow, nn))
        pending[:] = still

    for _ in range(rng.integers(8, 20)):
        op = rng.choice(["submit", "submit", "submit", "tick", "upsert",
                         "delete", "compact"])
        if op == "submit":
            qrow = qpool[rng.integers(0, len(qpool))]
            nn = int(rng.integers(1, 12))
            try:
                h = sched.submit(qrow, nn)
            except FrontendOverloadError:
                continue
            pending.append((h, qrow, nn))
            verify_resolved()         # cache hits resolve at submit time
        elif op == "tick":
            sched.tick()
            verify_resolved()         # verify before any further churn
        elif op == "upsert":
            sched.tick()              # drain, then verify, then churn —
            verify_resolved()         # responses reflect dispatch-time state
            server.upsert([next_id], rng.normal(size=(1, 24)).astype(
                np.float32))
            next_id += 1
        elif op == "delete":
            sched.tick()
            verify_resolved()
            server.delete([int(rng.integers(0, 300))])
        else:
            sched.tick()
            verify_resolved()
            server.compact()
    sched.flush()
    verify_resolved()
    assert not pending


# -- stats edge cases ----------------------------------------------------------


def test_latency_percentiles_empty_window_is_nan_not_crash():
    """Before any request completes there is no latency sample: the
    percentile accessors answer NaN (np.percentile of [] raises), and
    snapshot() omits the keys rather than reporting a fabricated 0ms SLO."""
    from repro.serving.stats import FrontendStats

    stats = FrontendStats()
    pct = stats.latency_percentiles()
    assert set(pct) == {"p50_ms", "p95_ms", "p99_ms"}
    assert all(np.isnan(v) for v in pct.values())
    snap = stats.snapshot()
    assert not any(k in snap for k in ("p50_ms", "p95_ms", "p99_ms"))
    stats.record_complete(1, 0.1)  # first sample: keys appear, real values
    snap = stats.snapshot()
    assert snap["p50_ms"] == pytest.approx(100.0)


def test_tick_dispatch_count_excludes_failed_dispatches(base_index, queries):
    """tick() returns the number of dispatches *issued*; a raising dispatch
    issued no kernel and must not count (its rows land in failures)."""
    server = _frontend_server(base_index["flat"])
    sched = server.frontend
    sched.submit(np.ones(7, np.float32), 10)  # wrong query dim: will raise
    assert sched.tick() == 0
    assert sched.stats.failures == 1
    sched.submit(queries[0], 10)
    assert sched.tick() == 1
