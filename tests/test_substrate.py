"""Substrate tests: optimizer, checkpoint (atomic/async/reshard), data
pipeline determinism, neighbor sampler, fault-tolerance hooks, compression."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import synthetic as syn
from repro.data.graph import CSRGraph, random_graph, sample_padded_batch
from repro.data.pipeline import PrefetchPipeline, shard_for_host
from repro.distributed.fault import (
    HeartbeatRegistry,
    PreemptionGuard,
    StepMonitor,
)
from repro.optim import AdamW, clip_by_global_norm
from repro.optim import compression as comp_lib


# ----------------------------- optimizer ------------------------------------


def test_adamw_reduces_quadratic_loss():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        updates, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_adamw_moments_are_f32_for_bf16_params():
    params = {"w": jnp.zeros((3,), jnp.bfloat16)}
    state = AdamW().init(params)
    assert state.mu["w"].dtype == jnp.float32


# ----------------------------- checkpoint -----------------------------------


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.int32(7)]}
    mgr.save(3, tree)
    step, restored = mgr.restore(like=tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"][0].dtype == jnp.bfloat16


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(10_000, dtype=jnp.float32)}
    mgr.save_async(1, tree)
    mgr.wait()
    # no tmp dirs left behind; manifest readable
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp.")]
    step, restored = mgr.restore(like=tree)
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(tree["x"]))


def test_checkpoint_elastic_reshard(tmp_path):
    """Save replicated, restore onto a 1x1 mesh with explicit sharding."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    specs = {"w": P(None, None)}
    mgr.save(5, tree, specs)
    mesh = make_host_mesh(1, 1)
    step, restored = mgr.restore(mesh=mesh, like=tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


# ----------------------------- data pipeline --------------------------------


def test_pipeline_deterministic_restart():
    make = lambda step: syn.lm_batch(7, step, 2, 8, 100)
    p1 = PrefetchPipeline(make, start_step=0)
    seq1 = [next(p1) for _ in range(5)]
    p1.close()
    # restart at step 3: batches must be byte-identical
    p2 = PrefetchPipeline(make, start_step=3)
    step, batch = next(p2)
    p2.close()
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(batch["tokens"]), np.asarray(seq1[3][1]["tokens"]))


def test_shard_for_host_slices_batch():
    batch = {"x": jnp.arange(8).reshape(8, 1)}
    out = shard_for_host(batch, host_index=1, num_hosts=4)
    np.testing.assert_array_equal(np.asarray(out["x"]).ravel(), [2, 3])


# ----------------------------- graph sampler --------------------------------


def test_csr_graph_neighbors():
    g = CSRGraph.from_edges(
        np.array([0, 0, 1, 2]), np.array([1, 2, 2, 0]), num_nodes=3)
    assert sorted(g.neighbors(0).tolist()) == [1, 2]
    assert g.neighbors(1).tolist() == [2]


def test_neighbor_sampler_shapes_and_validity():
    g = random_graph(1000, avg_degree=12, seed=0)
    batch = sample_padded_batch(
        g, batch_nodes=32, fanout=(15, 10), max_nodes=8192, max_edges=8192,
        seed=1)
    assert batch["senders"].shape == (8192,)
    n_valid_edges = int(batch["edge_mask"].sum())
    n_valid_nodes = int(batch["node_mask"].sum())
    assert 32 <= n_valid_nodes <= 8192
    assert n_valid_edges >= 32  # at least the root fanout edges
    # all valid edge endpoints are valid local node ids
    s = batch["senders"][: n_valid_edges]
    r = batch["receivers"][: n_valid_edges]
    assert (s < n_valid_nodes).all() and (r < n_valid_nodes).all()
    assert int(batch["root_mask"].sum()) == 32


def test_sampler_respects_fanout():
    g = random_graph(500, avg_degree=20, seed=2)
    batch = sample_padded_batch(
        g, batch_nodes=4, fanout=(5,), max_nodes=512, max_edges=512, seed=3)
    # each root samples at most 5 1-hop edges
    assert int(batch["edge_mask"].sum()) <= 4 * 5


# ----------------------------- fault tolerance ------------------------------


def test_step_monitor_flags_stragglers():
    mon = StepMonitor(threshold=2.0, warmup_steps=2, patience=2)
    for i in range(10):
        assert mon.record(i, 1.0) is None
    ev = mon.record(10, 5.0)
    assert ev is not None and ev.ratio > 2.0
    assert not mon.should_escalate
    mon.record(11, 5.0)
    assert mon.should_escalate


def test_step_monitor_ema_excludes_stragglers():
    mon = StepMonitor(threshold=2.0, warmup_steps=1)
    for i in range(5):
        mon.record(i, 1.0)
    mon.record(5, 100.0)  # straggler must not poison the EMA
    assert mon.ema < 1.5


def test_heartbeat_registry():
    t = [0.0]
    reg = HeartbeatRegistry(deadline_s=10.0, now=lambda: t[0])
    reg.beat("host0"); reg.beat("host1")
    t[0] = 5.0
    reg.beat("host0")
    t[0] = 12.0
    assert reg.dead_hosts() == ["host1"]
    assert reg.alive() == ["host0"]


def test_preemption_guard_manual_trigger():
    g = PreemptionGuard(install_signal=False)
    assert not g.should_save()
    g.request()
    assert g.should_save()
    g.clear()
    assert not g.should_save()


# ----------------------------- compression ----------------------------------


def test_compression_roundtrip_error_bound():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)), jnp.float32)
    rec, resid = comp_lib.compress_decompress(x)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(resid))) <= scale * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(rec + resid), np.asarray(x), atol=1e-6)


def test_error_feedback_is_unbiased_over_time():
    """EF residual carries over: sum of exchanged grads converges to sum of
    true grads (the EF-SGD guarantee)."""
    rng = np.random.default_rng(1)
    state = comp_lib.init_state({"g": jnp.zeros(64)})
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for _ in range(200):
        g = {"g": jnp.asarray(rng.normal(size=64) * 1e-3, jnp.float32)}
        sent, state = comp_lib.error_feedback_update(g, state)
        total_true += np.asarray(g["g"])
        total_sent += np.asarray(sent["g"])
    # residual is bounded, so averages converge
    err = np.abs(total_sent + np.asarray(state.error["g"]) - total_true).max()
    assert err < 1e-4
