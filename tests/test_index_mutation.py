"""Mutable corpus lifecycle: upsert / delete / compact on the IVF and flat
retrieval paths.

The invariants under test: tombstoned ids are never returned; upserted points
are immediately searchable and exact at nprobe = n_clusters; replacing an id
moves it (old coordinates gone, new ones found); a full inverted list grows
by whole tiles without disturbing existing members; compact drops tombstones
and tile slack without changing results; and — the acceptance bar — recall
after heavy churn stays within 0.02 of a freshly built index.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.quality import recall_at_k
from repro.data import synthetic as syn
from repro.index import IVFZenIndex
from repro.kernels import zen_topk as zt
from repro.launch.serve import ZenServer, build_index


def _coords(key, n, k=8):
    """Synthetic apex-like coordinates (non-negative altitude column)."""
    x = jax.random.normal(key, (n, k), jnp.float32)
    return x.at[:, -1].set(jnp.abs(x[:, -1]))


def _ids(res):
    return np.asarray(res[1])


# ---------------------------------------------------------------- IVF index

def test_ivf_delete_tombstones_never_returned():
    key = jax.random.PRNGKey(0)
    X = _coords(key, 1500)
    idx = IVFZenIndex.build(X, 12, key=key)
    Q = X[:6] + 0.01
    victims = _ids(idx.search(Q, 3, nprobe=idx.n_clusters))[:, 0]
    idx2 = idx.delete(victims)
    assert idx2.n_valid == 1500 - len(np.unique(victims))
    assert idx2.n_deleted == len(np.unique(victims))
    got = _ids(idx2.search(Q, 10, nprobe=idx2.n_clusters))
    assert not (set(victims.tolist()) & set(got.ravel().tolist()))
    # the original index is untouched (functional update)
    assert idx.n_valid == 1500


def test_ivf_delete_unknown_ids_is_noop():
    key = jax.random.PRNGKey(1)
    X = _coords(key, 400)
    idx = IVFZenIndex.build(X, 8, key=key)
    idx2 = idx.delete([10_000, 20_000])
    assert idx2 is idx  # nothing matched, no copy


def test_ivf_upsert_matches_fresh_build_exactly():
    key = jax.random.PRNGKey(2)
    X = _coords(key, 1200)
    idx = IVFZenIndex.build(X, 10, key=key)
    Xnew = _coords(jax.random.fold_in(key, 1), 300)
    idx2 = idx.upsert(np.arange(1200, 1500), Xnew)
    assert idx2.n_valid == 1500

    # nprobe = C scans everything: parity with a flat scan over the union
    allX = jnp.concatenate([X, Xnew])
    Q = _coords(jax.random.fold_in(key, 2), 8)
    d_ref, i_ref = zt.zen_topk_scan(Q, allX, 10, "zen")
    d_got, i_got = idx2.search(Q, 10, nprobe=idx2.n_clusters)
    np.testing.assert_allclose(
        np.asarray(d_got), np.asarray(d_ref), atol=1e-5)
    assert np.array_equal(np.asarray(i_got), np.asarray(i_ref))


def test_ivf_upsert_existing_id_replaces_and_can_move_cluster():
    key = jax.random.PRNGKey(3)
    X = _coords(key, 600)
    idx = IVFZenIndex.build(X, 8, key=key)
    # move id 5 to the far side of the space: its old location must stop
    # matching and its new location must match
    target = X[500]
    idx2 = idx.upsert([5], target[None] + 1e-4)
    assert idx2.n_valid == 600  # replaced, not added
    near_new = _ids(idx2.search(target[None], 2, nprobe=idx2.n_clusters))[0]
    assert 5 in near_new.tolist()
    near_old = _ids(idx2.search(X[5][None], 1, nprobe=idx2.n_clusters))[0]
    assert near_old[0] != 5


def test_ivf_upsert_duplicate_ids_last_write_wins():
    key = jax.random.PRNGKey(4)
    X = _coords(key, 300)
    idx = IVFZenIndex.build(X, 4, key=key)
    a, b = np.asarray(X[100]), np.asarray(X[200])
    idx2 = idx.upsert([900, 900], np.stack([a, b]))
    assert idx2.n_valid == 301
    got = _ids(idx2.search(b[None], 2, nprobe=idx2.n_clusters))[0]
    assert 900 in got.tolist()


def test_ivf_upsert_into_full_tile_grows_by_tile():
    key = jax.random.PRNGKey(5)
    X = _coords(key, 64)
    idx = IVFZenIndex.build(X, 2, tile_rows=32, key=key)
    T0 = idx.tiles_per_cluster
    # force one cluster past capacity: upsert many copies of one point
    base = np.asarray(X[0])
    n_new = idx.tiles_per_cluster * idx.tile_rows + 5
    new = base[None] + 0.001 * np.random.default_rng(0).normal(
        size=(n_new, X.shape[1])).astype(np.float32)
    new[:, -1] = np.abs(new[:, -1])
    idx2 = idx.upsert(np.arange(100, 100 + n_new), new)
    assert idx2.tiles_per_cluster > T0
    assert idx2.n_valid == 64 + n_new
    # layout invariants: shapes consistent, every id present exactly once
    C, T, R = idx2.n_clusters, idx2.tiles_per_cluster, idx2.tile_rows
    assert idx2.tile_ids.shape == (C * T, R)
    assert idx2.tile_coords.shape == (C * T, R, X.shape[1])
    tids = np.asarray(idx2.tile_ids)
    live = tids[tids >= 0]
    assert len(live) == len(np.unique(live)) == idx2.n_valid
    # old members (away from the inserted cloud around X[0]) survived
    got = _ids(idx2.search(X[1:5], 1, nprobe=C))[:, 0]
    assert np.array_equal(got, np.arange(1, 5))


def test_ivf_delete_all_in_cell_still_searches():
    key = jax.random.PRNGKey(6)
    X = _coords(key, 500)
    idx = IVFZenIndex.build(X, 6, key=key)
    sizes = idx.cluster_sizes()
    cell = int(np.argmax(sizes))
    tids = np.asarray(idx.tile_ids).reshape(
        idx.n_clusters, idx.tiles_per_cluster * idx.tile_rows)
    members = tids[cell][tids[cell] >= 0]
    idx2 = idx.delete(members)
    assert idx2.cluster_sizes()[cell] == 0
    assert idx2.n_valid == 500 - len(members)
    # probing every cluster (including the empty one) stays correct
    Q = X[:8] + 0.01
    live = np.setdiff1d(np.arange(500), members)
    d_ref, i_ref = zt.zen_topk_scan(Q, X[live], 5, "zen")
    d_got, i_got = idx2.search(Q, 5, nprobe=idx2.n_clusters)
    np.testing.assert_allclose(
        np.asarray(d_got), np.asarray(d_ref), atol=1e-5)
    assert np.array_equal(live[np.asarray(i_ref)], np.asarray(i_got))


def test_ivf_delete_everything_returns_empty_slots():
    key = jax.random.PRNGKey(7)
    X = _coords(key, 200)
    idx = IVFZenIndex.build(X, 4, key=key).delete(np.arange(200))
    assert idx.n_valid == 0
    d, ids = idx.search(X[:3], 5, nprobe=idx.n_clusters)
    assert d.shape == (3, 5) and ids.shape == (3, 5)  # full width kept
    assert (np.asarray(ids) == -1).all()
    assert np.isinf(np.asarray(d)).all()


def test_ivf_in_place_refresh_does_not_trip_compaction():
    # replacing existing ids reuses the freed slots immediately: a pure
    # refresh must not accumulate tombstone pressure
    key = jax.random.PRNGKey(15)
    X = _coords(key, 1000)
    idx = IVFZenIndex.build(X, 8, key=key)
    refresh_ids = np.arange(300)
    for r in range(3):
        new = _coords(jax.random.fold_in(key, 20 + r), 300)
        idx = idx.upsert(refresh_ids, new)
    assert idx.n_valid == 1000
    assert idx.n_deleted == 0
    assert not idx.needs_compact()


def test_ivf_compact_drops_tombstones_and_slack():
    key = jax.random.PRNGKey(8)
    X = _coords(key, 1000)
    idx = IVFZenIndex.build(X, 8, key=key)
    idx = idx.delete(np.arange(0, 1000, 2))  # 50% tombstones
    assert idx.needs_compact()
    Q = _coords(jax.random.fold_in(key, 1), 6)
    before = idx.search(Q, 10, nprobe=idx.n_clusters)
    packed = idx.compact()
    assert packed.n_deleted == 0 and packed.n_valid == idx.n_valid
    assert packed.tiles_per_cluster <= idx.tiles_per_cluster
    after = packed.search(Q, 10, nprobe=packed.n_clusters)
    assert np.array_equal(_ids(before), _ids(after))
    # recluster variant rebalances but returns the same neighbours
    refit = idx.compact(recluster=True, key=key)
    again = refit.search(Q, 10, nprobe=refit.n_clusters)
    assert np.array_equal(_ids(before), _ids(again))


def test_ivf_needs_compact_tile_slack_trigger():
    key = jax.random.PRNGKey(9)
    X = _coords(key, 64)
    idx = IVFZenIndex.build(X, 2, tile_rows=16, key=key)
    # inflate T by packing one cluster, then delete the overflow again
    base = np.asarray(X[0])
    n_new = 4 * idx.tile_rows
    new = base[None] + 0.001 * np.random.default_rng(1).normal(
        size=(n_new, X.shape[1])).astype(np.float32)
    new[:, -1] = np.abs(new[:, -1])
    grown = idx.upsert(np.arange(1000, 1000 + n_new), new)
    churned = grown.delete(np.arange(1000, 1000 + n_new))
    assert churned.tiles_per_cluster == grown.tiles_per_cluster
    assert churned.needs_compact()  # tile slack alone must trigger
    packed = churned.compact()
    assert packed.tiles_per_cluster < churned.tiles_per_cluster


# ------------------------------------------------------------- flat serving

def test_flat_server_delete_and_upsert():
    key = jax.random.PRNGKey(10)
    corpus = syn.manifold_space(key, 2000, 64, 8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 8, 64, 8)
    srv = ZenServer(build_index(corpus, 8), rerank_factor=2)
    d0, i0 = srv.query(q, 5)
    victim = int(np.asarray(i0)[0, 0])
    srv.delete([victim])
    assert srv.index.size == 1999
    _, i1 = srv.query(q, 5)
    assert victim not in set(np.asarray(i1).ravel().tolist())
    # new id becomes searchable; rerank corpus follows
    srv.upsert([5000], corpus[victim][None])
    _, i2 = srv.query(q, 5)
    assert 5000 in set(np.asarray(i2)[0].tolist())
    stats = srv.stats()
    assert stats["upserts"] == 1 and stats["deletes"] == 1


def test_flat_upsert_existing_id_replaces_in_place():
    key = jax.random.PRNGKey(11)
    corpus = syn.manifold_space(key, 800, 32, 8)
    srv = ZenServer(build_index(corpus, 8), rerank_factor=4)
    cap_before = srv.index.coords.shape[0]
    srv.upsert([3], corpus[700][None])
    assert srv.index.coords.shape[0] == cap_before  # replaced, no growth
    assert srv.index.size == 800
    # ids 3 and 700 now hold identical vectors; with exact re-rank both are
    # at true distance 0 from the query and must fill the top-2
    _, ids = srv.query(corpus[700][None], 2)
    assert set(np.asarray(ids)[0].tolist()) == {3, 700}


def test_flat_upsert_growth_and_compact():
    key = jax.random.PRNGKey(12)
    corpus = syn.manifold_space(key, 500, 32, 8)
    srv = ZenServer(build_index(corpus, 8), rerank_factor=0)
    extra = syn.manifold_space(jax.random.fold_in(key, 2), 700, 32, 8)
    srv.upsert(np.arange(500, 1200), extra)  # exceeds capacity -> grow
    assert srv.index.size == 1200
    assert srv.index.coords.shape[0] >= 1200
    q = syn.manifold_space(jax.random.fold_in(key, 3), 6, 32, 8)
    d0, i0 = srv.query(q, 10)
    # heavy delete then compact: same answers on the survivors
    srv.delete(np.arange(0, 500))
    assert srv.index.needs_compact()
    assert srv.maybe_compact()
    assert srv.index.size == 700 == srv.index.coords.shape[0]
    d1, i1 = srv.query(q, 10)
    assert (np.asarray(i1) >= 500).all()
    # the flat scan is exact over the reduced coords: the churned index must
    # agree bit-for-bit with a direct scan of the survivors under the SAME
    # fitted transform (ids are positions + 500)
    tr = srv.index.transform
    d_ref, i_ref = zt.zen_topk_scan(tr.transform(q), tr.transform(extra), 10,
                                    "zen")
    assert np.array_equal(np.asarray(i1), np.asarray(i_ref) + 500)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d_ref), atol=1e-5)


@pytest.mark.parametrize("kind", ["flat", "ivf"])
def test_sharded_index_mutation_rejected(kind):
    import math

    key = jax.random.PRNGKey(13)
    corpus = syn.manifold_space(key, 512, 32, 8)
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()), ("shard",))
    srv = ZenServer(build_index(corpus, 8, mesh=mesh, index=kind,
                                n_clusters=8), rerank_factor=0)
    with pytest.raises(NotImplementedError):
        srv.delete([0])
    with pytest.raises(NotImplementedError):
        srv.upsert([1000], corpus[:1])
    with pytest.raises(NotImplementedError):
        srv.compact()
    assert srv.maybe_compact() is False  # read-only probe must not raise
    assert math.isfinite(srv.stats()["p50_ms"])


def test_server_query_on_emptied_index_keeps_shape_contract():
    key = jax.random.PRNGKey(14)
    corpus = syn.manifold_space(key, 300, 32, 8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 4, 32, 8)
    for kind in ("flat", "ivf"):
        srv = ZenServer(build_index(corpus, 8, index=kind, n_clusters=4),
                        rerank_factor=2)
        srv.delete(np.arange(300))
        d, ids = srv.query(q, 5)
        assert d.shape == (4, 5) and ids.shape == (4, 5)
        assert (np.asarray(ids) == -1).all()
        assert np.isinf(np.asarray(d)).all()


def test_server_query_partially_filled_pads_to_requested_width():
    # fewer live rows than n_neighbors: the promised (Q, n) shape holds,
    # unfillable slots are (+inf, -1)
    key = jax.random.PRNGKey(16)
    corpus = syn.manifold_space(key, 300, 32, 8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 3, 32, 8)
    for kind in ("flat", "ivf"):
        srv = ZenServer(build_index(corpus, 8, index=kind, n_clusters=4),
                        rerank_factor=2)
        srv.delete(np.arange(295))  # 5 live rows left
        d, ids = srv.query(q, 10)
        assert d.shape == (3, 10) and ids.shape == (3, 10)
        ids_np = np.asarray(ids)
        assert ((ids_np >= 295) | (ids_np == -1)).all()
        assert (ids_np[:, 5:] == -1).all()
        assert np.isinf(np.asarray(d)[:, 5:]).all()


# -------------------------------------------------- churn acceptance (slow)

@pytest.mark.slow
def test_recall_after_20pct_churn_within_0p02_of_fresh():
    """Acceptance: 20% random churn on N=1e5, recall@10 of the churned IVF
    index within 0.02 of a freshly built index at the same nprobe."""
    key = jax.random.PRNGKey(42)
    n, kdim, n_churn = 100_000, 16, 20_000
    X = _coords(key, n, kdim)
    n_clusters = int(round(4 * n ** 0.5))
    idx = IVFZenIndex.build(X, n_clusters, n_iters=8, key=key)

    rng = np.random.default_rng(0)
    dead = rng.choice(n, size=n_churn, replace=False)
    Xnew = _coords(jax.random.fold_in(key, 1), n_churn, kdim)
    idx = idx.delete(dead).upsert(np.arange(n, n + n_churn), Xnew)
    if idx.needs_compact():
        idx = idx.compact()

    # live corpus after churn, with global ids
    live = np.setdiff1d(np.arange(n), dead)
    all_coords = jnp.concatenate([jnp.asarray(np.asarray(X)[live]), Xnew])
    all_ids = np.concatenate([live, np.arange(n, n + n_churn)])
    fresh = IVFZenIndex.build(
        all_coords, n_clusters, ids=all_ids, n_iters=8,
        key=jax.random.fold_in(key, 2))

    Q = _coords(jax.random.fold_in(key, 3), 64, kdim)
    _, truth_pos = zt.zen_topk_scan(Q, all_coords, 10, "zen")
    truth = all_ids[np.asarray(truth_pos)]

    nprobe = 16
    rec_churned = recall_at_k(truth, _ids(idx.search(Q, 10, nprobe=nprobe)))
    rec_fresh = recall_at_k(truth, _ids(fresh.search(Q, 10, nprobe=nprobe)))
    assert abs(rec_churned - rec_fresh) <= 0.02, (rec_churned, rec_fresh)
