"""Two-tower retrieval head + training-loop contracts beyond the arch smoke:
the loss actually learns on a learnable synthetic batch, the jitted head
keeps its shape/dtype contracts, and the dense-dot head agrees with the
Zen-reduced head (recall bar) on a trained tower."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import synthetic as syn
from repro.models import recsys
from repro.optim import AdamW

N_ITEMS = 256
CFG = recsys.RecsysConfig(
    name="tt_test", model="dlrm", n_sparse=4, embed_dim=16,
    vocab_sizes=(32,) * 4)


def _train(steps, seed=0, lr=3e-3, n_items=N_ITEMS, cfg=CFG, batch=64):
    params = recsys.init_two_tower_params(
        cfg, jax.random.PRNGKey(seed), n_items)
    opt = AdamW(learning_rate=lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state, b):
        (loss, aux), g = jax.value_and_grad(
            lambda p: recsys.two_tower_loss(cfg, p, b), has_aux=True)(params)
        upd, state = opt.update(g, state, params)
        return jax.tree.map(lambda a, u: a + u, params, upd), state, loss

    losses = []
    for s in range(steps):
        b = syn.two_tower_batch(seed, s, batch, cfg.vocab_sizes, n_items)
        params, state, loss = step(params, state, b)
        losses.append(float(loss))
    return params, losses


def test_two_tower_loss_decreases_fixed_seed():
    _, losses = _train(40)
    assert all(np.isfinite(losses))
    # compare averaged windows, not endpoints: single-step noise must not
    # flake the suite
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_two_tower_batch_deterministic_and_consistent():
    b1 = syn.two_tower_batch(3, 7, 32, CFG.vocab_sizes, N_ITEMS)
    b2 = syn.two_tower_batch(3, 7, 32, CFG.vocab_sizes, N_ITEMS)
    assert np.array_equal(np.asarray(b1["items"]), np.asarray(b2["items"]))
    assert b1["items"].dtype == jnp.int32
    assert int(jnp.max(b1["items"])) < N_ITEMS
    assert int(jnp.min(b1["items"])) >= 0
    # same sparse pattern -> same positive item (the learnable mapping)
    sparse = np.asarray(b1["sparse"])
    items = np.asarray(b1["items"])
    seen = {}
    for row, it in zip(map(tuple, sparse[:, :3]), items):
        assert seen.setdefault(row, it) == it


def test_user_repr_jit_contract():
    params = recsys.init_two_tower_params(CFG, jax.random.PRNGKey(0), N_ITEMS)
    b = syn.two_tower_batch(0, 0, 24, CFG.vocab_sizes, N_ITEMS)
    u = jax.jit(lambda p, bb: recsys.user_repr(CFG, p, bb))(params, b)
    assert u.shape == (24, CFG.embed_dim)
    assert u.dtype == jnp.float32


def test_retrieval_topk_jit_contract():
    params = recsys.init_two_tower_params(CFG, jax.random.PRNGKey(0), N_ITEMS)
    b = syn.two_tower_batch(0, 0, 8, CFG.vocab_sizes, N_ITEMS)
    u = recsys.user_repr(CFG, params, b)
    cands = recsys.item_repr(params)
    scores, ids = jax.jit(
        lambda q, c: recsys.retrieval_topk(q, c, k=9))(u, cands)
    assert scores.shape == (8, 9) and ids.shape == (8, 9)
    assert scores.dtype == jnp.float32
    assert jnp.issubdtype(ids.dtype, jnp.integer)
    # scores sorted descending per row
    assert np.all(np.diff(np.asarray(scores), axis=1) <= 1e-6)


def test_two_tower_towers_raw_not_normalized():
    params, _ = _train(10)
    b = syn.two_tower_batch(0, 999, 16, CFG.vocab_sizes, N_ITEMS)
    users, items = recsys.two_tower_towers(CFG, params, b)
    assert users.shape == (16, CFG.embed_dim)
    assert items.shape == (N_ITEMS, CFG.embed_dim)
    norms = np.linalg.norm(np.asarray(items), axis=1)
    assert norms.std() > 1e-3  # raw embeddings, not unit-sphere projected


def test_item_repr_gather_matches_full_table():
    params = recsys.init_two_tower_params(CFG, jax.random.PRNGKey(1), N_ITEMS)
    ids = jnp.asarray([0, 5, N_ITEMS - 1], jnp.int32)
    full = np.asarray(recsys.item_repr(params))
    sub = np.asarray(recsys.item_repr(params, ids))
    assert np.array_equal(sub, full[np.asarray(ids)])


def test_held_out_loss_improves_and_aux_contract():
    cfg = CFG
    params0 = recsys.init_two_tower_params(cfg, jax.random.PRNGKey(0), N_ITEMS)
    params1, _ = _train(40)
    # a batch the training stream never saw: the (pattern -> item) mapping
    # generalises, so the trained params score it strictly better
    b = syn.two_tower_batch(0, 12345, 64, cfg.vocab_sizes, N_ITEMS)
    loss0, aux0 = recsys.two_tower_loss(cfg, params0, b)
    loss1, aux1 = recsys.two_tower_loss(cfg, params1, b)
    for aux in (aux0, aux1):
        assert 0.0 <= float(aux["in_batch_acc"]) <= 1.0
        assert np.isfinite(float(aux["loss"]))
    assert float(loss1) < float(loss0)


def test_dense_dot_vs_zen_reduced_head_agreement():
    # the serving claim behind the e2e workload: a Zen-reduced index with
    # exact re-rank reproduces the dense retrieval head's top-k
    from repro.launch.serve import ZenServer, build_index

    params, _ = _train(60)
    b = syn.two_tower_batch(0, 54321, 32, CFG.vocab_sizes, N_ITEMS)
    users, items = recsys.two_tower_towers(CFG, params, b)
    # dense-dot ordering == Euclidean ordering on the normalized towers
    un = users / jnp.linalg.norm(users, axis=1, keepdims=True)
    vn = items / jnp.linalg.norm(items, axis=1, keepdims=True)
    _, dense_ids = recsys.retrieval_topk(un, vn, k=10)
    dense_ids = np.asarray(dense_ids)

    # k must stay at/below the ambient embed_dim: more references than
    # dimensions degrades the base simplex on this small tower
    index = build_index(vn, 16, index="flat", key=jax.random.PRNGKey(2))
    server = ZenServer(index, rerank_factor=8)
    zen_ids = np.asarray(server.query(un, 10)[1])
    recall = np.mean([len(set(dense_ids[i]) & set(zen_ids[i])) / 10
                      for i in range(dense_ids.shape[0])])
    assert recall >= 0.7
