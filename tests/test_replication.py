"""Deterministic simulation suite for the replicated serving tier.

Contract under test (``repro.launch.replicate``): a leader churns and
publishes atomic generation-tagged snapshots; replicas hot-swap to them
without dropping in-flight queries and serve **bit-identical** results to
a direct leader query at the replica's currently-loaded generation — never
a generation they have not fully swapped to. Everything here is driven
step by step (fake clocks, explicit poll/publish interleavings, a
hypothesis property over random schedules with a fixed-seed fallback); no
real threads sleep and no timing is load-bearing except in the one
explicit in-flight pinning test, which blocks on events, not time.
"""
import json
import os
import shutil
import tempfile
import threading

import numpy as np
import pytest

import jax

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fixed-seed replay keeps the suite green
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint.index_io import CheckpointFormatError
from repro.data import synthetic as syn
from repro.distributed.fault import ReplicaTracker
from repro.launch.replicate import (
    PUBLISH_POINTER,
    IndexLeader,
    LeaderHandedOff,
    QueryReplica,
    ReplicaNotReady,
    read_pointer,
)
from repro.launch.serve import ZenServer, build_index
from repro.serving import LRUCache, run_open_loop
from repro.serving.cache import result_key
from repro.serving.loadgen import poisson_arrivals

N, DIM, K = 400, 24, 8
N_CLUSTERS = 12


@pytest.fixture(scope="module", autouse=True)
def _x32():
    """Replication serves the stack's default f32 numerics; pin x64 off
    (sibling modules flip it at import time)."""
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    yield
    jax.config.update("jax_enable_x64", prev)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def corpus():
    return syn.manifold_space(jax.random.PRNGKey(0), N, DIM, 6)


@pytest.fixture(scope="module")
def queries():
    return np.asarray(
        syn.manifold_space(jax.random.PRNGKey(1), 12, DIM, 6), np.float32)


@pytest.fixture(scope="module")
def base_index(corpus):
    return {
        "flat": build_index(corpus, K, index="flat"),
        "ivf": build_index(corpus, K, index="ivf", n_clusters=N_CLUSTERS),
    }


def _fresh_vectors(seed, count):
    return np.asarray(
        syn.manifold_space(jax.random.PRNGKey(seed), count, DIM, 6),
        np.float32)


def _rows_equal(a, b):
    return (np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
            and np.array_equal(np.asarray(a[1]), np.asarray(b[1])))


# -- publish pointer protocol --------------------------------------------------


def test_pointer_absent_before_first_publish(tmp_path, base_index):
    root = str(tmp_path / "pub")
    assert read_pointer(root) is None
    rep = QueryReplica(root)
    assert rep.poll() is False
    with pytest.raises(ReplicaNotReady):
        rep.query(np.zeros((1, DIM), np.float32))


def test_publish_writes_generation_tagged_snapshot(tmp_path, base_index):
    leader = IndexLeader(ZenServer(base_index["flat"]), str(tmp_path))
    pub = leader.publish()
    assert pub.generation == 0
    assert os.path.basename(pub.snapshot) == "gen-000000000000"
    got = read_pointer(str(tmp_path))
    assert got == pub
    # republish of the same generation is idempotent
    assert leader.publish() == pub


def test_unknown_pointer_format_is_rejected_loudly(tmp_path):
    os.makedirs(tmp_path, exist_ok=True)
    with open(tmp_path / PUBLISH_POINTER, "w") as f:
        json.dump({"format": "someone-elses", "version": 9,
                   "generation": 3, "snapshot": "x"}, f)
    with pytest.raises(CheckpointFormatError):
        read_pointer(str(tmp_path))
    # a replica survives it: counted, not raised
    rep = QueryReplica(str(tmp_path))
    assert rep.poll() is False
    assert rep.poll_errors == 1


def test_publish_prunes_old_generations_but_never_current(
        tmp_path, base_index):
    leader = IndexLeader(ZenServer(base_index["flat"]), str(tmp_path),
                         keep=2)
    leader.publish()
    for seed in (10, 11, 12):
        leader.upsert([N + seed], _fresh_vectors(seed, 1))
        leader.publish()
    gens = sorted(d for d in os.listdir(tmp_path) if d.startswith("gen-")
                  and not d.endswith(".pool"))
    assert len(gens) == 2
    ptr = read_pointer(str(tmp_path))
    assert os.path.basename(ptr.snapshot) == gens[-1]


# -- hot-swap bit parity -------------------------------------------------------


@pytest.mark.parametrize("kind", ["flat", "ivf"])
@pytest.mark.parametrize("mmap", [False, True])
def test_replica_serves_bit_identical_to_leader(tmp_path, base_index,
                                                queries, kind, mmap):
    leader_srv = ZenServer(base_index[kind], nprobe=6, rerank_factor=2)
    leader = IndexLeader(leader_srv, str(tmp_path))
    leader.publish()
    rep = QueryReplica(str(tmp_path), mmap=mmap)
    assert rep.poll() is True
    assert rep.generation == 0
    assert _rows_equal(rep.query(queries, 5),
                       leader_srv.query(queries, 5, direct=True))


def test_churn_publish_swap_loop_zero_errors_bit_parity(
        tmp_path, base_index, queries):
    """The acceptance loop: churn -> publish -> swap -> query, many rounds,
    zero replica errors, every response bit-equal to the leader."""
    leader_srv = ZenServer(base_index["ivf"], nprobe=N_CLUSTERS)
    leader = IndexLeader(leader_srv, str(tmp_path), keep=3)
    leader.publish()
    rep = QueryReplica(str(tmp_path), mmap=True, frontend=True,
                       cache_size=64)
    assert rep.poll()
    for round_ in range(5):
        ids = [N + 10 * round_ + j for j in range(3)]
        leader.upsert(ids, _fresh_vectors(100 + round_, 3))
        leader.delete([round_, round_ + 20])
        leader.publish()
        assert rep.poll() is True
        assert rep.generation == leader.generation
        assert _rows_equal(rep.query(queries, 7),
                           leader_srv.query(queries, 7, direct=True))
    assert rep.poll_errors == 0
    assert rep.swaps == 6
    st_ = rep.stats()["server"]["frontend"]
    assert st_["failures"] == 0 and st_["swaps"] == 6


def test_replica_never_serves_an_unswapped_generation(
        tmp_path, base_index, queries):
    """Between a publish and the replica's poll, the replica must keep
    answering from its *currently loaded* generation — the new one becomes
    observable only through the swap."""
    leader_srv = ZenServer(base_index["flat"])
    leader = IndexLeader(leader_srv, str(tmp_path), keep=4)
    leader.publish()
    rep = QueryReplica(str(tmp_path))
    rep.poll()
    oracle_g0 = ZenServer.load(read_pointer(str(tmp_path)).snapshot)
    # leader moves two generations ahead; replica has not polled
    leader.delete([0, 1, 2, 3])
    leader.publish()
    leader.upsert([N + 1], _fresh_vectors(3, 1))
    leader.publish()
    assert rep.generation == 0
    assert _rows_equal(rep.query(queries, 6),
                       oracle_g0.query(queries, 6, direct=True))
    # after the swap — and only then — the replica serves the new state
    assert rep.poll() is True
    assert rep.generation == leader.generation
    assert _rows_equal(rep.query(queries, 6),
                       leader_srv.query(queries, 6, direct=True))


def test_swap_does_not_drop_in_flight_queries(tmp_path, base_index, queries):
    """A query in flight across a hot-swap resolves normally and keeps its
    generation pinned until it resolves (event-gated, no timing)."""
    leader_srv = ZenServer(base_index["flat"])
    leader = IndexLeader(leader_srv, str(tmp_path), keep=4)
    leader.publish()
    rep = QueryReplica(str(tmp_path), mmap=True)
    rep.poll()
    entered, release = threading.Event(), threading.Event()
    orig = rep.server._query_block

    def gated(*args, **kw):
        entered.set()
        assert release.wait(10), "test deadlock"
        return orig(*args, **kw)

    rep.server._query_block = gated
    out = []
    t = threading.Thread(
        target=lambda: out.append(rep.query(queries, 5, direct=True)))
    t.start()
    assert entered.wait(10)
    # swap under the in-flight query
    leader.upsert([N + 7], _fresh_vectors(9, 1))
    leader.publish()
    assert rep.poll() is True
    assert rep.pinned_generations() == (0, leader.generation)
    assert rep.released_generations() == ()
    release.set()
    t.join(10)
    assert out, "in-flight query was dropped by the swap"
    # the pin dropped with the last in-flight query; gen 0 is released
    assert rep.pinned_generations() == (leader.generation,)
    assert rep.released_generations() == (0,)
    rep.server._query_block = orig
    # the resolved result is a real served answer (some fully-swapped
    # generation — here the post-swap one, since the block re-reads index)
    assert _rows_equal(out[0], leader_srv.query(queries, 5, direct=True))


# -- generation as the coherence key (satellite: cache-key fix) ---------------


@pytest.mark.parametrize("kind", ["flat", "ivf"])
def test_loaded_snapshot_preserves_published_generation(
        tmp_path, base_index, kind):
    """Regression: ``ZenServer.load`` used to rebuild the index with a
    fresh ``generation=0`` regardless of the published counter, so a
    replica's cache keys collided with pre-publish entries. The restored
    index must serve under the *published* generation."""
    srv = ZenServer(base_index[kind])
    srv.upsert([N + 1, N + 2], _fresh_vectors(21, 2))
    srv.delete([N + 1])
    assert srv.index.generation == 2
    path = str(tmp_path / "snap")
    srv.save(path)
    restored = ZenServer.load(path)
    assert restored.index.generation == 2
    if kind == "ivf":
        assert restored.index.ivf.generation == 2


def test_pre_swap_cache_entry_is_unreachable_after_hot_swap(
        tmp_path, base_index, queries):
    """The replica's cache keys on the published generation: an entry
    cached at generation g must never answer a query once the replica has
    swapped to g' > g — even for the exact same query bytes."""
    leader_srv = ZenServer(base_index["flat"])
    leader = IndexLeader(leader_srv, str(tmp_path), keep=4)
    leader.publish()
    rep = QueryReplica(str(tmp_path), frontend=True, cache_size=128)
    rep.poll()
    d_old, ids_old = rep.query(queries, 5)
    cache = rep.server.frontend.cache
    assert cache.misses > 0 and len(cache) > 0
    # delete the current top-1 of the first query: its answer must change
    victim = int(np.asarray(ids_old)[0, 0])
    leader.delete([victim])
    leader.publish()
    assert rep.poll()
    # stale entries were evicted outright (capacity hygiene)...
    assert cache.stale_evictions > 0 and len(cache) == 0
    d_new, ids_new = rep.query(queries, 5)
    # ...and the answer is the new generation's, not the cached one
    assert victim not in np.asarray(ids_new)[0]
    assert _rows_equal((d_new, ids_new),
                       leader_srv.query(queries, 5, direct=True))


def test_lru_evict_stale_drops_only_other_generations():
    cache = LRUCache(8)
    k0 = result_key(b"q", "zen", 16, 8, 4, 0, 0)
    k1 = result_key(b"q", "zen", 16, 8, 4, 0, 1)
    cache.put(k0, "old")
    cache.put(k1, "new")
    assert cache.evict_stale(1) == 1
    assert cache.get(k1) == "new" and cache.get(k0) is None
    assert cache.stale_evictions == 1


# -- fault injection -----------------------------------------------------------


def test_leader_killed_mid_publish_leaves_only_loadable_snapshots(
        tmp_path, base_index, queries):
    """Crash windows of the publish sequence: whatever survives on disk,
    the pointer aims at a complete snapshot and the replica never loads a
    torn one."""
    leader_srv = ZenServer(base_index["flat"])
    leader = IndexLeader(leader_srv, str(tmp_path), keep=4)
    leader.publish()
    rep = QueryReplica(str(tmp_path))
    rep.poll()
    oracle_g0 = ZenServer.load(read_pointer(str(tmp_path)).snapshot)

    # window 1: killed while writing the snapshot — a tmp.* sibling
    # exists, the pointer still aims at gen 0
    torn = tmp_path / "tmp.gen-000000000099"
    os.makedirs(torn)
    (torn / "refs.npy").write_bytes(b"partial garbage")
    assert rep.poll() is False
    assert rep.generation == 0 and rep.poll_errors == 0

    # window 2: snapshot dir complete but killed before the pointer moved
    leader.upsert([N + 5], _fresh_vectors(5, 1))
    snap = str(tmp_path / "gen-000000000001")
    leader_srv.save(snap)  # the dir publish, without the pointer
    assert rep.poll() is False
    assert rep.generation == 0
    assert _rows_equal(rep.query(queries, 5),
                       oracle_g0.query(queries, 5, direct=True))

    # recovery: the restarted leader republishes — pointer moves, swap runs
    leader.publish()
    assert rep.poll() is True
    assert rep.generation == leader.generation
    assert _rows_equal(rep.query(queries, 5),
                       leader_srv.query(queries, 5, direct=True))


def test_pointer_to_vanished_snapshot_keeps_replica_serving(
        tmp_path, base_index, queries):
    leader_srv = ZenServer(base_index["flat"])
    leader = IndexLeader(leader_srv, str(tmp_path), keep=4)
    leader.publish()
    rep = QueryReplica(str(tmp_path))
    rep.poll()
    leader.upsert([N + 9], _fresh_vectors(8, 1))
    pub = leader.publish()
    shutil.rmtree(pub.snapshot)  # pruned/vanished under the pointer
    assert rep.poll() is False
    assert rep.poll_errors == 1 and rep.generation == 0
    d, ids = rep.query(queries, 5)  # still serving, just lagged
    assert np.asarray(ids).shape == (len(queries), 5)


def test_lagging_replica_and_tracker_verdicts(tmp_path, base_index):
    clock = FakeClock()
    leader = IndexLeader(ZenServer(base_index["flat"]), str(tmp_path),
                         keep=4)
    tracker = leader.track_replicas(deadline_s=10.0, clock=clock)
    assert isinstance(tracker, ReplicaTracker)
    leader.publish()
    rep_a = QueryReplica(str(tmp_path), name="a")
    rep_b = QueryReplica(str(tmp_path), name="b")
    rep_a.poll(), rep_b.poll()
    for r in (rep_a, rep_b):
        leader.replica_report(r.name, r.generation)
    assert leader.fleet_status()["lagging"] == []
    # publish a new generation; only a polls
    leader.delete([0])
    leader.publish()
    rep_a.poll()
    leader.replica_report("a", rep_a.generation)
    leader.replica_report("b", rep_b.generation)
    status = leader.fleet_status()
    assert status["lagging"] == ["b"]
    assert not tracker.coherent(leader.generation)
    # b goes silent past the deadline: dead, no longer counted as lagging
    clock.advance(11.0)
    leader.replica_report("a", rep_a.generation)
    status = leader.fleet_status()
    assert status["dead"] == ["b"] and status["lagging"] == []
    assert tracker.coherent(leader.generation)


def test_preemption_guard_hands_off_cleanly(tmp_path, base_index, queries):
    leader_srv = ZenServer(base_index["flat"])
    leader = IndexLeader(leader_srv, str(tmp_path), keep=4)
    leader.enable_preemption()
    leader.publish()
    rep = QueryReplica(str(tmp_path))
    rep.poll()
    leader.upsert([N + 3], _fresh_vectors(4, 1))
    assert leader.maybe_handoff() is False  # no preemption notice yet
    leader.preemption.request()             # platform announces preemption
    assert leader.maybe_handoff() is True
    assert leader.handed_off
    with pytest.raises(LeaderHandedOff):
        leader.upsert([N + 4], _fresh_vectors(5, 1))
    # the fleet swaps to the handoff snapshot...
    assert rep.poll() is True
    assert rep.generation == leader.generation
    assert _rows_equal(rep.query(queries, 5),
                       leader_srv.query(queries, 5, direct=True))
    # ...and a successor resumes churn from the published counter
    successor = IndexLeader(
        ZenServer.load(read_pointer(str(tmp_path)).snapshot),
        str(tmp_path), keep=4)
    assert successor.generation == leader.generation
    successor.upsert([N + 4], _fresh_vectors(5, 1))
    successor.publish()
    assert rep.poll() is True
    assert rep.generation == successor.generation


# -- property: random interleavings match a per-generation oracle -------------

_PROP_STATE = {}


def _prop_index(kind):
    if kind not in _PROP_STATE:
        corpus = syn.manifold_space(jax.random.PRNGKey(5), 300, 16, 4)
        _PROP_STATE[kind] = build_index(
            corpus, 6, index=kind, n_clusters=10 if kind == "ivf" else None)
    return _PROP_STATE[kind]


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_random_replication_schedule_matches_oracle(seed):
    """Any interleaving of churn, publish, per-replica poll and query:
    every replica response bit-equals a direct query against an oracle
    server loaded from the snapshot of the replica's *current* generation.

    (No pytest fixtures here: the hypothesis fallback shim replays a
    zero-argument wrapper, so the temp dir is managed by hand.)
    """
    rng = np.random.default_rng(seed)
    kind = "ivf" if seed % 2 else "flat"
    root = tempfile.mkdtemp(prefix="zen-repl-prop-")
    try:
        leader_srv = ZenServer(_prop_index(kind), nprobe=10)
        leader = IndexLeader(leader_srv, root, keep=50)  # no pruning mid-run
        leader.publish()
        oracles = {0: ZenServer.load(read_pointer(root).snapshot, nprobe=10)}
        reps = [QueryReplica(root, name=f"r{i}", mmap=bool(rng.integers(2)),
                             frontend=True,
                             cache_size=int(rng.integers(0, 33)))
                for i in range(2)]
        for r in reps:
            r.poll()
        qpool = rng.normal(size=(8, 16)).astype(np.float32)
        next_id = 10_000
        for _ in range(int(rng.integers(10, 24))):
            op = rng.choice(["churn", "publish", "poll", "query", "query"])
            if op == "churn":
                if rng.integers(2):
                    leader.upsert(
                        [next_id],
                        rng.normal(size=(1, 16)).astype(np.float32))
                    next_id += 1
                else:
                    leader.delete([int(rng.integers(0, 300))])
            elif op == "publish":
                pub = leader.publish()
                if pub.generation not in oracles:
                    oracles[pub.generation] = ZenServer.load(pub.snapshot,
                                                             nprobe=10)
            elif op == "poll":
                reps[int(rng.integers(2))].poll()
            else:
                rep = reps[int(rng.integers(2))]
                q = qpool[rng.integers(0, len(qpool))][None]
                nn = int(rng.integers(1, 8))
                got = rep.query(q, nn)
                want = oracles[rep.generation].query(q, nn, direct=True)
                assert _rows_equal(got, want), (
                    f"replica {rep.name} diverged from its generation "
                    f"{rep.generation} oracle (seed {seed})")
        for rep in reps:
            assert rep.poll_errors == 0
    finally:
        shutil.rmtree(root, ignore_errors=True)


# -- open-loop load generator (deterministic, fake clock) ---------------------


def test_poisson_arrivals_fixed_seed_and_rate():
    a = poisson_arrivals(200.0, 5.0, seed=3)
    b = poisson_arrivals(200.0, 5.0, seed=3)
    np.testing.assert_array_equal(a, b)
    assert (a >= 0).all() and (a < 5.0).all()
    assert np.all(np.diff(a) >= 0)
    assert a.size == pytest.approx(1000, rel=0.25)
    other = poisson_arrivals(200.0, 5.0, seed=4)
    assert other.size != a.size or not np.array_equal(other, a)


def test_open_loop_under_capacity_completes_everything(base_index, queries):
    clock = FakeClock()
    server = ZenServer(base_index["flat"], frontend=True, max_batch=16,
                       queue_limit=256, tick_interval=0.01, clock=clock)
    report = run_open_loop(server, queries, offered_qps=100.0,
                           duration_s=0.5, n_neighbors=5, seed=1,
                           clock=clock, sleep=clock.advance)
    assert report.rejected == 0 and report.failures == 0
    assert report.timeouts == 0
    assert report.completed == report.submitted > 0
    assert report.p99_ms == report.p99_ms  # not NaN
    # identical schedule + fake clock => bit-identical report
    clock2 = FakeClock()
    server2 = ZenServer(base_index["flat"], frontend=True, max_batch=16,
                        queue_limit=256, tick_interval=0.01, clock=clock2)
    report2 = run_open_loop(server2, queries, offered_qps=100.0,
                            duration_s=0.5, n_neighbors=5, seed=1,
                            clock=clock2, sleep=clock2.advance)
    assert report2 == report


def test_open_loop_overload_sheds_load_and_keeps_latency_bounded(
        base_index, queries):
    """Past the admission budget (max_batch per tick), reject-on-full
    sheds the excess; accepted requests still resolve promptly."""
    clock = FakeClock()
    server = ZenServer(base_index["flat"], frontend=True, max_batch=8,
                       queue_limit=8, tick_interval=0.01, clock=clock)
    # budget = 8 rows / 10ms = 800 qps; offer 4x that
    report = run_open_loop(server, queries, offered_qps=3200.0,
                           duration_s=0.25, n_neighbors=5, seed=2,
                           clock=clock, sleep=clock.advance)
    assert report.rejected > 0, "overload never tripped backpressure"
    assert report.completed > 0 and report.timeouts == 0
    assert report.achieved_qps < report.offered_qps
    # accepted work waits at most ~queue_limit/budget: well under a second
    assert report.p99_ms < 100.0


def test_open_loop_replica_fleet_scales_admission_budget(
        tmp_path, base_index, queries):
    """R replicas have R× the per-replica admission budget: at an offered
    rate that saturates one replica, the fleet's completed goodput scales
    with R (driven round-robin on one fake clock)."""
    leader = IndexLeader(ZenServer(base_index["ivf"], nprobe=6),
                         str(tmp_path))
    leader.publish()

    def fleet(n, clock):
        reps = [QueryReplica(str(tmp_path), name=f"r{i}", frontend=True,
                             max_batch=8, queue_limit=8, tick_interval=0.01,
                             cache_size=0, clock=clock, nprobe=6)
                for i in range(n)]
        for r in reps:
            assert r.poll()
        return [r.server for r in reps]

    results = {}
    for n in (1, 3):
        clock = FakeClock()
        servers = fleet(n, clock)
        report = run_open_loop(servers, queries, offered_qps=2400.0,
                               duration_s=0.25, n_neighbors=5, seed=4,
                               clock=clock, sleep=clock.advance)
        assert report.timeouts == 0 and report.failures == 0
        results[n] = report.completed
    assert results[3] >= 2 * results[1], results
