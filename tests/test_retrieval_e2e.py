"""Deterministic, smoke-shaped tests for the learned-embeddings-to-Zen
retrieval pipeline (``benchmarks/retrieval_e2e.py``): churn-during-training
ends with every live item retrievable through the frontend with
scheduled-vs-direct bit parity, the JSD/LM leg's simplex-domain invariants
hold through project -> index -> query, and the four paper-quality workloads
are importable and callable at tiny sizes (they had no smoke coverage and
hid a broken import path plus an LMDS eigen blowup)."""
import importlib.util
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.data import synthetic as syn
from repro.launch.serve import ZenServer, build_index
from repro.models import recsys
from repro.optim import AdamW

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load(path, name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(REPO, path))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def paper_quality():
    return _load("benchmarks/paper_quality.py", "pq_under_test")


# -- churn during training ------------------------------------------------


def test_churn_loop_all_live_items_retrievable():
    cfg = recsys.RecsysConfig(
        name="tt_e2e_test", model="dlrm", n_sparse=4, embed_dim=16,
        vocab_sizes=(32,) * 4)
    n_items = 192
    params = recsys.init_two_tower_params(cfg, jax.random.PRNGKey(0), n_items)
    opt = AdamW(learning_rate=3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, b):
        (loss, _), g = jax.value_and_grad(
            lambda p: recsys.two_tower_loss(cfg, p, b), has_aux=True)(params)
        upd, state = opt.update(g, state, params)
        return jax.tree.map(lambda a, u: a + u, params, upd), state, loss

    def train(params, state, start, steps):
        for s in range(start, start + steps):
            b = syn.two_tower_batch(0, s, 64, cfg.vocab_sizes, n_items)
            params, state, _ = step(params, state, b)
        return params, state

    params, state = train(params, state, 0, 8)
    items = recsys.item_repr(params)
    index = build_index(items, 12, index="ivf", key=jax.random.PRNGKey(1))
    server = ZenServer(index, nprobe=index.ivf.n_clusters, rerank_factor=8,
                       frontend=True, max_batch=32, cache_size=64)

    # churn: two rounds of continued training, each refreshing half the
    # corpus through the serving upsert path
    gen0 = server.index.generation
    for r in range(2):
        params, state = train(params, state, 1000 * (r + 1), 6)
        items = recsys.item_repr(params)
        ids = np.arange(r * (n_items // 2), (r + 1) * (n_items // 2))
        server.upsert(ids, np.asarray(items)[ids])
    assert server.index.generation == gen0 + 2

    # every live item id must come back as its own nearest neighbour, via
    # the scheduled frontend path, bit-identical to the direct path.  The
    # zen estimate between two identical apex projections is sqrt(2) x the
    # shared altitude — not zero — so the exact-rerank guarantee needs a
    # candidate pool (rerank_factor x nn) wider than the worst-case
    # approximate self-rank; nn=10 gives a pool of 80 on 192 items.
    live = np.asarray(server.index.corpus, np.float32)
    d_s, i_s = server.query(jnp.asarray(live), 10)
    d_d, i_d = server.query(jnp.asarray(live), 10, direct=True)
    assert np.array_equal(np.asarray(i_s), np.asarray(i_d))
    assert np.array_equal(np.asarray(d_s), np.asarray(d_d))
    assert np.array_equal(np.asarray(i_s)[:, 0], np.arange(n_items))


def test_frontend_cache_invalidated_by_churn():
    X = jnp.asarray(
        np.random.default_rng(0).normal(size=(128, 12)), jnp.float32)
    index = build_index(X, 8, key=jax.random.PRNGKey(0))
    server = ZenServer(index, rerank_factor=4, frontend=True, cache_size=32)
    q = X[:4]
    server.query(q, 5)
    server.query(q, 5)  # identical -> served from the generation-keyed cache
    hits_before = server.frontend.cache.info()["hits"]
    assert hits_before > 0
    server.upsert([0], np.asarray(X[:1]) + 0.5)
    d, i = server.query(q, 5)  # generation bumped -> recomputed, not stale
    info = server.frontend.cache.info()
    assert info["hits"] == hits_before
    d2, i2 = server.query(q, 5, direct=True)
    assert np.array_equal(np.asarray(d), np.asarray(d2))
    assert np.array_equal(np.asarray(i), np.asarray(i2))


# -- JSD / probability-simplex invariants ---------------------------------


def test_jsd_leg_simplex_invariants_through_serving():
    P = syn.probability_space(jax.random.PRNGKey(2), 160, 64, intrinsic=6)
    rows = np.asarray(P)
    np.testing.assert_allclose(rows.sum(1), np.ones(160), atol=1e-5)
    assert np.all(rows >= 0)
    # self-distance vanishes (up to f32 roundoff in the divergence)
    D = np.asarray(M.jsd_pdist(P[:24], P[:24], assume_normalized=True))
    assert float(np.abs(np.diagonal(D)).max()) < 2e-3

    index = build_index(P, 8, metric="jsd", index="flat",
                        key=jax.random.PRNGKey(3))
    server = ZenServer(index, rerank_factor=8)
    d, i = server.query(P[:24], 1)
    # a corpus row queried against the index comes back as itself at
    # (numerically) zero JSD after exact re-rank
    assert np.array_equal(np.asarray(i)[:, 0], np.arange(24))
    assert float(np.abs(np.asarray(d)).max()) < 2e-3


def test_lm_markov_batch_contract():
    b1 = syn.lm_markov_batch(5, 3, 16, 32, 64)
    b2 = syn.lm_markov_batch(5, 3, 16, 32, 64)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    t = np.asarray(b1["tokens"])
    assert t.shape == (16, 32) and t.dtype == np.int32
    assert t.min() >= 0 and t.max() < 64
    # Markov structure: the stream must not be i.i.d. uniform — consecutive
    # pairs repeat far more often than chance under a peaked transition
    pairs = {}
    for row in t:
        for a, b in zip(row[:-1], row[1:]):
            pairs[(int(a), int(b))] = pairs.get((int(a), int(b)), 0) + 1
    n_pairs = 16 * 31
    assert max(pairs.values()) >= 3 or len(pairs) < 0.8 * n_pairs


def test_next_token_distributions_simplex_rows():
    mod = _load("examples/train_lm.py", "train_lm_under_test")
    cfg, params, losses = mod.train_lm(2, batch=4, seq=16, data="markov")
    assert all(np.isfinite(losses))
    toks = syn.lm_markov_batch(1, 0, 6, 16, cfg.vocab_size)["tokens"]
    for temp in (1.0, 6.0):
        P = np.asarray(mod.next_token_distributions(
            cfg, params, toks, temperature=temp))
        assert P.shape == (6, cfg.vocab_size)
        np.testing.assert_allclose(P.sum(1), np.ones(6), atol=1e-4)
        assert np.all(P >= 0)
    # higher temperature must smooth (raise the entropy of) every row
    p1 = np.asarray(mod.next_token_distributions(cfg, params, toks,
                                                 temperature=1.0))
    p6 = np.asarray(mod.next_token_distributions(cfg, params, toks,
                                                 temperature=6.0))
    ent = lambda p: -(p * np.log(np.maximum(p, 1e-12))).sum(1)
    assert np.all(ent(p6) >= ent(p1) - 1e-5)


# -- paper_quality workloads: import path + smoke-size calls ---------------


def test_paper_quality_euclidean_smoke(paper_quality):
    res = paper_quality.euclidean_comparison(
        "uniform", n_witness=80, n_eval=40, m=24, k=8)
    for tr in ("zen", "pca", "rp", "mds"):
        assert 0.0 <= res[tr]["kruskal"] < 1.0
        assert np.isfinite(res[tr]["spearman"])


def test_paper_quality_jsd_smoke(paper_quality):
    res = paper_quality.jsd_comparison(n_eval=40, m=32, k=8)
    assert 0.0 <= res["zen"]["kruskal"] < 1.0
    # regression: the LMDS eigen blowup made sammon stress explode to ~1e7
    assert res["lmds"]["sammon"] < 100.0


def test_paper_quality_recall_smoke(paper_quality):
    res = paper_quality.recall_comparison(
        n_corpus=300, n_queries=5, m=32, k=8, n_nn=20)
    for name in ("zen", "pca", "rp"):
        assert 0.0 <= res[name] <= 1.0


def test_paper_quality_bounds_smoke(paper_quality):
    res = paper_quality.bounds_validation(n=60, m=32, k=8)
    assert res["lwb_violations"] == 0
    assert res["upb_violations"] == 0


def test_run_py_registers_quality_and_e2e_workloads():
    run = _load("benchmarks/run.py", "bench_run_under_test")
    for name in ("bounds", "euclidean", "jsd", "recall", "retrieval_e2e"):
        assert name in run._WORKLOADS
    e2e = _load("benchmarks/retrieval_e2e.py", "retrieval_e2e_under_test")
    assert callable(e2e.run_e2e)
    assert e2e.CURVE_KS_SMOKE == tuple(sorted(e2e.CURVE_KS_SMOKE))
    assert set(e2e.CURVE_KS_SMOKE) <= set(e2e.CURVE_KS)
