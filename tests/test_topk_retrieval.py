"""Streaming fused top-k retrieval: kernel/scan/dense parity (including
chunk-boundary and padded-tail shapes), estimator bound ordering, apex
projection parity with the paper oracle, sharded search, and the
bounded-memory guarantee. All paths run on CPU (interpret=True for Pallas)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.core import simplex as S
from repro.core import zen as Z
from repro.core.projection import NSimplexTransform
from repro.kernels import ops
from repro.kernels import zen_topk as zt


def _projected(seed, n, m, k):
    """Real apex coordinates: fit on random refs, project random objects."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, m))
    refs = rng.normal(size=(k, m))
    tr = NSimplexTransform(k=k).fit(jnp.asarray(refs, jnp.float32))
    return tr, jnp.asarray(tr.transform(jnp.asarray(X, jnp.float32)), jnp.float32)


def _rand_coords(seed, n, k):
    """Synthetic projected coords (non-negative altitude column)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, k)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    return jnp.asarray(X)


# -- kernel vs dense parity ----------------------------------------------------

SHAPES = [
    # (Q, N, k, n_neighbors, block_n): aligned, chunk-boundary, padded tail,
    # single-block, k=1 and k=N corner cases
    (8, 512, 16, 10, 128),    # N a multiple of the tile
    (5, 300, 17, 10, 128),    # padded tail (300 = 2*128 + 44)
    (3, 129, 8, 5, 128),      # one-row tail
    (9, 100, 12, 7, 128),     # N smaller than one tile
    (2, 257, 6, 1, 128),      # n_neighbors = 1
    (4, 96, 9, 96, 128),      # n_neighbors = N (full ranking)
]


@pytest.mark.parametrize("q,n,k,nn,bn", SHAPES)
@pytest.mark.parametrize("mode", ["zen", "lwb", "upb"])
def test_streaming_kernel_matches_dense(q, n, k, nn, bn, mode):
    rng = np.random.default_rng(q * 7 + n)
    Q = _rand_coords(q * 7 + n, q, k)
    X = _rand_coords(q * 7 + n + 1, n, k)
    want_d, want_i = Z._dense_topk(Q, X, nn, mode)
    got_d, got_i = zt.zen_topk(Q, X, nn, mode, block_n=bn, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-5, atol=1e-5
    )
    assert (np.asarray(got_i) == np.asarray(want_i)).all()


@pytest.mark.parametrize("q,n,k,nn,bn", SHAPES)
def test_streaming_scan_matches_dense(q, n, k, nn, bn):
    Q = _rand_coords(q + n, q, k)
    X = _rand_coords(q + n + 1, n, k)
    want_d, want_i = Z._dense_topk(Q, X, nn, "zen")
    got_d, got_i = zt.zen_topk_scan(Q, X, nn, "zen", chunk=bn)
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-5, atol=1e-5
    )
    assert (np.asarray(got_i) == np.asarray(want_i)).all()


def test_kernel_custom_query_blocks():
    Q = _rand_coords(0, 37, 11)  # ragged query count vs block_q
    X = _rand_coords(1, 400, 11)
    want_d, want_i = Z._dense_topk(Q, X, 9, "zen")
    got_d, got_i = zt.zen_topk(
        Q, X, 9, "zen", block_q=16, block_n=128, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-5, atol=1e-5
    )
    assert (np.asarray(got_i) == np.asarray(want_i)).all()


def test_knn_search_dispatch_modes_agree():
    tr, Xp = _projected(3, 400, 64, 12)
    Qp = Xp[:11]
    dense = Z.knn_search(Qp, Xp, n_neighbors=8)
    streamed = Z.knn_search(Qp, Xp, n_neighbors=8, chunk=128)
    kernel = Z.knn_search(Qp, Xp, n_neighbors=8, force_kernel=True)
    for got_d, got_i in (streamed, kernel):
        np.testing.assert_allclose(
            np.asarray(got_d), np.asarray(dense[0]), rtol=1e-5, atol=1e-5
        )
        assert (np.asarray(got_i) == np.asarray(dense[1])).all()


def test_ops_dispatch_cpu_scan_vs_interpret_kernel():
    Q = _rand_coords(5, 6, 10)
    X = _rand_coords(6, 350, 10)
    a = ops.zen_topk(Q, X, 12)                      # scan fallback on CPU
    b = ops.zen_topk(Q, X, 12, force_kernel=True)   # interpret-mode kernel
    np.testing.assert_allclose(
        np.asarray(a[0]), np.asarray(b[0]), rtol=1e-5, atol=1e-5
    )
    assert (np.asarray(a[1]) == np.asarray(b[1])).all()


# -- estimator bound ordering (paper Lemma C.2 over the streaming path) --------


def test_streaming_bound_ordering_on_projected_batch():
    """Full streaming ranking per mode, rebuilt as matrices: Lwb <= Zen <= Upb."""
    tr, Xp = _projected(11, 160, 48, 10)
    Qp = Xp[:13]
    n = Xp.shape[0]
    mats = {}
    for mode in ("lwb", "zen", "upb"):
        d, ids = zt.zen_topk(Qp, Xp, n, mode, block_n=128, interpret=True)
        mat = np.zeros((Qp.shape[0], n), np.float32)
        np.put_along_axis(mat, np.asarray(ids), np.asarray(d), axis=1)
        mats[mode] = mat
    tol = 1e-5
    assert (mats["lwb"] <= mats["zen"] + tol).all()
    assert (mats["zen"] <= mats["upb"] + tol).all()
    # and the true distance is bracketed (projection preserves ref distances)
    np.testing.assert_allclose(
        mats["zen"], np.asarray(Z.zen_pdist(Qp, Xp)), rtol=1e-4, atol=1e-4
    )


# -- apex projection parity with the paper-faithful oracle ---------------------


def test_apex_projection_parity_feeds_streaming_search():
    rng = np.random.default_rng(21)
    X = rng.normal(size=(50, 40))
    refs = rng.normal(size=(9, 40))
    D_refs = np.linalg.norm(refs[:, None] - refs[None, :], axis=-1)
    dists = np.linalg.norm(X[:, None] - refs[None, :], axis=-1)
    apex_oracle = S.apex_project_reference(D_refs, dists)

    tr = NSimplexTransform(k=9).fit(jnp.asarray(refs))
    Xp = np.asarray(tr.transform(jnp.asarray(X)))
    np.testing.assert_allclose(Xp, apex_oracle, atol=1e-4)

    # the oracle coordinates drive the streaming kernel to the same neighbours
    Qf = jnp.asarray(Xp[:5], jnp.float32)
    Xf = jnp.asarray(apex_oracle, jnp.float32)
    got_d, got_i = zt.zen_topk(Qf, Xf, 6, "zen", interpret=True)
    want_d, want_i = Z._dense_topk(Qf, Xf, 6, "zen")
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-5, atol=1e-5
    )
    assert (np.asarray(got_i) == np.asarray(want_i)).all()


# -- sharded search ------------------------------------------------------------


def test_sharded_search_single_device_mesh():
    from jax.sharding import Mesh

    from repro.distributed.retrieval import sharded_knn_search

    mesh = Mesh(np.array(jax.devices()[:1]), ("shard",))
    Q = _rand_coords(30, 7, 14)
    X = _rand_coords(31, 500, 14)
    want_d, want_i = Z._dense_topk(Q, X, 10, "zen")
    got_d, got_i = sharded_knn_search(Q, X, 10, "zen", mesh=mesh)
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=1e-5, atol=1e-5
    )
    assert (np.asarray(got_i) == np.asarray(want_i)).all()


_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import zen as Z
    from repro.distributed.retrieval import sharded_knn_search

    mesh = Mesh(np.array(jax.devices()[:4]), ("shard",))
    rng = np.random.default_rng(2)
    for n, shift in [(1000, 0.0), (1001, 0.0), (37, 0.0),
                     # pad rows sit at the origin: with the corpus far from it
                     # and queries near it, padding would win every local
                     # top-k slot unless masked/compensated correctly
                     (5, 100.0), (1001, 100.0)]:
        Q = jnp.asarray(rng.normal(size=(6, 12)), jnp.float32)
        X = jnp.asarray(shift + rng.normal(size=(n, 12)), jnp.float32)
        want_d, want_i = Z._dense_topk(Q, X, min(10, n), "zen")
        got_d, got_i = sharded_knn_search(Q, X, 10, "zen", mesh=mesh)
        assert np.allclose(np.asarray(got_d), np.asarray(want_d), atol=1e-4), n
        assert (np.asarray(got_i) == np.asarray(want_i)).all(), (n, shift)
    print("SHARDED_OK")
""")


def test_sharded_search_multi_device_merge():
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
    )
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED_OK" in r.stdout


# -- serving end-to-end over the kernel path -----------------------------------


def test_zen_server_force_kernel_matches_default():
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenServer, build_index

    key = jax.random.PRNGKey(5)
    corpus = syn.uniform_space(key, 2000, 64)
    index = build_index(corpus, 8)
    q = syn.uniform_space(jax.random.fold_in(key, 1), 5, 64)
    d0, i0 = ZenServer(index, chunk=256).query(q, 5)
    d1, i1 = ZenServer(index, chunk=256, force_kernel=True).query(q, 5)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-5,
                               atol=1e-5)
    assert (np.asarray(i0) == np.asarray(i1)).all()


# -- the memory bound itself ---------------------------------------------------


def test_streaming_memory_flat_in_index_size():
    """XLA temp allocation: dense grows ~linearly with N, streaming stays flat."""
    kdim, nn, chunk, q = 16, 10, 1024, 8

    def temp_bytes(fn, n):
        Q = jax.ShapeDtypeStruct((q, kdim), jnp.float32)
        X = jax.ShapeDtypeStruct((n, kdim), jnp.float32)
        mem = jax.jit(fn).lower(Q, X).compile().memory_analysis()
        return mem.temp_size_in_bytes

    dense = lambda Q, X: Z._dense_topk(Q, X, nn, "zen")
    stream = lambda Q, X: zt.zen_topk_scan(Q, X, nn, "zen", chunk=chunk)

    n_small, n_big = 16 * 1024, 128 * 1024
    dense_growth = temp_bytes(dense, n_big) / max(temp_bytes(dense, n_small), 1)
    stream_small = temp_bytes(stream, n_small)
    stream_big = temp_bytes(stream, n_big)
    assert dense_growth > 4, dense_growth  # ~8x for 8x the rows
    assert stream_big <= 2 * max(stream_small, 1), (stream_small, stream_big)
    # and the streaming path's live state is tile-sized, not index-sized
    assert stream_big < q * n_big * 4, stream_big
