"""Quantised index storage (bf16 / int8): parity vs fp32 across every
retrieval path, scale round-trips, and the checkpoint contract.

Protocol: ground truth is the exact f32 streaming scan over the same
estimator; a quantised path must land within 0.02 recall@10 of the f32 path
at matched settings (the ISSUE acceptance bar). bf16 round-trips of tiles
that are already bf16-representable must be *exact* — a plain cast cannot
lose bits it can represent. int8 per-row / per-cluster scales must survive
save -> load byte-for-byte.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.quality import recall_at_k
from repro.index import IVFZenIndex
from repro.kernels import ops
from repro.kernels import quantize as quant
from repro.kernels.zen_topk import zen_topk, zen_topk_scan

RECALL_BAR = 0.02  # quantised recall@10 within this of fp32, same settings


def _coords(seed, n, k):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, k)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    return jnp.asarray(X)


def _queries(seed, X, q, noise=0.25):
    rng = np.random.default_rng(seed)
    Q = np.asarray(X[:q]) + noise * rng.normal(size=(q, X.shape[1]))
    return jnp.asarray(Q.astype(np.float32))


# -- quantize module unit behaviour -------------------------------------------


def test_encode_rows_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 16)).astype(np.float32)
    vals, scales = quant.encode_rows(x, "int8")
    assert vals.dtype == np.int8 and scales.shape == (64, 1)
    back = quant.dequantize(vals, scales)
    # symmetric quantisation error is at most half a step per element
    assert np.abs(back - x).max() <= (scales / 2 + 1e-7).max()
    # the absmax element of every row pins +-127, so requantising the
    # dequantised values with fresh scales is lossless
    vals2, scales2 = quant.encode_rows(back, "int8")
    assert np.array_equal(vals, vals2)
    np.testing.assert_allclose(scales, scales2, rtol=1e-6)


def test_encode_rows_zero_and_sentinel_rows():
    x = np.zeros((3, 8), np.float32)
    x[1] = 1.0e15  # the flat dead-row sentinel
    vals, scales = quant.encode_rows(x, "int8")
    back = quant.dequantize(vals, scales)
    assert (back[0] == 0).all()  # all-zero row stays exactly zero
    np.testing.assert_allclose(back[1], 1.0e15, rtol=1e-6)


def test_cluster_scales_ignore_layout():
    rng = np.random.default_rng(1)
    coords = rng.normal(size=(200, 8)).astype(np.float32)
    assign = rng.integers(0, 5, size=200)
    s1 = quant.cluster_scales(coords, assign, 5)
    perm = rng.permutation(200)  # any member order gives the same scales
    s2 = quant.cluster_scales(coords[perm], assign[perm], 5)
    np.testing.assert_array_equal(s1, s2)


def test_check_storage_rejects_unknown():
    with pytest.raises(ValueError, match="storage"):
        quant.check_storage("float16")


# -- flat streaming scan + kernel ---------------------------------------------


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_flat_scan_recall_parity(storage):
    X = _coords(0, 2048, 16)
    Q = _queries(1, X, 16)
    truth = np.asarray(zen_topk_scan(Q, X, 10, "zen")[1])
    vals, scales = quant.encode_rows(np.asarray(X), storage)
    got = zen_topk_scan(
        Q, jnp.asarray(vals), 10, "zen",
        scales=None if scales is None else jnp.asarray(scales))[1]
    rec = recall_at_k(truth, np.asarray(got))
    assert rec >= 1.0 - RECALL_BAR, f"{storage}: recall {rec}"


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
@pytest.mark.parametrize("mode", ["zen", "lwb", "upb"])
def test_flat_kernel_matches_scan_quantized(storage, mode):
    """The Pallas kernel (interpret) and the fori_loop fallback must agree
    on the *same* quantised tiles — identical dequant, identical merge."""
    X = _coords(2, 700, 12)  # padded tail: 700 % 128 != 0
    Q = _queries(3, X, 9)
    vals, scales = quant.encode_rows(np.asarray(X), storage)
    vj = jnp.asarray(vals)
    sj = None if scales is None else jnp.asarray(scales)
    d0, i0 = zen_topk_scan(Q, vj, 7, mode, scales=sj)
    d1, i1 = zen_topk(Q, vj, 7, mode, scales=sj, interpret=True)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-5, atol=1e-5)


def test_ops_dispatch_passes_scales():
    X = _coords(4, 512, 8)
    Q = _queries(5, X, 4)
    vals, scales = quant.encode_rows(np.asarray(X), "int8")
    d0, i0 = ops.zen_topk(Q, jnp.asarray(vals), 5,
                          scales=jnp.asarray(scales))
    d1, i1 = ops.zen_topk(Q, jnp.asarray(vals), 5,
                          scales=jnp.asarray(scales), force_kernel=True)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-5, atol=1e-5)


# -- IVF probe ----------------------------------------------------------------


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_ivf_probe_recall_parity(storage):
    X = _coords(6, 4096, 16)
    Q = _queries(7, X, 16)
    truth = np.asarray(zen_topk_scan(Q, X, 10, "zen")[1])
    f32 = IVFZenIndex.build(X, 32, key=jax.random.PRNGKey(0))
    qidx = IVFZenIndex.build(X, 32, key=jax.random.PRNGKey(0),
                             storage=storage)
    assert str(qidx.tile_coords.dtype) == storage
    for nprobe in (4, 8):
        rec_f32 = recall_at_k(
            truth, np.asarray(f32.search(Q, 10, nprobe=nprobe)[1]))
        rec_q = recall_at_k(
            truth, np.asarray(qidx.search(Q, 10, nprobe=nprobe)[1]))
        assert abs(rec_f32 - rec_q) <= RECALL_BAR, (
            f"{storage} nprobe={nprobe}: {rec_q} vs f32 {rec_f32}")


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_ivf_kernel_matches_scan_quantized(storage):
    X = _coords(8, 1500, 12)
    Q = _queries(9, X, 6)
    qidx = IVFZenIndex.build(X, 12, key=jax.random.PRNGKey(1),
                             storage=storage)
    d0, i0 = qidx.search(Q, 8, nprobe=5)
    d1, i1 = qidx.search(Q, 8, nprobe=5, force_kernel=True)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-5, atol=1e-5)


def test_ivf_full_probe_int8_near_exact():
    """nprobe = C scans everything: int8 ids may only differ from f32 where
    the quantisation step flips a genuine near-tie."""
    X = _coords(10, 1024, 16)
    Q = _queries(11, X, 8)
    f32 = IVFZenIndex.build(X, 8, key=jax.random.PRNGKey(2))
    q8 = IVFZenIndex.build(X, 8, key=jax.random.PRNGKey(2), storage="int8")
    i0 = np.asarray(f32.search(Q, 10, nprobe=8)[1])
    i1 = np.asarray(q8.search(Q, 10, nprobe=8)[1])
    assert recall_at_k(i0, i1) >= 1.0 - RECALL_BAR


# -- bf16 exactness -----------------------------------------------------------


def test_bf16_exact_on_representable_tiles():
    """Tiles whose values are already bf16-representable lose nothing: the
    bf16 index returns bit-identical distances to the f32 index."""
    rng = np.random.default_rng(12)
    X = rng.normal(size=(1024, 12)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    X = X.astype(quant.np_dtype("bfloat16")).astype(np.float32)  # snap
    Xj = jnp.asarray(X)
    Q = _queries(13, Xj, 8)
    d0, i0 = zen_topk_scan(Q, Xj, 10, "zen")
    vals, _ = quant.encode_rows(X, "bfloat16")
    assert np.asarray(vals.astype(np.float32) == X).all()
    d1, i1 = zen_topk_scan(Q, jnp.asarray(vals), 10, "zen")
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))

    f32 = IVFZenIndex.build(Xj, 10, key=jax.random.PRNGKey(3))
    bf = IVFZenIndex.build(Xj, 10, key=jax.random.PRNGKey(3),
                           storage="bfloat16")
    d2, i2 = f32.search(Q, 10, nprobe=10)
    d3, i3 = bf.search(Q, 10, nprobe=10)
    assert (np.asarray(i2) == np.asarray(i3)).all()
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d3))


# -- persistence --------------------------------------------------------------


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_ivf_quantized_save_load_bit_identical(storage, tmp_path):
    X = _coords(14, 2000, 12)
    Q = _queries(15, X, 6)
    qidx = IVFZenIndex.build(X, 16, key=jax.random.PRNGKey(4),
                             storage=storage)
    d0, i0 = qidx.search(Q, 9, nprobe=6)
    path = qidx.save(str(tmp_path / "snap"))
    back = IVFZenIndex.load(path)
    assert back.storage == storage
    assert str(back.tile_coords.dtype) == storage
    if storage == "int8":
        np.testing.assert_array_equal(
            np.asarray(back.tile_scales), np.asarray(qidx.tile_scales))
    np.testing.assert_array_equal(
        np.asarray(back.tile_coords), np.asarray(qidx.tile_coords))
    d1, i1 = back.search(Q, 9, nprobe=6)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_int8_scales_survive_churn_and_reload(tmp_path):
    X = _coords(16, 1500, 10)
    qidx = IVFZenIndex.build(X, 12, key=jax.random.PRNGKey(5),
                             storage="int8")
    qidx = qidx.delete(np.arange(100))
    qidx = qidx.upsert(np.arange(1500, 1600), _coords(17, 100, 10))
    assert qidx.tile_scales is not None
    path = qidx.save(str(tmp_path / "snap"))
    back = IVFZenIndex.load(path)
    Q = _queries(18, X, 5)
    np.testing.assert_array_equal(
        np.asarray(qidx.search(Q, 8, nprobe=12)[1]),
        np.asarray(back.search(Q, 8, nprobe=12)[1]))


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_server_quantized_flat_roundtrip(storage, tmp_path):
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenServer, build_index

    key = jax.random.PRNGKey(0)
    # f32 corpus regardless of ambient x64: snapshots persist the fitted
    # references as f32, so an f64-fitted transform reloads at reduced
    # precision (a pre-existing format property, not a storage one)
    corpus = syn.manifold_space(key, 3000, 64, 8).astype(jnp.float32)
    index = build_index(corpus, 10, storage=storage)
    assert str(index.coords.dtype) == storage
    server = ZenServer(index, chunk=512)
    q = syn.manifold_space(
        jax.random.fold_in(key, 1), 8, 64, 8).astype(jnp.float32)
    server.upsert(np.arange(3000, 3040), corpus[:40])
    server.delete(np.arange(10))
    d0, i0 = server.query(q, 10)
    server.save(str(tmp_path / "srv"))
    back = ZenServer.load(str(tmp_path / "srv"), chunk=512)
    assert back.index.storage == storage
    d1, i1 = back.query(q, 10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_flat_compact_preserves_quantized_bytes():
    """Per-row scales ride with their rows: compaction is a pure slice,
    live rows keep their exact stored bytes."""
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenServer, build_index

    key = jax.random.PRNGKey(1)
    corpus = syn.manifold_space(key, 1000, 32, 4)
    server = ZenServer(build_index(corpus, 8, storage="int8"), chunk=256)
    server.delete(np.arange(300))
    vals_before = np.asarray(server.index.coords)
    ids_before = np.asarray(server.index.row_ids)
    server.compact()
    live = ids_before >= 0
    np.testing.assert_array_equal(
        np.asarray(server.index.coords), vals_before[live])
    q = syn.manifold_space(jax.random.fold_in(key, 2), 4, 32, 4)
    d, ids = server.query(q, 5)
    assert (np.asarray(ids) >= 300).all()


# -- sharded (4 host devices, subprocess) -------------------------------------

_SHARDED_QUANT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import tempfile
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.quality import recall_at_k
    from repro.distributed.retrieval import sharded_knn_search
    from repro.index import IVFZenIndex, ShardedIVFZenIndex
    from repro.kernels import quantize as quant
    from repro.kernels.zen_topk import zen_topk_scan

    mesh = Mesh(np.array(jax.devices()[:4]), ("shard",))
    rng = np.random.default_rng(2)
    X = rng.normal(size=(4000, 16)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    Xj = jnp.asarray(X)
    Q = jnp.asarray(
        (X[:8] + 0.25 * rng.normal(size=(8, 16))).astype(np.float32))
    truth = np.asarray(zen_topk_scan(Q, Xj, 10, "zen")[1])

    # flat: sharded int8 search == single-host int8 search, recall within bar
    vals, scales = quant.encode_rows(X, "int8")
    vj, sj = jnp.asarray(vals), jnp.asarray(scales)
    d0, i0 = zen_topk_scan(Q, vj, 10, "zen", scales=sj)
    d1, i1 = sharded_knn_search(Q, vj, 10, "zen", mesh=mesh, scales=sj)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    assert np.allclose(np.asarray(d0), np.asarray(d1), atol=1e-5)
    assert recall_at_k(truth, np.asarray(i1)) >= 0.98

    # IVF: int8 snapshot reloads onto 4 devices bit-identically, and the
    # sharded probe stays within the recall bar of sharded f32
    for storage in ("bfloat16", "int8"):
        qi = IVFZenIndex.build(Xj, 24, key=jax.random.PRNGKey(0),
                               storage=storage)
        want_d, want_i = qi.search(Q, 10, nprobe=8)
        with tempfile.TemporaryDirectory() as td:
            qi.save(td + "/snap")
            sidx = ShardedIVFZenIndex.load(td + "/snap", mesh=mesh)
            assert sidx.storage == storage, sidx.storage
            got_d, got_i = sidx.search(Q, 10, nprobe=8)
        assert (np.asarray(got_i) == np.asarray(want_i)).all(), storage
        assert np.allclose(np.asarray(got_d), np.asarray(want_d),
                           atol=1e-5), storage
        f32 = ShardedIVFZenIndex.build(Xj, 24, mesh=mesh,
                                       key=jax.random.PRNGKey(0))
        rec_f32 = recall_at_k(truth, np.asarray(
            f32.search(Q, 10, nprobe=8)[1]))
        rec_q = recall_at_k(truth, np.asarray(got_i))
        assert abs(rec_f32 - rec_q) <= 0.02, (storage, rec_f32, rec_q)
    print("SHARDED_QUANT_OK")
""")


@pytest.mark.slow
def test_sharded_quantized_multi_device():
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
    )
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_QUANT_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED_QUANT_OK" in r.stdout
