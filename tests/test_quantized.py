"""Quantised index storage (bf16 / int8): parity vs fp32 across every
retrieval path, scale round-trips, and the checkpoint contract.

Protocol: ground truth is the exact f32 streaming scan over the same
estimator; a quantised path must land within 0.02 recall@10 of the f32 path
at matched settings (the ISSUE acceptance bar). bf16 round-trips of tiles
that are already bf16-representable must be *exact* — a plain cast cannot
lose bits it can represent. int8 per-row / per-cluster scales must survive
save -> load byte-for-byte.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.quality import recall_at_k
from repro.index import IVFZenIndex
from repro.kernels import ops
from repro.kernels import pq as pq_lib
from repro.kernels import quantize as quant
from repro.kernels.zen_topk import zen_topk, zen_topk_scan

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fixed-seed replay fallback (tests/_hypothesis_fallback)
    from _hypothesis_fallback import given, settings, st

RECALL_BAR = 0.02  # quantised recall@10 within this of fp32, same settings


def _coords(seed, n, k):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, k)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    return jnp.asarray(X)


def _queries(seed, X, q, noise=0.25):
    rng = np.random.default_rng(seed)
    Q = np.asarray(X[:q]) + noise * rng.normal(size=(q, X.shape[1]))
    return jnp.asarray(Q.astype(np.float32))


# -- quantize module unit behaviour -------------------------------------------


def test_encode_rows_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 16)).astype(np.float32)
    vals, scales = quant.encode_rows(x, "int8")
    assert vals.dtype == np.int8 and scales.shape == (64, 1)
    back = quant.dequantize(vals, scales)
    # symmetric quantisation error is at most half a step per element
    assert np.abs(back - x).max() <= (scales / 2 + 1e-7).max()
    # the absmax element of every row pins +-127, so requantising the
    # dequantised values with fresh scales is lossless
    vals2, scales2 = quant.encode_rows(back, "int8")
    assert np.array_equal(vals, vals2)
    np.testing.assert_allclose(scales, scales2, rtol=1e-6)


def test_encode_rows_zero_and_sentinel_rows():
    x = np.zeros((3, 8), np.float32)
    x[1] = 1.0e15  # the flat dead-row sentinel
    vals, scales = quant.encode_rows(x, "int8")
    back = quant.dequantize(vals, scales)
    assert (back[0] == 0).all()  # all-zero row stays exactly zero
    np.testing.assert_allclose(back[1], 1.0e15, rtol=1e-6)


def test_cluster_scales_ignore_layout():
    rng = np.random.default_rng(1)
    coords = rng.normal(size=(200, 8)).astype(np.float32)
    assign = rng.integers(0, 5, size=200)
    s1 = quant.cluster_scales(coords, assign, 5)
    perm = rng.permutation(200)  # any member order gives the same scales
    s2 = quant.cluster_scales(coords[perm], assign[perm], 5)
    np.testing.assert_array_equal(s1, s2)


def test_check_storage_rejects_unknown():
    with pytest.raises(ValueError, match="storage"):
        quant.check_storage("float16")


# -- flat streaming scan + kernel ---------------------------------------------


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_flat_scan_recall_parity(storage):
    X = _coords(0, 2048, 16)
    Q = _queries(1, X, 16)
    truth = np.asarray(zen_topk_scan(Q, X, 10, "zen")[1])
    vals, scales = quant.encode_rows(np.asarray(X), storage)
    got = zen_topk_scan(
        Q, jnp.asarray(vals), 10, "zen",
        scales=None if scales is None else jnp.asarray(scales))[1]
    rec = recall_at_k(truth, np.asarray(got))
    assert rec >= 1.0 - RECALL_BAR, f"{storage}: recall {rec}"


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
@pytest.mark.parametrize("mode", ["zen", "lwb", "upb"])
def test_flat_kernel_matches_scan_quantized(storage, mode):
    """The Pallas kernel (interpret) and the fori_loop fallback must agree
    on the *same* quantised tiles — identical dequant, identical merge."""
    X = _coords(2, 700, 12)  # padded tail: 700 % 128 != 0
    Q = _queries(3, X, 9)
    vals, scales = quant.encode_rows(np.asarray(X), storage)
    vj = jnp.asarray(vals)
    sj = None if scales is None else jnp.asarray(scales)
    d0, i0 = zen_topk_scan(Q, vj, 7, mode, scales=sj)
    d1, i1 = zen_topk(Q, vj, 7, mode, scales=sj, interpret=True)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-5, atol=1e-5)


def test_ops_dispatch_passes_scales():
    X = _coords(4, 512, 8)
    Q = _queries(5, X, 4)
    vals, scales = quant.encode_rows(np.asarray(X), "int8")
    d0, i0 = ops.zen_topk(Q, jnp.asarray(vals), 5,
                          scales=jnp.asarray(scales))
    d1, i1 = ops.zen_topk(Q, jnp.asarray(vals), 5,
                          scales=jnp.asarray(scales), force_kernel=True)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-5, atol=1e-5)


# -- IVF probe ----------------------------------------------------------------


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_ivf_probe_recall_parity(storage):
    X = _coords(6, 4096, 16)
    Q = _queries(7, X, 16)
    truth = np.asarray(zen_topk_scan(Q, X, 10, "zen")[1])
    f32 = IVFZenIndex.build(X, 32, key=jax.random.PRNGKey(0))
    qidx = IVFZenIndex.build(X, 32, key=jax.random.PRNGKey(0),
                             storage=storage)
    assert str(qidx.tile_coords.dtype) == storage
    for nprobe in (4, 8):
        rec_f32 = recall_at_k(
            truth, np.asarray(f32.search(Q, 10, nprobe=nprobe)[1]))
        rec_q = recall_at_k(
            truth, np.asarray(qidx.search(Q, 10, nprobe=nprobe)[1]))
        assert abs(rec_f32 - rec_q) <= RECALL_BAR, (
            f"{storage} nprobe={nprobe}: {rec_q} vs f32 {rec_f32}")


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_ivf_kernel_matches_scan_quantized(storage):
    X = _coords(8, 1500, 12)
    Q = _queries(9, X, 6)
    qidx = IVFZenIndex.build(X, 12, key=jax.random.PRNGKey(1),
                             storage=storage)
    d0, i0 = qidx.search(Q, 8, nprobe=5)
    d1, i1 = qidx.search(Q, 8, nprobe=5, force_kernel=True)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-5, atol=1e-5)


def test_ivf_full_probe_int8_near_exact():
    """nprobe = C scans everything: int8 ids may only differ from f32 where
    the quantisation step flips a genuine near-tie."""
    X = _coords(10, 1024, 16)
    Q = _queries(11, X, 8)
    f32 = IVFZenIndex.build(X, 8, key=jax.random.PRNGKey(2))
    q8 = IVFZenIndex.build(X, 8, key=jax.random.PRNGKey(2), storage="int8")
    i0 = np.asarray(f32.search(Q, 10, nprobe=8)[1])
    i1 = np.asarray(q8.search(Q, 10, nprobe=8)[1])
    assert recall_at_k(i0, i1) >= 1.0 - RECALL_BAR


# -- bf16 exactness -----------------------------------------------------------


def test_bf16_exact_on_representable_tiles():
    """Tiles whose values are already bf16-representable lose nothing: the
    bf16 index returns bit-identical distances to the f32 index."""
    rng = np.random.default_rng(12)
    X = rng.normal(size=(1024, 12)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    X = X.astype(quant.np_dtype("bfloat16")).astype(np.float32)  # snap
    Xj = jnp.asarray(X)
    Q = _queries(13, Xj, 8)
    d0, i0 = zen_topk_scan(Q, Xj, 10, "zen")
    vals, _ = quant.encode_rows(X, "bfloat16")
    assert np.asarray(vals.astype(np.float32) == X).all()
    d1, i1 = zen_topk_scan(Q, jnp.asarray(vals), 10, "zen")
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))

    f32 = IVFZenIndex.build(Xj, 10, key=jax.random.PRNGKey(3))
    bf = IVFZenIndex.build(Xj, 10, key=jax.random.PRNGKey(3),
                           storage="bfloat16")
    d2, i2 = f32.search(Q, 10, nprobe=10)
    d3, i3 = bf.search(Q, 10, nprobe=10)
    assert (np.asarray(i2) == np.asarray(i3)).all()
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d3))


# -- persistence --------------------------------------------------------------


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_ivf_quantized_save_load_bit_identical(storage, tmp_path):
    X = _coords(14, 2000, 12)
    Q = _queries(15, X, 6)
    qidx = IVFZenIndex.build(X, 16, key=jax.random.PRNGKey(4),
                             storage=storage)
    d0, i0 = qidx.search(Q, 9, nprobe=6)
    path = qidx.save(str(tmp_path / "snap"))
    back = IVFZenIndex.load(path)
    assert back.storage == storage
    assert str(back.tile_coords.dtype) == storage
    if storage == "int8":
        np.testing.assert_array_equal(
            np.asarray(back.tile_scales), np.asarray(qidx.tile_scales))
    np.testing.assert_array_equal(
        np.asarray(back.tile_coords), np.asarray(qidx.tile_coords))
    d1, i1 = back.search(Q, 9, nprobe=6)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_int8_scales_survive_churn_and_reload(tmp_path):
    X = _coords(16, 1500, 10)
    qidx = IVFZenIndex.build(X, 12, key=jax.random.PRNGKey(5),
                             storage="int8")
    qidx = qidx.delete(np.arange(100))
    qidx = qidx.upsert(np.arange(1500, 1600), _coords(17, 100, 10))
    assert qidx.tile_scales is not None
    path = qidx.save(str(tmp_path / "snap"))
    back = IVFZenIndex.load(path)
    Q = _queries(18, X, 5)
    np.testing.assert_array_equal(
        np.asarray(qidx.search(Q, 8, nprobe=12)[1]),
        np.asarray(back.search(Q, 8, nprobe=12)[1]))


@pytest.mark.parametrize("storage", ["bfloat16", "int8"])
def test_server_quantized_flat_roundtrip(storage, tmp_path):
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenServer, build_index

    key = jax.random.PRNGKey(0)
    # f32 corpus regardless of ambient x64: snapshots persist the fitted
    # references as f32, so an f64-fitted transform reloads at reduced
    # precision (a pre-existing format property, not a storage one)
    corpus = syn.manifold_space(key, 3000, 64, 8).astype(jnp.float32)
    index = build_index(corpus, 10, storage=storage)
    assert str(index.coords.dtype) == storage
    server = ZenServer(index, chunk=512)
    q = syn.manifold_space(
        jax.random.fold_in(key, 1), 8, 64, 8).astype(jnp.float32)
    server.upsert(np.arange(3000, 3040), corpus[:40])
    server.delete(np.arange(10))
    d0, i0 = server.query(q, 10)
    server.save(str(tmp_path / "srv"))
    back = ZenServer.load(str(tmp_path / "srv"), chunk=512)
    assert back.index.storage == storage
    d1, i1 = back.query(q, 10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_flat_compact_preserves_quantized_bytes():
    """Per-row scales ride with their rows: compaction is a pure slice,
    live rows keep their exact stored bytes."""
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenServer, build_index

    key = jax.random.PRNGKey(1)
    corpus = syn.manifold_space(key, 1000, 32, 4)
    server = ZenServer(build_index(corpus, 8, storage="int8"), chunk=256)
    server.delete(np.arange(300))
    vals_before = np.asarray(server.index.coords)
    ids_before = np.asarray(server.index.row_ids)
    server.compact()
    live = ids_before >= 0
    np.testing.assert_array_equal(
        np.asarray(server.index.coords), vals_before[live])
    q = syn.manifold_space(jax.random.fold_in(key, 2), 4, 32, 4)
    d, ids = server.query(q, 5)
    assert (np.asarray(ids) >= 300).all()


# -- PQ codec properties (hypothesis; fixed-seed replay without it) -----------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10**6), m=st.integers(1, 4))
def test_pq_encode_nearest_entry_and_error_decomposition(seed, m):
    """encode() snaps every subspace to its *nearest* codebook entry, and
    the reconstruction error is bounded by (equals, when M | k) the sum of
    the chosen per-subspace distortions — the ADC invariant that makes
    LUT scores exact on the decoded coordinates."""
    rng = np.random.default_rng(seed)
    k = 8
    resid = rng.normal(size=(40, k)).astype(np.float32)
    ds = pq_lib.subspace_dims(k, m)
    books = rng.normal(size=(m, pq_lib.PQ_ENTRIES, ds)).astype(np.float32)
    codes = pq_lib.encode(resid, books)
    assert codes.dtype == np.uint8 and codes.shape == (40, m)
    sub = pq_lib.split_subspaces(resid, m)  # (n, M, ds)
    d2 = ((sub[:, :, None, :] - books[None]) ** 2).sum(-1)  # (n, M, E)
    chosen = np.take_along_axis(
        d2, codes[..., None].astype(np.int64), 2)[..., 0]
    np.testing.assert_allclose(chosen, d2.min(axis=2), rtol=1e-4, atol=1e-5)
    recon = pq_lib.decode(codes, books, k)
    err = ((recon - resid) ** 2).sum(1)
    distortion = chosen.sum(1)
    assert (err <= distortion + 1e-4).all()
    if k % m == 0:  # no padded columns: the decomposition is exact
        np.testing.assert_allclose(err, distortion, rtol=1e-4, atol=1e-5)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_pq_codebook_training_deterministic(seed):
    """Same key, same residuals -> byte-identical codebooks and codes."""
    rng = np.random.default_rng(seed)
    resid = rng.normal(size=(150, 8)).astype(np.float32)
    key = jax.random.PRNGKey(seed)
    b1 = pq_lib.train_codebooks(resid, 2, key=key, n_iters=3)
    b2 = pq_lib.train_codebooks(resid, 2, key=key, n_iters=3)
    np.testing.assert_array_equal(b1, b2)
    np.testing.assert_array_equal(
        pq_lib.encode(resid, b1), pq_lib.encode(resid, b2))


def test_pq_small_corpus_duplicate_entries_never_win():
    """Fewer rows than 256 entries: the trailing codebook entries repeat
    entry 0, and an exact duplicate can never beat the first occurrence."""
    rng = np.random.default_rng(5)
    resid = rng.normal(size=(20, 8)).astype(np.float32)
    books = pq_lib.train_codebooks(resid, 2, key=jax.random.PRNGKey(0),
                                   n_iters=3)
    np.testing.assert_array_equal(
        books[:, 20:], np.broadcast_to(books[:, :1], books[:, 20:].shape))
    assert pq_lib.encode(resid, books).max() < 20


def test_pq_padded_width_contributes_zero():
    """M not dividing k: residual padding columns are zero, codebooks train
    to exactly zero there, and decode truncates them away losslessly."""
    rng = np.random.default_rng(6)
    resid = rng.normal(size=(300, 7)).astype(np.float32)  # M=3 -> ds=3, pad 2
    books = pq_lib.train_codebooks(resid, 3, key=jax.random.PRNGKey(1),
                                   n_iters=4)
    assert books.shape == (3, 256, 3)
    assert (books[2, :, -1] == 0.0).all() and (books[2, :, -2] == 0.0).all()
    codes = pq_lib.encode(resid, books)
    assert pq_lib.decode(codes, books, 7).shape == (300, 7)


def _member_codes(idx):
    """id -> uint8 code row for every live member of a PQ index."""
    codes = np.asarray(idx.tile_coords).reshape(-1, idx.tile_coords.shape[-1])
    ids = np.asarray(idx.tile_ids).ravel()
    return {int(i): codes[s].tobytes()
            for s, i in enumerate(ids) if i >= 0}


def test_pq_tombstone_codes_roundtrip(tmp_path):
    """delete() under storage="pq" rewrites only the id slots (the -1
    sentinel the probe masks), never the stored code bytes; a pristine
    snapshot round-trips codes/ids/codebooks byte-for-byte, and a
    post-delete snapshot (an implicit repack) still carries every live
    member's exact code bytes — deleted rows never surface again."""
    X = _coords(30, 900, 12)
    idx = IVFZenIndex.build(X, 10, key=jax.random.PRNGKey(7), storage="pq")
    assert idx.tile_coords.dtype == jnp.uint8
    back0 = IVFZenIndex.load(idx.save(str(tmp_path / "snap0")))
    assert back0.storage == "pq" and back0.tile_coords.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(back0.tile_coords),
                                  np.asarray(idx.tile_coords))
    np.testing.assert_array_equal(np.asarray(back0.tile_ids),
                                  np.asarray(idx.tile_ids))
    np.testing.assert_array_equal(np.asarray(back0.codebooks),
                                  np.asarray(idx.codebooks))

    codes_before = np.asarray(idx.tile_coords)
    idx2 = idx.delete(np.arange(50))
    np.testing.assert_array_equal(np.asarray(idx2.tile_coords), codes_before)
    assert (~np.isin(np.asarray(idx2.tile_ids), np.arange(50))).all()
    Q = _queries(31, X, 8)
    d0, i0 = idx2.search(Q, 10, nprobe=10)
    assert not np.isin(np.asarray(i0), np.arange(50)).any()

    back = IVFZenIndex.load(idx2.save(str(tmp_path / "snap")))
    assert back.n_valid == 850
    assert _member_codes(back) == _member_codes(idx2)
    np.testing.assert_array_equal(np.asarray(back.codebooks),
                                  np.asarray(idx2.codebooks))
    d1, i1 = back.search(Q, 10, nprobe=10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_pq_from_members_requires_codebooks():
    codes = np.zeros((10, 4), np.uint8)
    cents = np.zeros((2, 16), np.float32)
    with pytest.raises(ValueError, match="codebooks"):
        IVFZenIndex.from_members(
            codes, np.arange(10), np.zeros(10, np.int64), cents, 2, 128,
            storage="pq")


_PQ_DEVICE_COUNT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import hashlib
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.index import IVFZenIndex

    rng = np.random.default_rng(33)
    X = rng.normal(size=(800, 8)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    idx = IVFZenIndex.build(jnp.asarray(X), 8, key=jax.random.PRNGKey(9),
                            storage="pq", pq_m=2)
    for name, arr in (("codes", idx.tile_coords), ("ids", idx.tile_ids),
                      ("books", idx.codebooks)):
        print(name, hashlib.sha256(np.asarray(arr).tobytes()).hexdigest())
""")


def test_pq_snapshot_bytes_identical_across_device_counts():
    """Codes depend only on the global k-means assignment (residuals are
    taken against the *assigned* centroid), so the same corpus + key builds
    byte-identical PQ tiles whether 1 or 4 devices are visible — the same
    invariant the int8 cluster scales already carry."""
    rng = np.random.default_rng(33)
    X = rng.normal(size=(800, 8)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    # pin x32: the subprocess runs at the default precision, and an earlier
    # test module may have flipped the global x64 switch in this process
    prev_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    try:
        idx = IVFZenIndex.build(jnp.asarray(X), 8, key=jax.random.PRNGKey(9),
                                storage="pq", pq_m=2)
    finally:
        jax.config.update("jax_enable_x64", prev_x64)
    import hashlib
    want = {
        "codes": hashlib.sha256(
            np.asarray(idx.tile_coords).tobytes()).hexdigest(),
        "ids": hashlib.sha256(
            np.asarray(idx.tile_ids).tobytes()).hexdigest(),
        "books": hashlib.sha256(
            np.asarray(idx.codebooks).tobytes()).hexdigest(),
    }
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
    )
    r = subprocess.run(
        [sys.executable, "-c", _PQ_DEVICE_COUNT_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    got = dict(line.split() for line in r.stdout.strip().splitlines())
    assert got == want


# -- the storage menu is owned by one tuple ------------------------------------


def test_storage_menu_registry():
    assert quant.STORAGE_DTYPES == quant.SCALAR_STORAGE_DTYPES + ("pq",)
    assert quant.np_dtype("pq") == np.uint8
    with pytest.raises(ValueError, match="IVF-only"):
        quant.encode_rows(np.zeros((2, 4), np.float32), "pq")
    for name in quant.STORAGE_DTYPES:
        assert name in quant.storage_help()


def test_cli_help_lists_every_storage_and_pivot_choice():
    """The serve CLI's --help is generated from STORAGE_DTYPES /
    PIVOT_STRATEGIES — grep the actual help text so a menu addition that
    skips the CLI (or vice versa) fails here."""
    from repro.core.pivots import PIVOT_STRATEGIES

    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
    )
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--help"],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    for name in quant.STORAGE_DTYPES:
        assert name in r.stdout, f"--storage menu missing {name!r}"
    for name in PIVOT_STRATEGIES:
        assert name in r.stdout, f"--pivots menu missing {name!r}"
    assert "--pq-m" in r.stdout


# -- sharded (4 host devices, subprocess) -------------------------------------

_SHARDED_QUANT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import tempfile
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.quality import recall_at_k
    from repro.distributed.retrieval import sharded_knn_search
    from repro.index import IVFZenIndex, ShardedIVFZenIndex
    from repro.kernels import quantize as quant
    from repro.kernels.zen_topk import zen_topk_scan

    mesh = Mesh(np.array(jax.devices()[:4]), ("shard",))
    rng = np.random.default_rng(2)
    X = rng.normal(size=(4000, 16)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    Xj = jnp.asarray(X)
    Q = jnp.asarray(
        (X[:8] + 0.25 * rng.normal(size=(8, 16))).astype(np.float32))
    truth = np.asarray(zen_topk_scan(Q, Xj, 10, "zen")[1])

    # flat: sharded int8 search == single-host int8 search, recall within bar
    vals, scales = quant.encode_rows(X, "int8")
    vj, sj = jnp.asarray(vals), jnp.asarray(scales)
    d0, i0 = zen_topk_scan(Q, vj, 10, "zen", scales=sj)
    d1, i1 = sharded_knn_search(Q, vj, 10, "zen", mesh=mesh, scales=sj)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    assert np.allclose(np.asarray(d0), np.asarray(d1), atol=1e-5)
    assert recall_at_k(truth, np.asarray(i1)) >= 0.98

    # IVF: int8 snapshot reloads onto 4 devices bit-identically, and the
    # sharded probe stays within the recall bar of sharded f32
    for storage in ("bfloat16", "int8"):
        qi = IVFZenIndex.build(Xj, 24, key=jax.random.PRNGKey(0),
                               storage=storage)
        want_d, want_i = qi.search(Q, 10, nprobe=8)
        with tempfile.TemporaryDirectory() as td:
            qi.save(td + "/snap")
            sidx = ShardedIVFZenIndex.load(td + "/snap", mesh=mesh)
            assert sidx.storage == storage, sidx.storage
            got_d, got_i = sidx.search(Q, 10, nprobe=8)
        assert (np.asarray(got_i) == np.asarray(want_i)).all(), storage
        assert np.allclose(np.asarray(got_d), np.asarray(want_d),
                           atol=1e-5), storage
        f32 = ShardedIVFZenIndex.build(Xj, 24, mesh=mesh,
                                       key=jax.random.PRNGKey(0))
        rec_f32 = recall_at_k(truth, np.asarray(
            f32.search(Q, 10, nprobe=8)[1]))
        rec_q = recall_at_k(truth, np.asarray(got_i))
        assert abs(rec_f32 - rec_q) <= 0.02, (storage, rec_f32, rec_q)
    print("SHARDED_QUANT_OK")
""")


@pytest.mark.slow
def test_sharded_quantized_multi_device():
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
    )
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_QUANT_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED_QUANT_OK" in r.stdout
