"""Property-based metric-law suite over the whole ``core/metrics`` registry.

The entire Zen pipeline rests on one assumption: every registry metric is a
*metric* on a Hilbert-embeddable space (paper Appendix A) — otherwise the
base simplex construction, the apex projection and the Lwb <= d <= Upb
bounds are meaningless. These properties are checked here for every
registered metric over randomly sampled point sets:

  * non-negativity        d(x, y) >= 0
  * identity              d(x, x) == 0
  * symmetry              d(x, y) == d(y, x)
  * triangle inequality   d(x, z) <= d(x, y) + d(y, z), all sampled triples

``sqeuclidean`` is registered as a convenience kernel, not a metric (it
famously violates the triangle inequality); the registry's
``hilbert_embeddable`` flag gates the triangle check, and a companion test
pins the violation down so the flag can never silently rot.

Runs under real ``hypothesis`` when installed, else the fixed-seed replay
fallback (``tests/_hypothesis_fallback``).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fixed-seed replay keeps the suite green
    from _hypothesis_fallback import given, settings, st

from repro.core import metrics as M

jax.config.update("jax_enable_x64", True)

#: every name the registry exposes — new metrics are covered automatically
ALL_METRICS = sorted(M._REGISTRY)

#: names whose pairwise fn satisfies the triangle inequality (true metrics)
TRUE_METRICS = [n for n in ALL_METRICS if M.get_metric(n).hilbert_embeddable]


def _sample_points(name: str, seed: int, n: int, m: int) -> jnp.ndarray:
    """Points in the metric's natural domain (f64 for tight tolerances)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, m))
    if M.get_metric(name).normalize is M.l1_normalize:
        # probability-simplex metrics: non-negative with a few exact zeros,
        # exercising the 0 log 0 / 0-over-0 conventions; every row keeps at
        # least one positive mass (the all-zero vector is out of domain)
        X = np.abs(X)
        X[rng.random(X.shape) < 0.1] = 0.0
        X[np.arange(n), rng.integers(0, m, size=n)] = 1.0
    return jnp.asarray(X, jnp.float64)


def _pairwise(name: str, X: jnp.ndarray) -> np.ndarray:
    return np.asarray(M.self_pairwise(name, X), np.float64)


@pytest.mark.parametrize("name", ALL_METRICS)
def test_non_negativity_and_identity(name):
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 24),
           m=st.integers(2, 48))
    def prop(seed, n, m):
        D = _pairwise(name, _sample_points(name, seed, n, m))
        assert np.isfinite(D).all(), f"{name}: non-finite distances"
        assert (D >= 0.0).all(), f"{name}: negative distance {D.min()}"
        assert np.abs(np.diag(D)).max() < 1e-7, (
            f"{name}: d(x, x) = {np.abs(np.diag(D)).max()}")

    prop()


@pytest.mark.parametrize("name", ALL_METRICS)
def test_symmetry(name):
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 24),
           m=st.integers(2, 48))
    def prop(seed, n, m):
        D = _pairwise(name, _sample_points(name, seed, n, m))
        assert np.abs(D - D.T).max() < 1e-9, (
            f"{name}: asymmetry {np.abs(D - D.T).max()}")

    prop()


@pytest.mark.parametrize("name", TRUE_METRICS)
def test_triangle_inequality(name):
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(3, 20),
           m=st.integers(2, 48))
    def prop(seed, n, m):
        D = _pairwise(name, _sample_points(name, seed, n, m))
        # all (i, j, k) triples at once: D[i, k] <= D[i, j] + D[j, k]
        lhs = D[:, None, :]                      # (i, 1, k)
        rhs = D[:, :, None] + D[None, :, :]      # (i, j) + (j, k)
        slack = (lhs - rhs).max()
        tol = 1e-9 * max(1.0, float(D.max()))
        assert slack <= tol, (
            f"{name}: triangle violated by {slack} (tol {tol})")

    prop()


def test_sqeuclidean_is_flagged_non_metric():
    """The registry's one non-metric really does break the triangle law —
    if this stops failing, the ``hilbert_embeddable`` gate above is stale."""
    m = M.get_metric("sqeuclidean")
    assert not m.hilbert_embeddable
    X = jnp.asarray([[0.0], [1.0], [2.0]], jnp.float64)  # collinear
    D = np.asarray(m.pdist(X, X))
    # d(0, 2) = 4 > d(0, 1) + d(1, 2) = 2
    assert D[0, 2] > D[0, 1] + D[1, 2]


@pytest.mark.parametrize("name", TRUE_METRICS)
def test_distinct_points_have_positive_distance(name):
    """d(x, y) > 0 for clearly distinct points (no metric collapses)."""
    X = _sample_points(name, 7, 12, 16)
    D = _pairwise(name, X)
    off = D.copy()
    np.fill_diagonal(off, np.inf)
    assert off.min() > 0.0, f"{name}: distinct points at distance 0"


def test_qform_matches_cholesky_euclidean():
    """The registry qform metric is Euclidean after the chol(M) transform —
    the constructive proof of its Hilbert embeddability."""
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(20, 12)), jnp.float64)
    Mmat = M.default_qform_matrix(12).astype(jnp.float64)
    L = np.linalg.cholesky(np.asarray(Mmat))
    want = np.asarray(M.euclidean_pdist(X @ L, X @ L))
    got = np.asarray(M.self_pairwise("qform", X))
    # sqrt amplifies the d^2 cancellation noise of either formula to
    # ~sqrt(eps * ||x||^2) — compare at that scale, not machine eps
    np.testing.assert_allclose(got, want, atol=1e-6)
