"""Per-architecture smoke tests: REDUCED same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness (no NaNs).
The FULL configs are exercised only via the dry-run (ShapeDtypeStructs)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.data import synthetic as syn
from repro.models import mace as mace_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.optim import AdamW

LM_ARCHS = [
    "qwen2-moe-a2.7b", "granite-moe-3b-a800m", "qwen1.5-0.5b",
    "gemma2-2b", "granite-8b",
]
RECSYS_ARCHS = ["autoint", "wide-deep", "dlrm-rm2", "xdeepfm"]


def _one_train_step(loss_fn, params):
    opt = AdamW(learning_rate=1e-3)
    state = opt.init(params)
    (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    updates, state = opt.update(grads, state, params)
    new_params = jax.tree.map(lambda p, u: p + u, params, updates)
    return float(loss), new_params


def _all_finite(tree) -> bool:
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    spec = C.get_arch(arch)
    cfg = spec.make_reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = syn.lm_batch(0, 0, B, S, cfg.vocab_size)

    logits = tfm.forward(cfg, params, batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    loss, new_params = _one_train_step(
        lambda p: tfm.loss_fn(cfg, p, batch), params)
    assert np.isfinite(loss)
    assert _all_finite(new_params)

    # serving path: prefill + one decode step
    lg, cache = tfm.prefill(cfg, params, batch["tokens"][:, :-1], pad_to=S + 4)
    assert lg.shape == (B, cfg.vocab_size)
    lg2, cache2 = tfm.decode_step(
        cfg, params, cache, batch["tokens"][:, -1:], jnp.int32(S - 1))
    assert lg2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg2).all())


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    spec = C.get_arch(arch)
    cfg = spec.make_reduced()
    params = recsys_lib.init_params(cfg, jax.random.PRNGKey(0))
    B = 32
    batch = syn.recsys_batch(0, 0, B, cfg.vocab_sizes, cfg.n_dense)

    logits = recsys_lib.forward(cfg, params, batch)
    assert logits.shape == (B,)
    assert bool(jnp.isfinite(logits).all())

    loss, new_params = _one_train_step(
        lambda p: recsys_lib.loss_fn(cfg, p, batch), params)
    assert np.isfinite(loss)
    assert _all_finite(new_params)

    # retrieval head
    q = recsys_lib.user_repr(cfg, params, batch)
    cands = jax.random.normal(jax.random.PRNGKey(1), (500, cfg.embed_dim))
    scores, ids = recsys_lib.retrieval_topk(q, cands, k=7)
    assert scores.shape == (B, 7) and ids.shape == (B, 7)
    assert bool((ids >= 0).all()) and bool((ids < 500).all())


def test_gnn_smoke():
    spec = C.get_arch("mace")
    cfg = spec.make_reduced()
    params = mace_lib.init_params(cfg, jax.random.PRNGKey(0))
    batch = syn.geometric_graph_batch(0, n_nodes=60, n_edges=180,
                                      d_feat=cfg.d_feat, n_graphs=4)
    batch["n_graphs"] = 4

    energies = mace_lib.forward(cfg, params, batch)
    assert energies.shape == (4,)
    assert bool(jnp.isfinite(energies).all())

    loss, new_params = _one_train_step(
        lambda p: mace_lib.loss_fn(cfg, p, batch), params)
    assert np.isfinite(loss)
    assert _all_finite(new_params)


def test_gnn_smoke_node_level():
    spec = C.get_arch("mace")
    cfg = spec.make_reduced()
    params = mace_lib.init_params(cfg, jax.random.PRNGKey(0))
    batch = syn.geometric_graph_batch(1, n_nodes=50, n_edges=140,
                                      d_feat=cfg.d_feat, node_level=True)
    batch["n_graphs"] = 1
    batch["node_level"] = True
    loss, _ = mace_lib.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


def test_registry_covers_grid():
    cells = C.all_cells()
    assert len(cells) == 40, len(cells)
    # mandated skips: long_500k for the four pure full-attention LMs
    skipped = [
        (a, s) for a, s in cells if C.get_arch(a).cell(s).skip is not None
    ]
    assert sorted(skipped) == [
        ("granite-8b", "long_500k"),
        ("granite-moe-3b-a800m", "long_500k"),
        ("qwen1.5-0.5b", "long_500k"),
        ("qwen2-moe-a2.7b", "long_500k"),
    ]


@pytest.mark.parametrize("arch", LM_ARCHS + RECSYS_ARCHS + ["mace"])
def test_full_config_instantiates_abstractly(arch):
    """Full published configs build abstract params without allocation."""
    spec = C.get_arch(arch)
    cfg = spec.make_config()
    if spec.family == "lm":
        from repro.models.transformer import init_params
    elif spec.family == "gnn":
        from repro.models.mace import init_params
    else:
        from repro.models.recsys import init_params
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    assert n_params > 100_000
