"""Minimal stand-in for ``hypothesis`` when it is not installed.

The property tests in this suite only use ``@settings``/``@given`` with
``st.integers`` strategies. This fallback replays each property over a fixed
deterministic sample of draws (seeded rng), so the suite stays runnable — and
still exercises a spread of shapes/seeds — in environments without the real
dependency. With ``hypothesis`` installed the real library is used instead
(see the try/except import in the test modules).
"""
from __future__ import annotations

import functools

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def sample(self, rng):
        return self._draw(rng)


class _Integers:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


st = _Integers()

_DEFAULT_EXAMPLES = 10


def settings(*, max_examples=_DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(0)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strategies.items()}
                fn(**drawn)

        # pytest must see the zero-arg signature, not the wrapped one —
        # otherwise the drawn parameters look like missing fixtures
        del wrapper.__dict__["__wrapped__"]
        return wrapper

    return deco
