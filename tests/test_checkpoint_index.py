"""Persisted Zen indexes: versioned save/load round-trips.

Covers the generic ``checkpoint.index_io`` store (atomicity, version and
kind rejection, corruption detection), bit-identical ``ZenServer`` search
parity through a save/load cycle for flat and IVF indexes (fresh and
churned), ``IVFZenIndex`` snapshots, and elastic resharding: a snapshot
saved from a 4-device mesh reloading onto 2 devices, 1 host, and back.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (
    CheckpointFormatError, INDEX_FORMAT_VERSION, load_state, save_state,
)
from repro.data import synthetic as syn
from repro.index import IVFZenIndex
from repro.launch.serve import ZenServer, build_index


# ------------------------------------------------------------ generic store

def test_index_io_roundtrip_and_atomic_overwrite(tmp_path):
    d = str(tmp_path / "snap")
    arrays = {"a": np.arange(6, dtype=np.int32).reshape(2, 3),
              "b.x-1": np.ones(4, np.float32)}
    save_state(d, arrays, {"note": "v1"}, kind="test")
    back, meta = load_state(d, expect_kind="test")
    assert meta == {"note": "v1"}
    assert np.array_equal(back["a"], arrays["a"])
    assert np.array_equal(back["b.x-1"], arrays["b.x-1"])
    # overwrite in place is atomic (tmp dir renamed over the old snapshot)
    save_state(d, {"a": np.zeros(1, np.int8)}, {"note": "v2"}, kind="test")
    back, meta = load_state(d)
    assert meta == {"note": "v2"} and list(back) == ["a"]
    # neither the write staging dir nor the crash-window backup survive
    assert not any(p.startswith(("tmp.", "old.")) for p in
                   os.listdir(tmp_path))


def test_index_io_rejects_unsafe_names_and_missing(tmp_path):
    with pytest.raises(ValueError):
        save_state(str(tmp_path / "s"), {"../evil": np.zeros(1)}, {},
                   kind="test")
    with pytest.raises(FileNotFoundError):
        load_state(str(tmp_path / "nothing"))


def _tamper(directory, **updates):
    path = os.path.join(directory, "manifest.json")
    with open(path) as f:
        m = json.load(f)
    m.update(updates)
    with open(path, "w") as f:
        json.dump(m, f)


def test_index_io_version_and_kind_rejection(tmp_path):
    d = str(tmp_path / "snap")
    save_state(d, {"a": np.zeros(2)}, {}, kind="test")
    _tamper(d, version=INDEX_FORMAT_VERSION + 1)
    with pytest.raises(CheckpointFormatError, match="version"):
        load_state(d)
    # v1 snapshots (pre-quantisation: f32 arrays, no storage meta) are a
    # strict subset of v2 and must keep loading
    _tamper(d, version=1)
    back, _ = load_state(d)
    assert list(back) == ["a"]
    _tamper(d, version=INDEX_FORMAT_VERSION, format="something-else")
    with pytest.raises(CheckpointFormatError, match="format"):
        load_state(d)
    _tamper(d, format="zen-index")
    with pytest.raises(CheckpointFormatError, match="kind"):
        load_state(d, expect_kind="other-kind")


def test_index_io_detects_corrupt_array(tmp_path):
    d = str(tmp_path / "snap")
    save_state(d, {"a": np.zeros((3, 3), np.float32)}, {}, kind="test")
    np.save(os.path.join(d, "a.npy"), np.zeros(2, np.int16))
    with pytest.raises(CheckpointFormatError, match="'a'"):
        load_state(d)


def test_write_json_atomic_replaces_without_torn_state(tmp_path):
    """The publish-pointer primitive: replace is all-or-nothing, the tmp
    staging file never survives, and a stale staging file left by a crashed
    writer neither blocks nor corrupts the next write."""
    from repro.checkpoint.index_io import write_json_atomic

    path = str(tmp_path / "PUBLISHED.json")
    write_json_atomic(path, {"generation": 1})
    with open(path) as f:
        assert json.load(f) == {"generation": 1}
    # a crashed writer's staging leftover (the crash window is before the
    # rename) must not confuse a reader or the next writer
    with open(path + ".crashed", "w") as f:
        f.write('{"generation":')  # torn JSON, never renamed into place
    (tmp_path / ("tmp." + "PUBLISHED.json")).write_text("{half")
    write_json_atomic(path, {"generation": 2})
    with open(path) as f:
        assert json.load(f) == {"generation": 2}
    assert not os.path.exists(path + ".tmp")


def test_snapshot_publish_crash_windows_leave_loadable_state(tmp_path):
    """Mid-publish kill simulation at the store level: whatever partial
    state a dead leader leaves next to a committed snapshot — a tmp.*
    staging dir, an old.* backup, a torn pointer staging file — the
    committed snapshot itself stays loadable and bit-identical."""
    d = str(tmp_path / "gen-000000000007")
    arrays = {"a": np.arange(12, dtype=np.float32)}
    save_state(d, arrays, {"generation": 7}, kind="test")
    # crash window 1: killed while staging the *next* snapshot version
    staging = tmp_path / "tmp.gen-000000000008"
    os.makedirs(staging)
    np.save(staging / "a.npy", np.zeros(3, np.float32))  # no manifest yet
    # crash window 2: killed between rename and backup cleanup
    backup = tmp_path / "old.gen-000000000007"
    os.makedirs(backup)
    (backup / "manifest.json").write_text("{}")
    # crash window 3: killed mid-pointer-write (torn staging file)
    (tmp_path / "tmp.PUBLISHED.json").write_text('{"generation": 8, "snap')
    back, meta = load_state(d, expect_kind="test")
    assert meta == {"generation": 7}
    np.testing.assert_array_equal(back["a"], arrays["a"])
    # and the staged-but-never-committed snapshot is not loadable as if
    # it were real — a reader that guesses at tmp.* names gets a loud error
    with pytest.raises(FileNotFoundError):
        load_state(str(staging))


# ----------------------------------------------------------- index snapshots

def _coords(key, n, k=8):
    x = jax.random.normal(key, (n, k), jnp.float32)
    return x.at[:, -1].set(jnp.abs(x[:, -1]))


def test_ivf_index_save_load_bit_identical(tmp_path):
    key = jax.random.PRNGKey(0)
    X = _coords(key, 1500)
    idx = IVFZenIndex.build(X, 12, key=key)
    Q = _coords(jax.random.fold_in(key, 1), 8)
    d0, i0 = idx.search(Q, 10, nprobe=5)
    idx.save(str(tmp_path / "ivf"))
    back = IVFZenIndex.load(str(tmp_path / "ivf"))
    assert back.n_valid == idx.n_valid
    d1, i1 = back.search(Q, 10, nprobe=5)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1))


def test_ivf_index_save_drops_tombstones(tmp_path):
    key = jax.random.PRNGKey(1)
    X = _coords(key, 1000)
    idx = IVFZenIndex.build(X, 8, key=key).delete(np.arange(0, 1000, 3))
    Q = _coords(jax.random.fold_in(key, 1), 6)
    d0, i0 = idx.search(Q, 10, nprobe=idx.n_clusters)
    idx.save(str(tmp_path / "ivf"))
    back = IVFZenIndex.load(str(tmp_path / "ivf"))
    assert back.n_deleted == 0 and back.n_valid == idx.n_valid
    assert back.tiles_per_cluster <= idx.tiles_per_cluster
    d1, i1 = back.search(Q, 10, nprobe=back.n_clusters)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))


def test_ivf_wrong_kind_rejected(tmp_path):
    key = jax.random.PRNGKey(2)
    idx = IVFZenIndex.build(_coords(key, 300), 4, key=key)
    idx.save(str(tmp_path / "ivf"))
    with pytest.raises(CheckpointFormatError, match="kind"):
        ZenServer.load(str(tmp_path / "ivf"))


# ------------------------------------------------------------ server parity

@pytest.mark.parametrize("kind", ["flat", "ivf"])
def test_server_save_load_bit_identical(tmp_path, kind):
    key = jax.random.PRNGKey(3)
    corpus = syn.manifold_space(key, 2500, 64, 8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 8, 64, 8)
    srv = ZenServer(build_index(corpus, 8, index=kind, n_clusters=16),
                    rerank_factor=2, nprobe=16)
    d0, i0 = srv.query(q, 5)
    srv.save(str(tmp_path / "srv"))
    back = ZenServer.load(str(tmp_path / "srv"))
    assert back.nprobe == 16 and back.rerank_factor == 2  # config restored
    d1, i1 = back.query(q, 5)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("kind", ["flat", "ivf"])
def test_server_save_load_after_churn(tmp_path, kind):
    key = jax.random.PRNGKey(4)
    corpus = syn.manifold_space(key, 2000, 64, 8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 8, 64, 8)
    srv = ZenServer(build_index(corpus, 8, index=kind, n_clusters=16),
                    rerank_factor=2, nprobe=16)
    srv.delete(np.arange(0, 2000, 5))
    extra = syn.manifold_space(jax.random.fold_in(key, 2), 300, 64, 8)
    srv.upsert(np.arange(3000, 3300), extra)
    d0, i0 = srv.query(q, 5)
    srv.save(str(tmp_path / "srv"))
    back = ZenServer.load(str(tmp_path / "srv"))
    d1, i1 = back.query(q, 5)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1))
    # churn continues after restore: external ids stay stable
    back.delete([int(np.asarray(i1)[0, 0])])
    _, i2 = back.query(q, 5)
    assert int(np.asarray(i1)[0, 0]) not in np.asarray(i2).ravel().tolist()


def test_server_load_config_overrides(tmp_path):
    key = jax.random.PRNGKey(5)
    corpus = syn.manifold_space(key, 600, 32, 8)
    ZenServer(build_index(corpus, 8), rerank_factor=3,
              chunk=1234).save(str(tmp_path / "srv"))
    back = ZenServer.load(str(tmp_path / "srv"), rerank_factor=0)
    assert back.rerank_factor == 0 and back.chunk == 1234


# ------------------------------------------------- elastic reshard (4 dev)

_RESHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenServer, build_index

    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, 3001, 64, 8)   # odd N: pad path
    q = syn.manifold_space(jax.random.fold_in(key, 1), 8, 64, 8)
    devs = jax.devices()
    mesh4 = Mesh(np.asarray(devs), ("shard",))
    mesh2 = Mesh(np.asarray(devs[:2]), ("shard",))

    for kind in ("flat", "ivf"):
        srv = ZenServer(
            build_index(corpus, 8, index=kind, n_clusters=16, mesh=mesh4),
            rerank_factor=2, nprobe=16)
        d0, i0 = srv.query(q, 5)
        path = os.path.join(os.environ["SNAP_DIR"], kind)
        srv.save(path)
        # saved from 4 shards; reload onto 2 shards, 1 host, and 4 again
        for m, label in ((mesh2, "2dev"), (None, "host"), (mesh4, "4dev")):
            back = ZenServer.load(path, mesh=m)
            d1, i1 = back.query(q, 5)
            assert np.array_equal(np.asarray(i0), np.asarray(i1)), (
                kind, label)
            assert np.allclose(np.asarray(d0), np.asarray(d1),
                               atol=1e-5), (kind, label)
    print("RESHARD_OK")
""")


@pytest.mark.slow
def test_sharded_save_reshard_on_load(tmp_path):
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
        SNAP_DIR=str(tmp_path),
    )
    r = subprocess.run(
        [sys.executable, "-c", _RESHARD_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "RESHARD_OK" in r.stdout
