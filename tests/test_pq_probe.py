"""PQ probe parity: the fused-LUT path against its fallbacks and oracle.

Three implementations of the product-quantised probe must agree on the
same code tiles: the Pallas kernel (interpret mode on CPU), the fori_loop
LUT-gather scan, and the dense oracle — the plain estimator evaluated on
the *decoded* member coordinates (``centroid + decode(code)``). The ADC
tables fold the Zen/Lwb/Upb altitude terms, so agreement across all three
modes pins the mode-folding algebra, not just the gather. Mirrors
``test_ivf_index.py``: padded tails, single cluster, multi-tile clusters,
``nprobe = n_clusters`` exactness, plus the non-Euclidean (jsd/qform)
serving path through exact re-rank. All CPU.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import zen as Z
from repro.core.quality import recall_at_k
from repro.index import IVFZenIndex
from repro.kernels import ivf_probe as ip
from repro.kernels import ops
from repro.kernels import pq as pq_lib
from repro.kernels import scoring

MODES = ["zen", "lwb", "upb"]


def _coords(seed, n, k):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, k)).astype(np.float32)
    X[:, -1] = np.abs(X[:, -1])
    return jnp.asarray(X)


def _queries(seed, X, q, noise=0.05):
    rng = np.random.default_rng(seed)
    Q = np.asarray(X[:q]) + noise * rng.normal(size=(q, X.shape[1]))
    return jnp.asarray(Q.astype(np.float32))


def _decoded_corpus(idx, n):
    """(n, k) f32 coordinates the PQ index *actually* stores — each member
    decoded against its centroid — the oracle the LUT path must match."""
    tiles = idx._host_tiles_f32().reshape(-1, np.asarray(idx.centroids).shape[1])
    ids = np.asarray(idx.tile_ids).ravel()
    out = np.zeros((n, tiles.shape[1]), np.float32)
    out[ids[ids >= 0]] = tiles[ids >= 0]
    return out


# -- kernel vs scan vs dense oracle -------------------------------------------

PQ_PARITY_CASES = [
    # (n, k, n_clusters, nprobe): padded tiles, single cluster, T >= 2,
    # ragged k (k=18 -> M=4, ds=5: padded subspace columns in play)
    (600, 12, 8, 3),
    (513, 8, 1, 1),       # single cluster edge
    (900, 8, 4, 2),       # clusters > tile_rows: T >= 2
    (200, 18, 12, 12),    # ragged k + all clusters probed
]


@pytest.mark.parametrize("n,k,c,nprobe", PQ_PARITY_CASES)
@pytest.mark.parametrize("mode", MODES)
def test_pq_probe_kernel_matches_scan(n, k, c, nprobe, mode):
    """Interpret-mode kernel and fori_loop scan gather the same tables over
    the same code tiles: identical ids, near-bit distances."""
    X = _coords(n * 5 + k, n, k)
    Q = _queries(n * 5, X, 6)
    idx = IVFZenIndex.build(X, c, key=jax.random.PRNGKey(5), storage="pq")
    probes = idx.probe_clusters(Q, nprobe, mode)
    luts = pq_lib.build_luts(Q, idx.centroids, idx.codebooks, probes,
                             scoring.MODE_IDS[mode])
    scan_d, scan_i = ip.ivf_probe_pq_scan(
        idx.tile_coords, idx.tile_ids, probes, luts, 9,
        tiles_per_cluster=idx.tiles_per_cluster)
    kern_d, kern_i = ip.ivf_probe_pq(
        idx.tile_coords, idx.tile_ids, probes, luts, 9,
        tiles_per_cluster=idx.tiles_per_cluster, interpret=True)
    assert (np.asarray(kern_i) == np.asarray(scan_i)).all()
    np.testing.assert_allclose(np.asarray(kern_d), np.asarray(scan_d),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", MODES)
def test_pq_full_probe_matches_dense_on_decoded(mode):
    """nprobe = n_clusters scans everything: the LUT path must equal the
    flat estimator search over the decoded coordinates — same distances,
    and ids agreeing wherever the decoded points are distinct (members
    sharing all M codes in one cluster decode identically; such genuine
    ties may legally reorder)."""
    n, k, c, nn = 700, 12, 10, 10
    X = _coords(7, n, k)
    Q = _queries(8, X, 7)
    idx = IVFZenIndex.build(X, c, key=jax.random.PRNGKey(2), storage="pq")
    Xhat = jnp.asarray(_decoded_corpus(idx, n))
    want_d, want_i = Z.knn_search(Q, Xhat, nn, mode)
    got_d, got_i = idx.search(Q, nn, nprobe=idx.n_clusters, mode=mode)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-4, atol=1e-4)
    # each returned id must realise its reported distance on the decoded
    # corpus (exactness up to ties), and the id sets must coincide
    dense = np.asarray(Z.estimate_pdist(Q, Xhat, mode))
    np.testing.assert_allclose(
        np.take_along_axis(dense, np.asarray(got_i), 1),
        np.asarray(got_d), rtol=1e-4, atol=1e-4)
    for qi in range(Q.shape[0]):
        assert set(np.asarray(got_i)[qi].tolist()) == \
            set(np.asarray(want_i)[qi].tolist())


@pytest.mark.parametrize("mode", MODES)
def test_build_luts_match_dense_estimator(mode):
    """sum_m lut[q, p, m, code[m]] == the mode's squared estimator distance
    to ``centroid + decode(code)`` — the table algebra itself, checked
    against arbitrary (not trained) codebooks and random codes."""
    rng = np.random.default_rng(9)
    q_n, c_n, k, m = 5, 6, 10, 3
    ds = pq_lib.subspace_dims(k, m)
    Qv = _coords(10, q_n, k)
    cents = _coords(11, c_n, k)
    books = rng.normal(size=(m, pq_lib.PQ_ENTRIES, ds)).astype(np.float32)
    pad = m * ds - k
    if pad:  # padded columns must stay zero, as trained books do
        books[-1, :, ds - pad:] = 0.0
    codes = rng.integers(0, 256, size=(c_n, 4, m)).astype(np.uint8)
    probes = jnp.asarray(np.stack([rng.permutation(c_n)[:4]
                                   for _ in range(q_n)]), jnp.int32)
    luts = pq_lib.build_luts(Qv, cents, jnp.asarray(books), probes,
                             scoring.MODE_IDS[mode])
    luts = np.asarray(luts)
    for qi in range(q_n):
        for pi in range(4):
            c = int(np.asarray(probes)[qi, pi])
            xhat = np.asarray(cents)[c] + pq_lib.decode(codes[c], books, k)
            want = np.asarray(Z.estimate_pdist(
                Qv[qi:qi + 1], jnp.asarray(xhat), mode))[0] ** 2
            got = np.take_along_axis(
                luts[qi, pi].T, codes[c].astype(np.int64), 0).sum(1)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pq_search_force_kernel_matches_scan():
    X = _coords(80, 700, 9)
    idx = IVFZenIndex.build(X, 12, key=jax.random.PRNGKey(9), storage="pq")
    Q = _queries(81, X, 5)
    d0, i0 = idx.search(Q, 7, nprobe=5)
    d1, i1 = idx.search(Q, 7, nprobe=5, force_kernel=True)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-5, atol=1e-5)


def test_pq_ops_dispatch_matches():
    X = _coords(70, 500, 11)
    idx = IVFZenIndex.build(X, 10, key=jax.random.PRNGKey(8), storage="pq")
    Q = _queries(71, X, 6)
    probes = idx.probe_clusters(Q, 4)
    luts = pq_lib.build_luts(Q, idx.centroids, idx.codebooks, probes,
                             scoring.MODE_IDS["zen"])
    a = ops.ivf_probe_pq(idx.tile_coords, idx.tile_ids, probes, luts, 8,
                         tiles_per_cluster=idx.tiles_per_cluster)
    b = ops.ivf_probe_pq(idx.tile_coords, idx.tile_ids, probes, luts, 8,
                         tiles_per_cluster=idx.tiles_per_cluster,
                         force_kernel=True)
    assert (np.asarray(a[1]) == np.asarray(b[1])).all()
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                               rtol=1e-5, atol=1e-5)


def test_pq_probe_returns_padding_when_pool_too_small():
    X = _coords(60, 64, 6)
    idx = IVFZenIndex.build(X, 64, key=jax.random.PRNGKey(7), storage="pq",
                            pq_m=1)
    Q = _queries(61, X, 4)
    d, ids = idx.search(Q, 10, nprobe=1)
    d, ids = np.asarray(d), np.asarray(ids)
    assert (ids[:, 0] >= 0).all()
    assert (ids[:, 1:] == -1).all() and np.isinf(d[:, 1:]).all()
    assert ids.max() < 64


def test_pq_recall_close_to_f32():
    """The BENCH acceptance protocol in miniature: a 4x PQ candidate pool,
    exactly re-ranked, lands within 0.05 recall@10 of the f32 probe at the
    same nprobe (both indexes share the coarse quantizer key, so only the
    member storage differs)."""
    X = _coords(90, 4096, 16)
    Q = _queries(91, X, 16)
    truth = np.asarray(Z.knn_search(Q, X, 10, "zen")[1])
    f32 = IVFZenIndex.build(X, 32, key=jax.random.PRNGKey(0))
    pq = IVFZenIndex.build(X, 32, key=jax.random.PRNGKey(0), storage="pq")
    dense = np.asarray(Z.estimate_pdist(Q, X, "zen"))
    for nprobe in (8, 16):
        rec_f32 = recall_at_k(truth, np.asarray(
            f32.search(Q, 10, nprobe=nprobe)[1]))
        cand = np.asarray(pq.search(Q, 40, nprobe=nprobe)[1])
        cd = np.where(cand >= 0,
                      np.take_along_axis(dense, np.maximum(cand, 0), 1),
                      np.inf)
        picked = np.take_along_axis(
            cand, np.argsort(cd, axis=1, kind="stable"), 1)[:, :10]
        rec_pq = recall_at_k(truth, picked)
        assert rec_pq >= rec_f32 - 0.05, (nprobe, rec_pq, rec_f32)


# -- non-Euclidean metrics through serving (rerank pool from PQ probe) --------


@pytest.mark.parametrize("metric", ["jsd", "qform"])
def test_pq_noneuclid_serving_rerank(metric):
    """storage="pq" composes with jsd/qform end to end: the PQ probe feeds
    the candidate pool, the exact metric re-ranks — recall must track the
    f32 pipeline within the acceptance bar."""
    from repro.data import synthetic as syn
    from repro.launch.serve import ZenServer, build_index

    key = jax.random.PRNGKey(13)
    if metric == "jsd":
        corpus = syn.probability_space(key, 2000, 48, 8)
        q = syn.probability_space(jax.random.fold_in(key, 1), 32, 48, 8)
    else:
        corpus = syn.manifold_space(key, 2000, 48, 8)
        q = syn.manifold_space(jax.random.fold_in(key, 1), 32, 48, 8)
    kw = dict(metric=metric, index="ivf", n_clusters=24,
              key=jax.random.PRNGKey(3))
    pq_index = build_index(corpus, 12, storage="pq", **kw)
    assert pq_index.ivf.codebooks is not None
    f32_index = build_index(corpus, 12, **kw)
    d_pq, i_pq = ZenServer(pq_index, nprobe=8, rerank_factor=4).query(q, 10)
    d_f, i_f = ZenServer(f32_index, nprobe=8, rerank_factor=4).query(q, 10)
    assert (np.asarray(i_pq) >= 0).all()
    assert bool(jnp.isfinite(d_pq).all())
    rec = recall_at_k(np.asarray(i_f), np.asarray(i_pq))
    assert rec >= 1.0 - 0.05, (metric, rec)
