"""MoE dispatch invariants: equivalence to a dense per-token reference when
capacity is ample, capacity-drop semantics, padded-expert masking."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models.transformer import TransformerConfig, init_params


def _cfg(**kw):
    base = dict(
        name="moe-t", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=64, n_experts=6, top_k=2, moe_d_ff=16,
        moe_group_size=32, capacity_factor=8.0,  # ample capacity
        dtype=jnp.float32, remat_policy="none",
    )
    base.update(kw)
    return TransformerConfig(**base)


def _layer_params(cfg, seed=0):
    p = init_params(cfg, jax.random.PRNGKey(seed))
    return jax.tree.map(lambda a: a[0, 0], p["layers"])  # (G=1, PL=1) -> leaf


def _dense_reference(cfg, p, x):
    """Per-token dense loop over ALL experts weighted by renormalised top-k
    gates — the semantics moe_ffn must match when nothing is dropped."""
    B, S, D = x.shape
    E = p["we_gate"].shape[0]
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    mask = jnp.arange(E) >= cfg.n_experts
    logits = jnp.where(mask[None, None], -1e30, logits)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # compute every expert on every token (reference only)
    g = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, p["we_gate"]))
    u = jnp.einsum("bsd,edf->bsef", x, p["we_up"])
    outs = jnp.einsum("bsef,efd->bsed", g * u, p["we_down"])  # (B,S,E,D)
    sel = jnp.take_along_axis(outs, idx[..., None], axis=2)  # (B,S,k,D)
    return jnp.sum(sel * gate[..., None], axis=2)


def test_moe_matches_dense_reference_with_ample_capacity():
    cfg = _cfg()
    p = _layer_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    got = moe_lib.moe_ffn(cfg, p, x)
    want = _dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_moe_capacity_drop_reduces_output_not_nan():
    # tiny capacity: most assignments dropped; output finite and smaller norm
    cfg_low = _cfg(capacity_factor=0.25)
    cfg_hi = _cfg(capacity_factor=8.0)
    p = _layer_params(cfg_hi)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 32))
    hi = moe_lib.moe_ffn(cfg_hi, p, x)
    lo = moe_lib.moe_ffn(cfg_low, p, x)
    assert bool(jnp.isfinite(lo).all())
    assert float(jnp.linalg.norm(lo)) < float(jnp.linalg.norm(hi)) + 1e-6


def test_moe_padded_experts_receive_no_tokens():
    cfg = _cfg(n_experts=6)  # padded to 16
    p = _layer_params(cfg)
    E = p["we_gate"].shape[0]
    assert E == moe_lib.padded_experts(6) and E > 6
    # poison padded expert weights with NaN: output must stay finite
    poison = p["we_gate"].at[6:].set(jnp.nan)
    p2 = dict(p, we_gate=poison)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 32))
    out = moe_lib.moe_ffn(cfg, p2, x)
    assert bool(jnp.isfinite(out).all()), "padded experts were routed tokens"


def test_moe_grouping_invariance():
    # same tokens, different group sizes -> identical results (ample capacity)
    cfg_a = _cfg(moe_group_size=16)
    cfg_b = _cfg(moe_group_size=64)
    p = _layer_params(cfg_a)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, 32))
    a = moe_lib.moe_ffn(cfg_a, p, x)
    b = moe_lib.moe_ffn(cfg_b, p, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-5)


def test_capacity_formula():
    assert moe_lib.capacity(4096, 4, 64, 1.25) == 320
    assert moe_lib.capacity(16, 2, 16, 1.0) >= 8  # floor
