"""Batched Lloyd's k-means (the IVF coarse quantizer): recovery on separated
blobs, empty-cluster reseeding, fixed-point behaviour on degenerate data, and
chunked-assignment invariance. All CPU."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.index.kmeans import kmeans_assign, kmeans_fit


def _blobs(seed, n_per, n_blobs, dim, scale=20.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_blobs, dim)) * scale
    X = np.concatenate([c + rng.normal(size=(n_per, dim)) for c in centers])
    return jnp.asarray(X, jnp.float32)


def test_recovers_separated_blobs():
    X = _blobs(0, 200, 8, 6)
    cents, inertia = kmeans_fit(X, 8, key=jax.random.PRNGKey(0), n_iters=20)
    assign = np.asarray(kmeans_assign(X, cents))
    counts = np.bincount(assign, minlength=8)
    # every blob found: all clusters populated with exactly one blob each
    assert (counts == 200).all(), counts
    # within-blob variance only: mean squared distance ~ dim
    assert float(inertia) < 3 * 6, float(inertia)


def test_empty_cluster_reseeding_uses_all_clusters():
    # two tight far-apart blobs but 8 clusters: naive Lloyd's would park most
    # centroids empty next to one blob; reseeding must keep all 8 in use
    X = _blobs(1, 100, 2, 4, scale=100.0)
    cents, _ = kmeans_fit(X, 8, key=jax.random.PRNGKey(1), n_iters=15)
    assign = np.asarray(kmeans_assign(X, cents))
    assert jnp.isfinite(cents).all()
    counts = np.bincount(assign, minlength=8)
    assert (counts > 0).all(), counts


def test_degenerate_identical_points_fixed_point():
    # all points identical and fewer distinct values than clusters: the fit
    # must stay finite, reach inertia 0, and be a fixed point of iteration
    X = jnp.ones((50, 4), jnp.float32)
    c_short, i_short = kmeans_fit(X, 16, key=jax.random.PRNGKey(2), n_iters=2)
    c_long, i_long = kmeans_fit(X, 16, key=jax.random.PRNGKey(2), n_iters=12)
    assert jnp.isfinite(c_short).all() and jnp.isfinite(c_long).all()
    assert float(i_short) == 0.0 and float(i_long) == 0.0
    np.testing.assert_allclose(np.asarray(c_short), np.asarray(c_long))
    a = np.asarray(kmeans_assign(X, c_long))
    assert a.min() >= 0 and a.max() < 16


def test_n_clusters_equals_n_gives_distinct_cells():
    X = _blobs(3, 2, 8, 5)  # 16 points
    cents, inertia = kmeans_fit(X, 16, key=jax.random.PRNGKey(3), n_iters=10)
    assign = np.asarray(kmeans_assign(X, cents))
    assert len(set(assign.tolist())) == 16
    assert float(inertia) < 1e-3  # f32 roundoff only: every point is its own cell


def test_assignment_chunking_invariance():
    X = _blobs(4, 37, 5, 7)  # 185 rows, deliberately ragged vs chunk
    cents, _ = kmeans_fit(X, 5, key=jax.random.PRNGKey(4), n_iters=10)
    a_full = np.asarray(kmeans_assign(X, cents, chunk=10_000))
    a_small = np.asarray(kmeans_assign(X, cents, chunk=13))
    assert (a_full == a_small).all()


def test_fit_deterministic_in_key():
    X = _blobs(5, 50, 4, 6)
    c1, _ = kmeans_fit(X, 4, key=jax.random.PRNGKey(9), n_iters=8)
    c2, _ = kmeans_fit(X, 4, key=jax.random.PRNGKey(9), n_iters=8)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2))
