"""Distribution/lowering infrastructure tests.

The production 256/512-device meshes need the dry-run entrypoint (subprocess
with XLA_FLAGS); here a subprocess with 8 host devices lowers + compiles a
representative subset of cells on a (2,2,2) pod/data/model mesh — the same
code path as the full dry-run, small enough for CI.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.launch.mesh import _make_mesh
    from repro.launch.steps import build_plan

    mesh = _make_mesh((2, 2, 2), ("pod", "data", "model"), jax.devices()[:8])
    out = []
    for arch, shape in json.loads(sys.argv[1]):
        plan = build_plan(arch, shape, reduced=True, multi_pod=True)
        if plan.skip:
            out.append([arch, shape, "skip"])
            continue
        compiled = plan.lower(mesh).compile()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: list of per-computation dicts
            ca = ca[0] if ca else {}
        out.append([arch, shape, "ok", float(ca.get("flops", 0))])
    print("RESULT " + json.dumps(out))
""")

CELLS = [
    ["qwen1.5-0.5b", "train_4k"],
    ["gemma2-2b", "long_500k"],
    ["qwen2-moe-a2.7b", "decode_32k"],
    ["mace", "molecule"],
    ["dlrm-rm2", "train_batch"],
    ["xdeepfm", "retrieval_cand"],
    ["granite-8b", "long_500k"],  # mandated skip
]


@pytest.mark.slow
def test_reduced_cells_compile_on_multipod_mesh():
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT, json.dumps(CELLS)],
        capture_output=True, text=True, env=env, timeout=1500,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    results = json.loads(line[len("RESULT "):])
    status = {(a, s): st for a, s, st, *rest in results}
    assert status[("granite-8b", "long_500k")] == "skip"
    for (a, s), st in status.items():
        if (a, s) != ("granite-8b", "long_500k"):
            assert st == "ok", (a, s)


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives

    hlo = """
    %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={}
    %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%sum
    %cp = f32[8,8]{1,0} collective-permute(%z)
    ROOT %t = (f32[4]{0}) tuple(%ar.1)
    %ag2s = bf16[64]{0} all-gather-start(%w)
    %ag2d = bf16[64]{0} all-gather-done(%ag2s)
    """
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 2  # ag + ag-start (done not counted)
    assert out["all-gather"]["bytes"] == 16 * 128 * 2 + 64 * 2
    assert out["all-reduce"]["bytes"] == 256 * 4
    assert out["collective-permute"]["count"] == 1
    assert out["total_count"] == 4


def test_variant_parsing():
    from repro.launch.dryrun import _parse_variant

    v = _parse_variant("unroll_layers=True,n_microbatches=4,remat_policy=dots")
    assert v == {"unroll_layers": True, "n_microbatches": 4,
                 "remat_policy": "dots"}


def test_param_spec_rules_cover_all_leaves():
    import jax

    from repro import configs as C
    from repro.distributed import sharding as sl
    from repro.models import transformer as tfm

    for arch in ["qwen2-moe-a2.7b", "gemma2-2b"]:
        cfg = C.get_arch(arch).make_reduced()
        shapes = jax.eval_shape(
            lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
        specs = sl.lm_param_specs(shapes)
        # every leaf got a spec whose rank fits the leaf
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval")
            or x.__class__.__name__ == "PartitionSpec")
        flat_l = jax.tree.leaves(shapes)
        assert len(flat_s) == len(flat_l)
        for sp, leaf in zip(flat_s, flat_l):
            assert len(sp) <= leaf.ndim or len(sp) == 0
