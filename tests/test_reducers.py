"""Tier-1 suite for the ``core.reducers`` uniform fit/transform protocol —
the shim the retrieval_e2e workload and quality curves plug every DR method
through."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    DISTANCE_ONLY,
    REDUCER_NAMES,
    make_reducer,
    select_references,
    zen_pdist,
)
from repro.core import metrics as M


def _witness(seed=0, n=120, m=24):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(n, m)), jnp.float32)


@pytest.mark.parametrize("name", REDUCER_NAMES)
def test_protocol_shapes_and_finiteness(name):
    X = _witness()
    Q = _witness(1, 20, 24)
    r = make_reducer(name, 6).fit(X, key=jax.random.PRNGKey(0))
    Xr, Qr = r.transform(X), r.transform(Q)
    assert Xr.shape == (120, 6) and Qr.shape == (20, 6)
    D = np.asarray(r.pdist(Qr, Xr))
    assert D.shape == (20, 120)
    assert np.all(np.isfinite(D))
    assert np.all(D >= -1e-5)


@pytest.mark.parametrize("name", REDUCER_NAMES)
def test_fit_returns_new_object(name):
    r0 = make_reducer(name, 4)
    r1 = r0.fit(_witness(), key=jax.random.PRNGKey(0))
    assert r0.transform_ is None  # unfitted original untouched
    assert r1.transform_ is not None


@pytest.mark.parametrize("name", sorted(set(REDUCER_NAMES) - set(DISTANCE_ONLY)))
def test_coordinate_methods_refuse_non_euclidean(name):
    P = _witness()
    with pytest.raises(ValueError, match="Euclidean-coordinate"):
        make_reducer(name, 4, metric="jsd").fit(P)


@pytest.mark.parametrize("name", DISTANCE_ONLY)
def test_distance_only_methods_fit_jsd(name):
    rng = np.random.default_rng(2)
    P = rng.uniform(size=(80, 48)).astype(np.float32)
    P = jnp.asarray(P / P.sum(1, keepdims=True))
    r = make_reducer(name, 5, metric="jsd").fit(P, key=jax.random.PRNGKey(1))
    Pr = r.transform(P)
    assert Pr.shape == (80, 5)
    assert np.all(np.isfinite(np.asarray(Pr)))


def test_zen_reducer_matches_direct_path_bitwise():
    # the shim must be a zero-cost veneer over select_references + zen_pdist
    X = _witness(3)
    key = jax.random.PRNGKey(42)
    r = make_reducer("zen", 8).fit(X, key=key)
    tr = select_references(X, 8, key)
    assert np.array_equal(np.asarray(r.transform(X)),
                          np.asarray(tr.transform(X)))
    Xr = tr.transform(X)
    assert np.array_equal(np.asarray(r.pdist(Xr, Xr)),
                          np.asarray(zen_pdist(Xr, Xr)))


def test_lmds_reducer_landmarks_clamped_to_witness():
    X = _witness(4, n=9, m=12)  # fewer rows than the default 2k landmarks
    r = make_reducer("lmds", 6).fit(X, key=jax.random.PRNGKey(0))
    assert r.landmarks_.shape[0] == 9


def test_lmds_reducer_deterministic_under_key():
    X = _witness(5)
    a = make_reducer("lmds", 6).fit(X, key=jax.random.PRNGKey(9))
    b = make_reducer("lmds", 6).fit(X, key=jax.random.PRNGKey(9))
    assert np.array_equal(np.asarray(a.transform(X)),
                          np.asarray(b.transform(X)))


def test_reducers_beat_chance_on_recall():
    # sanity: every reducer's reduced-space top-10 does far better than
    # random guessing on an easy clustered corpus
    rng = np.random.default_rng(6)
    centers = rng.normal(size=(10, 32)) * 4
    X = jnp.asarray((centers[np.arange(200) % 10]
                     + rng.normal(size=(200, 32))).astype(np.float32))
    d_true = np.asarray(M.euclidean_pdist(X, X))
    truth = np.argsort(d_true, 1)[:, 1:11]
    for name in REDUCER_NAMES:
        r = make_reducer(name, 8).fit(X, key=jax.random.PRNGKey(0))
        Xr = r.transform(X)
        pred = np.argsort(np.asarray(r.pdist(Xr, Xr)), 1)[:, 1:11]
        rec = np.mean([len(set(truth[i]) & set(pred[i])) / 10
                       for i in range(200)])
        assert rec > 0.3, name  # chance is ~10/200 = 0.05


def test_make_reducer_unknown_name():
    with pytest.raises(ValueError, match="unknown reducer"):
        make_reducer("umap", 4)
