"""Per-kernel validation: Pallas body under interpret=True vs pure-jnp oracle,
sweeping shapes (aligned, ragged, tiny, feature-dim remainders) and dtypes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import jsd as jsd_k
from repro.kernels import ops
from repro.kernels import pdist as pdist_k
from repro.kernels import ref
from repro.kernels import zen as zen_k


SHAPES_PDIST = [
    (8, 8, 16),
    (128, 128, 512),
    (100, 37, 129),  # ragged everything
    (256, 64, 1000),
    (1, 5, 3),
    (130, 257, 640),
]


@pytest.mark.parametrize("n,k,m", SHAPES_PDIST)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pdist_kernel_matches_ref(n, k, m, dtype):
    rng = np.random.default_rng(n * 1000 + k + m)
    X = jnp.asarray(rng.normal(size=(n, m)), dtype)
    Y = jnp.asarray(rng.normal(size=(k, m)), dtype)
    got = pdist_k.pdist_sq(X, Y, interpret=True)
    want = ref.pdist_sq_ref(X, Y)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol * float(jnp.max(want))
    )


@pytest.mark.parametrize("n,k,m", [(64, 64, 256), (33, 100, 70)])
def test_pdist_kernel_custom_blocks(n, k, m):
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(k, m)), jnp.float32)
    got = pdist_k.pdist_sq(X, Y, block_n=32, block_k=128, block_m=128, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.pdist_sq_ref(X, Y)), rtol=1e-5, atol=1e-4
    )


SHAPES_ZEN = [(16, 16, 4), (256, 256, 32), (100, 300, 17), (7, 1, 2), (64, 128, 130)]


@pytest.mark.parametrize("n,m,k", SHAPES_ZEN)
@pytest.mark.parametrize("mode", ["zen", "lwb", "upb"])
def test_zen_kernel_matches_ref(n, m, k, mode):
    rng = np.random.default_rng(n + m + k)
    X = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    # altitudes are non-negative in real projections
    X = X.at[:, -1].set(jnp.abs(X[:, -1]))
    Y = Y.at[:, -1].set(jnp.abs(Y[:, -1]))
    got = zen_k.zen_estimate(X, Y, mode, interpret=True)
    want = ref.zen_estimate_ref(X, Y, mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_zen_kernel_dtypes(dtype):
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(64, 16)), dtype)
    Y = jnp.asarray(rng.normal(size=(96, 16)), dtype)
    got = zen_k.zen_estimate(X, Y, "zen", interpret=True)
    want = ref.zen_estimate_ref(X, Y, "zen")
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol * 10)


SHAPES_JSD = [(8, 8, 32), (64, 64, 256), (40, 100, 100), (16, 16, 48), (128, 128, 513)]


@pytest.mark.parametrize("n,k,m", SHAPES_JSD)
def test_jsd_kernel_matches_ref(n, k, m):
    rng = np.random.default_rng(n + k * 7 + m)
    X = rng.uniform(size=(n, m))
    Y = rng.uniform(size=(k, m))
    X = jnp.asarray(X / X.sum(1, keepdims=True), jnp.float32)
    Y = jnp.asarray(Y / Y.sum(1, keepdims=True), jnp.float32)
    got = jsd_k.jsd_pdist(X, Y, interpret=True)
    want = ref.jsd_pdist_ref(X, Y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_jsd_kernel_sparse_rows():
    # 0 log 0 handling inside the kernel, incl. disjoint supports -> distance 1
    X = jnp.asarray([[0.5, 0.5, 0.0, 0.0], [0.25, 0.25, 0.25, 0.25]], jnp.float32)
    Y = jnp.asarray([[0.0, 0.0, 0.5, 0.5]], jnp.float32)
    got = np.asarray(jsd_k.jsd_pdist(X, Y, interpret=True))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got[0, 0], 1.0, atol=1e-6)


def test_ops_dispatch_cpu_matches_kernel():
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.normal(size=(50, 64)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(30, 64)), jnp.float32)
    a = ops.pdist_sq(X, Y)                      # jnp fallback on CPU
    b = ops.pdist_sq(X, Y, force_kernel=True)   # interpret-mode kernel
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4)


def test_kernel_oracle_matches_core_metrics():
    # kernels/ref.py and core/metrics.py agree (independent implementations)
    from repro.core import metrics as M

    rng = np.random.default_rng(6)
    X = jnp.asarray(rng.uniform(size=(20, 40)), jnp.float32)
    Y = jnp.asarray(rng.uniform(size=(10, 40)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.pdist_sq_ref(X, Y)),
        np.asarray(M.sqeuclidean_pdist(X, Y)),
        rtol=1e-5, atol=1e-5,
    )
    Xn, Yn = M.l1_normalize(X), M.l1_normalize(Y)
    np.testing.assert_allclose(
        np.asarray(ref.jsd_pdist_ref(Xn, Yn)),
        np.asarray(M.jsd_pdist(Xn, Yn, assume_normalized=True)),
        rtol=1e-5, atol=1e-5,
    )
