"""Dedicated tier-1 suite for ``core.baselines`` — the paper's comparison
transforms (PCA / Achlioptas random projection / MDS / landmark MDS).

Each transform gets its own contract tests: PCA spectral properties and the
``dims_for_variance`` edge cases, the RP Johnson-Lindenstrauss distortion
bound and seed determinism, MDS out-of-sample consistency, and LMDS
distance-only parity with the coordinate path (plus the degenerate-spectrum
regression: near-zero eigenvalues must be dropped, not inverted).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.core.baselines import (
    LMDSTransform,
    MDSTransform,
    PCATransform,
    RandomProjection,
    classical_mds_embed,
)


def _gaussian(seed, n, m, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(n, m)) * scale, jnp.float32)


# -- PCA -------------------------------------------------------------------


def test_pca_components_orthonormal():
    X = _gaussian(0, 200, 32)
    pca = PCATransform(k=8).fit(X)
    C = np.asarray(pca.components)  # (m, k)
    assert C.shape == (32, 8)
    np.testing.assert_allclose(C.T @ C, np.eye(8), atol=1e-4)


def test_pca_reconstruction_error_monotone_in_k():
    X = _gaussian(1, 300, 24)
    Xc = np.asarray(X) - np.asarray(X).mean(0)
    errs = []
    for k in (1, 2, 4, 8, 16, 24):
        pca = PCATransform(k=k).fit(X)
        C = np.asarray(pca.components)
        recon = (Xc @ C) @ C.T
        errs.append(float(np.linalg.norm(Xc - recon)))
    # adding components can only explain more variance
    for lo, hi in zip(errs[1:], errs[:-1]):
        assert lo <= hi + 1e-5
    # full-rank PCA reconstructs exactly
    assert errs[-1] < 1e-2


def test_pca_dims_for_variance_k1():
    # k=1 fit still sees the full witness spectrum: the answer to "how many
    # dims explain frac of variance" is independent of the fitted k and
    # stays within [1, len(spectrum)]
    X = _gaussian(2, 100, 16)
    pca = PCATransform(k=1).fit(X)
    assert pca.dims_for_variance(0.0) == 1
    assert 1 <= pca.dims_for_variance(0.5) <= 16
    assert 1 <= pca.dims_for_variance(1.0) <= 16
    assert pca.transform(X).shape == (100, 1)


def test_pca_dims_for_variance_frac_one_clamped():
    # f32 cumsum can land just below 1.0: searchsorted then points one past
    # the spectrum and the old code returned k+1 dims
    X = _gaussian(3, 200, 12)
    pca = PCATransform(k=12).fit(X)
    d = pca.dims_for_variance(1.0)
    assert 1 <= d <= 12
    assert pca.dims_for_variance(0.0) >= 1


def test_pca_dims_for_variance_monotone_in_frac():
    X = _gaussian(4, 200, 16)
    pca = PCATransform(k=16).fit(X)
    dims = [pca.dims_for_variance(f) for f in (0.1, 0.5, 0.8, 0.95, 1.0)]
    assert dims == sorted(dims)


def test_pca_transform_centers_witness_mean():
    X = _gaussian(5, 150, 10) + 7.0
    pca = PCATransform(k=10).fit(X)
    Z = np.asarray(pca.transform(X))
    np.testing.assert_allclose(Z.mean(0), np.zeros(10), atol=1e-3)


# -- Achlioptas random projection -----------------------------------------


def test_rp_jl_distortion_bound():
    # JL: k = 256 rows preserve pairwise distances of n = 40 points within
    # eps ~ sqrt(8 ln n / k) ~ 0.34; assert a generous 0.5 on the *squared*
    # distance ratio (Achlioptas 2003, Thm 1.1)
    n, m, k = 40, 512, 256
    X = _gaussian(10, n, m)
    rp = RandomProjection(k=k).fit(m, key=jax.random.PRNGKey(0))
    Y = rp.transform(X)
    d_true = np.asarray(M.sqeuclidean_pdist(X, X))
    d_red = np.asarray(M.sqeuclidean_pdist(Y, Y))
    iu = np.triu_indices(n, 1)
    ratio = d_red[iu] / d_true[iu]
    assert float(np.max(np.abs(ratio - 1.0))) < 0.5


def test_rp_distortion_shrinks_with_k():
    X = _gaussian(11, 40, 512)
    d_true = np.asarray(M.sqeuclidean_pdist(X, X))
    iu = np.triu_indices(40, 1)
    worst = []
    for k in (16, 64, 256):
        rp = RandomProjection(k=k).fit(512, key=jax.random.PRNGKey(1))
        d_red = np.asarray(M.sqeuclidean_pdist(rp.transform(X),
                                               rp.transform(X)))
        worst.append(float(np.max(np.abs(d_red[iu] / d_true[iu] - 1.0))))
    assert worst[2] < worst[0]


def test_rp_seed_determinism():
    rp1 = RandomProjection(k=32).fit(128, key=jax.random.PRNGKey(7))
    rp2 = RandomProjection(k=32).fit(128, key=jax.random.PRNGKey(7))
    rp3 = RandomProjection(k=32).fit(128, key=jax.random.PRNGKey(8))
    assert np.array_equal(np.asarray(rp1.matrix), np.asarray(rp2.matrix))
    assert not np.array_equal(np.asarray(rp1.matrix), np.asarray(rp3.matrix))


def test_rp_achlioptas_entry_distribution():
    # entries are +-sqrt(3)/sqrt(k) w.p. 1/6 each and 0 w.p. 2/3
    m, k = 600, 200
    rp = RandomProjection(k=k).fit(m, key=jax.random.PRNGKey(2))
    A = np.asarray(rp.matrix) * np.sqrt(k)
    vals = np.unique(np.round(A, 5))
    s = round(float(np.sqrt(3.0)), 5)
    assert set(vals.tolist()) <= {-s, 0.0, s}
    frac_zero = float(np.mean(np.abs(A) < 1e-9))
    assert abs(frac_zero - 2.0 / 3.0) < 0.02


def test_rp_fit_from_witness_uses_its_width():
    X = _gaussian(12, 50, 96)
    rp = RandomProjection(k=16).fit(X, key=jax.random.PRNGKey(3))
    assert np.asarray(rp.matrix).shape == (96, 16)
    assert rp.transform(X).shape == (50, 16)


# -- classical MDS ---------------------------------------------------------


def test_mds_out_of_sample_map_consistent_on_witness():
    # the linear out-of-sample map must reproduce the witness's own
    # classical-MDS embedding (it was least-squares fitted to it)
    W = _gaussian(20, 120, 16)
    mds = MDSTransform(k=16).fit(W)
    Z = np.asarray(mds.transform(W))
    D_fit = np.asarray(M.euclidean_pdist(W, W))
    coords, _, _ = classical_mds_embed(jnp.asarray(D_fit), 16)
    np.testing.assert_allclose(
        np.asarray(M.euclidean_pdist(jnp.asarray(Z), jnp.asarray(Z))),
        np.asarray(M.euclidean_pdist(coords, coords)),
        atol=1e-2)


def test_mds_full_rank_preserves_distances():
    W = _gaussian(21, 80, 12)
    mds = MDSTransform(k=12).fit(W)
    Z = mds.transform(W)
    np.testing.assert_allclose(
        np.asarray(M.euclidean_pdist(Z, Z)),
        np.asarray(M.euclidean_pdist(W, W)), atol=1e-2)


def test_mds_translation_invariant_embedding():
    W = _gaussian(22, 60, 8)
    Z1 = MDSTransform(k=8).fit(W).transform(W)
    Z2 = MDSTransform(k=8).fit(W + 11.0).transform(W + 11.0)
    np.testing.assert_allclose(
        np.asarray(M.euclidean_pdist(Z1, Z1)),
        np.asarray(M.euclidean_pdist(Z2, Z2)), atol=2e-2)


def test_mds_accepts_precomputed_distance_matrix():
    W = _gaussian(23, 70, 10)
    D = M.euclidean_pdist(W, W)
    mds_d = MDSTransform(k=6).fit(W, D=D)
    mds_c = MDSTransform(k=6).fit(W)
    np.testing.assert_allclose(
        np.asarray(mds_d.transform(W)), np.asarray(mds_c.transform(W)),
        atol=1e-3)


# -- landmark MDS ----------------------------------------------------------


def test_lmds_distance_parity_with_coordinate_mds():
    # on Euclidean input, LMDS fitted purely from the landmark distance
    # matrix must reproduce the coordinate path's geometry
    L = _gaussian(30, 40, 12)
    D = M.euclidean_pdist(L, L)
    lmds = LMDSTransform(k=12).fit_from_distances(D)
    Z = lmds.transform_from_distances(D)
    np.testing.assert_allclose(
        np.asarray(M.euclidean_pdist(Z, Z)), np.asarray(D), atol=5e-2)


def test_lmds_out_of_sample_matches_witness_geometry():
    rng = np.random.default_rng(31)
    L = jnp.asarray(rng.normal(size=(30, 8)), jnp.float32)  # landmarks
    X = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)  # out-of-sample
    lmds = LMDSTransform(k=8).fit_from_distances(M.euclidean_pdist(L, L))
    Z = lmds.transform_from_distances(M.euclidean_pdist(X, L))
    np.testing.assert_allclose(
        np.asarray(M.euclidean_pdist(Z, Z)),
        np.asarray(M.euclidean_pdist(X, X)), atol=0.1)


def test_lmds_degenerate_spectrum_stays_bounded():
    # l == k forces near-zero trailing eigenvalues; the pseudo-inverse must
    # drop those directions instead of dividing by ~eps (regression: this
    # produced ~1e6-scale coordinates in the jsd quality workload)
    rng = np.random.default_rng(32)
    L = jnp.asarray(rng.normal(size=(12, 50)), jnp.float32)
    D = M.euclidean_pdist(L, L)
    lmds = LMDSTransform(k=12).fit_from_distances(D)
    X = jnp.asarray(rng.normal(size=(40, 50)), jnp.float32)
    Z = np.asarray(lmds.transform_from_distances(M.euclidean_pdist(X, L)))
    assert np.all(np.isfinite(Z))
    scale = float(np.abs(np.asarray(D)).max())
    assert float(np.abs(Z).max()) < 10 * scale


def test_lmds_jsd_distance_only_fit():
    # the differentiating capability: fitting a coordinate-free metric
    rng = np.random.default_rng(33)
    P = rng.uniform(size=(25, 64)).astype(np.float32)
    P /= P.sum(1, keepdims=True)
    P = jnp.asarray(P)
    D = M.jsd_pdist(P, P, assume_normalized=True)
    D = jnp.where(jnp.eye(25, dtype=bool), 0.0, D)
    lmds = LMDSTransform(k=6).fit_from_distances(D)
    Z = np.asarray(lmds.transform_from_distances(D))
    assert Z.shape == (25, 6)
    assert np.all(np.isfinite(Z))
    # embedded geometry correlates with the true JSD geometry
    iu = np.triu_indices(25, 1)
    d_emb = np.asarray(M.euclidean_pdist(jnp.asarray(Z), jnp.asarray(Z)))[iu]
    d_true = np.asarray(D)[iu]
    r = np.corrcoef(d_emb, d_true)[0, 1]
    assert r > 0.7
