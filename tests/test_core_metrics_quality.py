"""Metric definitions (paper Appendix A) and quality measures (Appendix E)."""
import numpy as np
import pytest
import scipy.stats

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fixed-seed replay keeps the suite green
    from _hypothesis_fallback import given, settings, st

from repro.core import metrics as M
from repro.core import quality as Q
from repro.core.baselines import (
    LMDSTransform,
    MDSTransform,
    PCATransform,
    RandomProjection,
    classical_mds_embed,
)

jax.config.update("jax_enable_x64", True)


# ----------------------------- metrics --------------------------------------


def test_euclidean_matches_numpy():
    rng = np.random.default_rng(0)
    X, Y = rng.normal(size=(20, 13)), rng.normal(size=(7, 13))
    got = np.asarray(M.euclidean_pdist(jnp.asarray(X), jnp.asarray(Y)))
    want = np.linalg.norm(X[:, None] - Y[None, :], axis=-1)
    np.testing.assert_allclose(got, want, atol=1e-10)


def test_cosine_is_l2_over_normalised():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(10, 8))
    Xn = X / np.linalg.norm(X, axis=1, keepdims=True)
    Xj = jnp.asarray(X)  # same object twice: exact-zero self-distance path
    got = np.asarray(M.cosine_pdist(Xj, Xj))
    want = np.linalg.norm(Xn[:, None] - Xn[None, :], axis=-1)
    np.testing.assert_allclose(got, want, atol=1e-10)


def test_jsd_range_and_symmetry():
    rng = np.random.default_rng(2)
    X = M.l1_normalize(jnp.asarray(rng.uniform(size=(12, 30))))
    D = np.asarray(M.jsd_pdist(X, X, assume_normalized=True))
    assert (D >= -1e-12).all() and (D <= 1.0 + 1e-9).all()
    np.testing.assert_allclose(D, D.T, atol=1e-10)
    np.testing.assert_allclose(np.diag(D), 0.0, atol=1e-6)


def test_jsd_zero_handling():
    # sparse vectors: 0 log 0 := 0 must not produce nan
    v = jnp.asarray([[0.5, 0.5, 0.0, 0.0], [0.0, 0.0, 0.5, 0.5]])
    D = M.jsd_pdist(v, v, assume_normalized=True)
    assert bool(jnp.isfinite(D).all())
    # disjoint supports -> maximal JSD distance 1
    np.testing.assert_allclose(float(D[0, 1]), 1.0, atol=1e-7)


def test_triangular_estimates_jsd():
    # paper Appendix A.4: triangular is an accurate JSD estimator in high dims
    rng = np.random.default_rng(3)
    X = M.l1_normalize(jnp.asarray(rng.uniform(size=(20, 200))))
    J = np.asarray(M.jsd_pdist(X, X, assume_normalized=True))
    T = np.asarray(M.triangular_pdist(X, X, assume_normalized=True))
    mask = ~np.eye(20, dtype=bool)
    # accurate estimator (same ordering, ~10% magnitude) in high dimensions
    assert np.abs(J - T)[mask].mean() < 0.15 * J[mask].mean()
    assert Q.spearman_rho(J[mask], T[mask]) > 0.99


def test_qform_reduces_to_euclidean():
    rng = np.random.default_rng(4)
    X, Y = rng.normal(size=(6, 5)), rng.normal(size=(4, 5))
    got = np.asarray(M.qform_pdist(jnp.asarray(X), jnp.asarray(Y), jnp.eye(5)))
    want = np.asarray(M.euclidean_pdist(jnp.asarray(X), jnp.asarray(Y)))
    np.testing.assert_allclose(got, want, atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 16), m=st.integers(2, 32))
def test_property_metric_axioms(seed, n, m):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, m)))
    for name in ("euclidean", "cosine"):
        D = np.asarray(M.pairwise(name, X, X))
        np.testing.assert_allclose(D, D.T, atol=1e-8)
        assert (D >= -1e-9).all()
        i, j, k = rng.integers(0, n, size=(3, 50))
        assert (D[i, k] <= D[i, j] + D[j, k] + 1e-7).all()


# ----------------------------- quality --------------------------------------


def test_pava_monotone_and_ls():
    y = np.array([3.0, 1.0, 2.0, 5.0, 4.0])
    fit = Q._pava(y)
    assert (np.diff(fit) >= -1e-12).all()
    np.testing.assert_allclose(fit, [2.0, 2.0, 2.0, 4.5, 4.5])


def test_kruskal_zero_for_monotone_map():
    rng = np.random.default_rng(5)
    delta = rng.uniform(1, 10, size=500)
    zeta = np.sqrt(delta) * 3.0  # monotone, nonlinear
    assert Q.kruskal_stress(delta, zeta) < 1e-12
    assert Q.spearman_rho(delta, zeta) > 0.999999


def test_spearman_matches_scipy():
    rng = np.random.default_rng(6)
    a, b = rng.normal(size=300), rng.normal(size=300)
    got = Q.spearman_rho(a, b)
    want = scipy.stats.spearmanr(a, b).statistic
    np.testing.assert_allclose(got, want, atol=1e-10)


def test_spearman_matches_scipy_with_ties():
    """Quantized / near-equidistant corpora produce exact distance ties;
    tie-averaged ranks must reproduce scipy's rho, where dense integer
    ranks would order ties arbitrarily and drift."""
    rng = np.random.default_rng(8)
    a = rng.integers(0, 12, size=400).astype(float)
    b = a + rng.integers(-2, 3, size=400)  # correlated, still heavily tied
    got = Q.spearman_rho(a, b)
    want = scipy.stats.spearmanr(a, b).statistic
    np.testing.assert_allclose(got, want, atol=1e-10)


def test_spearman_all_ties_and_degenerate_inputs():
    # fewer than two pairs: correlation undefined, must be NaN (not a crash)
    assert np.isnan(Q.spearman_rho([], []))
    assert np.isnan(Q.spearman_rho([3.0], [5.0]))
    # a constant margin has zero rank variance: also undefined
    assert np.isnan(Q.spearman_rho([2.0, 2.0, 2.0], [1.0, 5.0, 3.0]))


def test_sammon_and_qloss_zero_when_exact():
    d = np.random.default_rng(7).uniform(1, 5, size=100)
    assert Q.sammon_stress(d, d) == 0.0
    assert Q.quadratic_loss(d, d) == 0.0


def test_dcg_recall_perfect_and_disjoint():
    ids = np.arange(1000)
    assert Q.dcg_recall(ids, ids) == pytest.approx(1.0)
    assert Q.dcg_recall(ids, ids + 10_000) == pytest.approx(0.0, abs=1e-5)


def test_recall_at_k_hand_computed():
    # perfect agreement (order-insensitive)
    assert Q.recall_at_k([[1, 2, 3]], [[3, 1, 2]]) == 1.0
    # partial overlap: {1,2} of 4 -> 0.5
    assert Q.recall_at_k([[1, 2, 3, 4]], [[2, 1, 9, 8]]) == 0.5
    # disjoint
    assert Q.recall_at_k([[1, 2]], [[3, 4]]) == 0.0
    # batch mean: 1.0 and 0.5 -> 0.75
    assert Q.recall_at_k([[1, 2], [3, 4]], [[2, 1], [3, 9]]) == 0.75
    # 1D convenience form
    assert Q.recall_at_k([5, 6], [6, 7]) == 0.5


def test_recall_at_k_ignores_padding_ids():
    # -1 slots (clustered/sharded padding) never count as hits
    assert Q.recall_at_k([[0, 1]], [[-1, 1]]) == 0.5
    assert Q.recall_at_k([[0, 1]], [[-1, -1]]) == 0.0


def test_recall_at_k_mismatched_batch_raises():
    with pytest.raises(ValueError):
        Q.recall_at_k([[1, 2], [3, 4]], [[1, 2]])


def test_dcg_recall_discriminates_at_serving_k():
    """Eq. 34's sigmoid must scale with the list length n: at k=10 a
    shuffled result list scores strictly below the perfect one (a fixed
    n=1000 midpoint would rate every rank <=10 as ~0.993-relevant and
    grade any shuffle ~1.0)."""
    ids = np.arange(10)
    shuffled = np.array([9, 4, 7, 1, 8, 0, 5, 3, 6, 2])
    assert Q.dcg_recall(ids, ids) == pytest.approx(1.0)
    assert Q.dcg_recall(ids, shuffled) < 0.95
    # reversal is the worst same-set ordering: strictly below a mild swap
    swap = ids.copy()
    swap[0], swap[1] = swap[1], swap[0]
    assert Q.dcg_recall(ids, ids[::-1]) < Q.dcg_recall(ids, swap) < 1.0


def test_rank_relevance_midpoint_scales_with_n():
    # Eq. 34: midpoint n/2 (relevance 0.5), width n/10
    for n in (10, 100, 1000):
        assert Q.rank_relevance(n / 2, n) == pytest.approx(0.5)
        assert Q.rank_relevance(1, n) > 0.98
        assert Q.rank_relevance(n, n) < 0.01
    # head ranks separate at small n instead of saturating
    assert Q.rank_relevance(1, 10) - Q.rank_relevance(10, 10) > 0.9


def test_dcg_recall_prefers_early_agreement():
    ids = np.arange(1000)
    # swap within the head (significant region) vs within the tail
    head = ids.copy(); head[:10] = head[:10][::-1]
    tail = ids.copy(); tail[-10:] = tail[-10:][::-1]
    assert Q.dcg_recall(ids, tail) > Q.dcg_recall(ids, head) or (
        Q.dcg_recall(ids, tail) == pytest.approx(1.0, abs=1e-6)
    )
    assert Q.dcg_recall(ids, head) > 0.9  # head swaps are still near neighbours


# ----------------------------- baselines ------------------------------------


def test_pca_recovers_low_rank():
    rng = np.random.default_rng(8)
    Z5 = rng.normal(size=(400, 5))
    A = rng.normal(size=(5, 64))
    X = jnp.asarray(Z5 @ A)  # rank-5 manifold in R^64
    pca = PCATransform(k=5).fit(X)
    assert pca.dims_for_variance(0.999) <= 5
    Xp = pca.transform(X)
    D0 = np.asarray(M.euclidean_pdist(X, X))
    D1 = np.asarray(M.euclidean_pdist(Xp, Xp))
    # float32 SVD path: off-diagonal distances agree to f32 noise
    mask = ~np.eye(D0.shape[0], dtype=bool)
    np.testing.assert_allclose(D1[mask], D0[mask], rtol=1e-3)


def test_rp_preserves_distances_statistically():
    rng = np.random.default_rng(9)
    X = jnp.asarray(rng.normal(size=(100, 512)))
    rp = RandomProjection(k=128).fit(512, key=jax.random.PRNGKey(0))
    Xp = rp.transform(X)
    d0 = np.asarray(M.euclidean_pdist(X, X))
    d1 = np.asarray(M.euclidean_pdist(Xp, Xp))
    mask = ~np.eye(100, dtype=bool)
    ratio = d1[mask] / d0[mask]
    assert abs(ratio.mean() - 1.0) < 0.05
    assert ratio.std() < 0.15


def test_classical_mds_recovers_euclidean_config():
    rng = np.random.default_rng(10)
    X = rng.normal(size=(50, 4))
    D = np.linalg.norm(X[:, None] - X[None, :], axis=-1)
    coords, evals, _ = classical_mds_embed(jnp.asarray(D), 4)
    D2 = np.asarray(M.euclidean_pdist(coords, coords))
    np.testing.assert_allclose(D2, D, rtol=1e-4, atol=1e-5)


def test_mds_out_of_sample_linear_map():
    rng = np.random.default_rng(11)
    W = jnp.asarray(rng.normal(size=(120, 16)))
    mds = MDSTransform(k=16).fit(W)
    X = jnp.asarray(rng.normal(size=(30, 16)))
    D0 = np.asarray(M.euclidean_pdist(X, X))
    D1 = np.asarray(M.euclidean_pdist(mds.transform(X), mds.transform(X)))
    # full-rank k=m: must be near-isometric
    np.testing.assert_allclose(D1, D0, rtol=1e-3, atol=1e-4)


def test_lmds_matches_mds_on_landmarks():
    rng = np.random.default_rng(12)
    L = rng.normal(size=(40, 6))
    D = np.linalg.norm(L[:, None] - L[None, :], axis=-1)
    lmds = LMDSTransform(k=6).fit_from_distances(jnp.asarray(D))
    emb = lmds.transform_from_distances(jnp.asarray(D))
    D1 = np.asarray(M.euclidean_pdist(emb, emb))
    np.testing.assert_allclose(D1, D, rtol=1e-3, atol=1e-4)


def test_lmds_distance_only_jsd_space():
    rng = np.random.default_rng(13)
    L = M.l1_normalize(jnp.asarray(rng.uniform(size=(30, 50))))
    X = M.l1_normalize(jnp.asarray(rng.uniform(size=(20, 50))))
    DL = M.jsd_pdist(L, L, assume_normalized=True)
    lmds = LMDSTransform(k=10).fit_from_distances(DL)
    emb = lmds.transform_from_distances(M.jsd_pdist(X, L, assume_normalized=True))
    assert emb.shape == (20, 10)
    assert bool(jnp.isfinite(emb).all())
