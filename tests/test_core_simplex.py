"""Core nSimplex correctness: TPU-native path vs paper-faithful oracle, and the
paper's bound/estimator properties (Lemma C.2) as hypothesis property tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: fixed-seed replay keeps the suite green
    from _hypothesis_fallback import given, settings, st

from repro.core import metrics as M
from repro.core import simplex as S
from repro.core import zen as Z
from repro.core.projection import NSimplexTransform, select_references

jax.config.update("jax_enable_x64", True)


def _euclid_space(seed, n, m, k):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, m))
    refs = rng.normal(size=(k, m))
    return X, refs


@pytest.mark.parametrize("k", [2, 3, 8, 33])
def test_base_simplex_matches_paper_oracle(k):
    _, refs = _euclid_space(0, 1, 64, k)
    D = np.array(M.euclidean_pdist(jnp.asarray(refs), jnp.asarray(refs)))
    np.fill_diagonal(D, 0.0)
    sigma_oracle = S.nsimplex_build_reference(D)
    base = S.build_base_simplex(D)
    np.testing.assert_allclose(np.asarray(base.vertices()), sigma_oracle, atol=1e-9)


@pytest.mark.parametrize("k", [2, 5, 16])
def test_base_simplex_reconstructs_distances(k):
    _, refs = _euclid_space(1, 1, 32, k)
    D = np.array(M.euclidean_pdist(jnp.asarray(refs), jnp.asarray(refs)))
    np.fill_diagonal(D, 0.0)
    ok, err = S.verify_base_simplex(D, S.build_base_simplex(D), atol=1e-8)
    assert ok, f"distance reconstruction error {err}"


@pytest.mark.parametrize("k,n", [(2, 7), (10, 50), (31, 11)])
def test_apex_matches_paper_oracle(k, n):
    X, refs = _euclid_space(2, n, 48, k)
    D = np.array(M.euclidean_pdist(jnp.asarray(refs), jnp.asarray(refs)))
    np.fill_diagonal(D, 0.0)
    dists = np.asarray(M.euclidean_pdist(jnp.asarray(X), jnp.asarray(refs)))
    apex_oracle = S.apex_project_reference(D, dists)
    apex = np.asarray(S.apex_project(S.build_base_simplex(D), dists))
    np.testing.assert_allclose(apex, apex_oracle, atol=1e-8)


def test_apex_preserves_reference_distances():
    # l2(apex, vertex_i) == d(u, r_i): the defining property of the projection.
    X, refs = _euclid_space(3, 20, 64, 12)
    tr = NSimplexTransform(k=12).fit(jnp.asarray(refs))
    dists = np.asarray(tr.reference_distances(jnp.asarray(X)))
    apex = np.asarray(tr.transform(jnp.asarray(X)))
    V = np.asarray(tr.base.vertices())  # (k, k-1)
    Vfull = np.concatenate([V, np.zeros((V.shape[0], 1))], axis=1)  # embed in R^k
    got = np.linalg.norm(apex[:, None, :] - Vfull[None, :, :], axis=-1)
    np.testing.assert_allclose(got, dists, atol=1e-8)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(16, 128),
    k=st.integers(2, 16),
    n=st.integers(2, 24),
)
def test_property_bounds_euclidean(seed, m, k, n):
    """Lemma C.2: lwb <= d <= upb and lwb <= zen <= upb, any Euclidean space.

    k <= m so the random reference simplex is non-degenerate (k > m+1 points
    in R^m CANNOT be affinely independent — the library contract, paper §7.2,
    is to redraw such reference sets; select_references does)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, m))
    refs = rng.normal(size=(k, m))
    tr = NSimplexTransform(k=k).fit(jnp.asarray(refs))
    Xp = tr.transform(jnp.asarray(X))
    Dt = np.asarray(M.euclidean_pdist(jnp.asarray(X), jnp.asarray(X)))
    lwb, zen, upb = [np.asarray(a) for a in Z.estimate_triple(Xp, Xp)]
    tol = 1e-6 * (1.0 + Dt.max())
    assert (lwb <= Dt + tol).all()
    assert (Dt <= upb + tol).all()
    assert (lwb <= zen + tol).all() and (zen <= upb + tol).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(2, 10), n=st.integers(2, 12))
def test_property_bounds_jsd(seed, k, n):
    """Bounds hold for the coordinate-free Jensen-Shannon Hilbert space."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.uniform(0.05, 1.0, size=(n, 40)))
    R = jnp.asarray(rng.uniform(0.05, 1.0, size=(k, 40)))
    X, R = M.l1_normalize(X), M.l1_normalize(R)
    D_refs = np.array(M.jsd_pdist(R, R, assume_normalized=True))
    np.fill_diagonal(D_refs, 0.0)
    tr = NSimplexTransform.from_distances(D_refs)
    dX = M.jsd_pdist(X, R, assume_normalized=True)
    Xp = tr.transform_from_distances(dX)
    Dt = np.asarray(M.jsd_pdist(X, X, assume_normalized=True))
    lwb, zen, upb = [np.asarray(a) for a in Z.estimate_triple(Xp, Xp)]
    tol = 2e-5
    assert (lwb <= Dt + tol).all()
    assert (Dt <= upb + tol).all()
    assert (lwb <= zen + tol).all() and (zen <= upb + tol).all()


def test_zen_triangle_inequality_sampled():
    """Zen is not a metric (no identity) but keeps the triangle inequality."""
    rng = np.random.default_rng(7)
    X, refs = _euclid_space(7, 64, 100, 8)
    tr = NSimplexTransform(k=8).fit(jnp.asarray(refs))
    Xp = tr.transform(jnp.asarray(X))
    D = np.asarray(Z.zen_pdist(Xp, Xp))
    i, j, l = rng.integers(0, 64, size=(3, 500))
    assert (D[i, l] <= D[i, j] + D[j, l] + 1e-9).all()


def test_zen_self_distance_positive():
    # paper §7.1: Zen(x, x) = sqrt(2) * altitude > 0 — by design, not a bug.
    X, refs = _euclid_space(9, 10, 64, 6)
    tr = NSimplexTransform(k=6).fit(jnp.asarray(refs))
    Xp = np.asarray(tr.transform(jnp.asarray(X)))
    zen_self = np.diag(np.asarray(Z.zen_pdist(Xp, Xp)))
    np.testing.assert_allclose(zen_self, np.sqrt(2.0) * Xp[:, -1], atol=1e-9)


def test_contraction_property():
    # sigma is a contraction: l2(sigma(u), sigma(v)) <= d(u, v)  (paper §4.1)
    X, refs = _euclid_space(11, 40, 200, 24)
    tr = NSimplexTransform(k=24).fit(jnp.asarray(refs))
    Xp = tr.transform(jnp.asarray(X))
    Dt = np.asarray(M.euclidean_pdist(jnp.asarray(X), jnp.asarray(X)))
    lwb = np.asarray(Z.lwb_pdist(Xp, Xp))
    assert (lwb <= Dt + 1e-6 * (1.0 + Dt.max())).all()


def test_degenerate_detection():
    # duplicate reference -> rank-deficient simplex must be flagged
    rng = np.random.default_rng(5)
    refs = rng.normal(size=(6, 16))
    refs[3] = refs[1]  # duplicate
    D = np.array(M.euclidean_pdist(jnp.asarray(refs), jnp.asarray(refs)))
    np.fill_diagonal(D, 0.0)
    base = S.build_base_simplex(D)
    assert bool(S.simplex_is_degenerate(base))


def test_select_references_avoids_degenerate():
    rng = np.random.default_rng(6)
    X = rng.normal(size=(100, 32))
    tr = select_references(jnp.asarray(X), 8, jax.random.PRNGKey(0))
    assert tr.is_fitted and not bool(tr.degenerate())


def test_knn_search_chunked_equals_dense():
    rng = np.random.default_rng(8)
    X, refs = _euclid_space(8, 300, 64, 16)
    q = rng.normal(size=(9, 64))
    tr = NSimplexTransform(k=16).fit(jnp.asarray(refs))
    Xp, Qp = tr.transform(jnp.asarray(X)), tr.transform(jnp.asarray(q))
    d0, i0 = Z.knn_search(Qp, Xp, n_neighbors=5)
    d1, i1 = Z.knn_search(Qp, Xp, n_neighbors=5, chunk=64)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-9)
    assert (np.asarray(i0) == np.asarray(i1)).all()


def test_zen_estimator_beats_lwb_in_high_dims():
    """The paper's headline effect: Zen tracks true distance closely."""
    rng = np.random.default_rng(10)
    X = rng.uniform(size=(200, 100))
    refs = rng.uniform(size=(10, 100))
    tr = NSimplexTransform(k=10).fit(jnp.asarray(refs))
    Xp = tr.transform(jnp.asarray(X))
    Dt = np.asarray(M.euclidean_pdist(jnp.asarray(X), jnp.asarray(X)))
    lwb, zen, _ = [np.asarray(a) for a in Z.estimate_triple(Xp, Xp)]
    mask = ~np.eye(200, dtype=bool)
    zen_err = np.abs(zen - Dt)[mask].mean()
    lwb_err = np.abs(lwb - Dt)[mask].mean()
    assert zen_err < 0.25 * lwb_err
