"""End-to-end trainer integration: loss decreases, checkpoint/restart is
exact, compression path runs, CLI entrypoint works."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.checkpoint import CheckpointManager
from repro.data import synthetic as syn
from repro.models import transformer as tfm
from repro.optim import AdamW
from repro.optim import compression as comp_lib

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def _train(cfg, params, opt_state, start, steps, comp_state=None):
    opt = AdamW(learning_rate=1e-3)

    @jax.jit
    def step_fn(params, opt_state, comp_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: tfm.loss_fn(cfg, p, batch), has_aux=True)(params)
        if comp_state is not None:
            grads, comp_state = comp_lib.error_feedback_update(grads, comp_state)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda a, b: a + b, params, updates)
        return params, opt_state, comp_state, loss

    losses = []
    for s in range(start, start + steps):
        batch = syn.lm_batch(0, s, 4, 32, cfg.vocab_size)
        params, opt_state, comp_state, loss = step_fn(
            params, opt_state, comp_state, batch)
        losses.append(float(loss))
    return params, opt_state, comp_state, losses


def test_loss_decreases_and_restart_is_exact(tmp_path):
    cfg = C.get_arch("qwen1.5-0.5b").make_reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = AdamW(learning_rate=1e-3).init(params)

    # run 8 steps, checkpoint at 4
    p4, o4, _, losses_a = _train(cfg, params, opt_state, 0, 4)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, (p4, o4))
    p8, o8, _, losses_b = _train(cfg, p4, o4, 4, 4)
    assert losses_b[-1] < losses_a[0], "loss must decrease"

    # restart from the checkpoint: steps 4..8 must be bit-identical
    step, (rp, ro) = mgr.restore(like=(p4, o4))
    assert step == 4
    p8r, _, _, losses_r = _train(cfg, rp, ro, 4, 4)
    np.testing.assert_array_equal(np.asarray(losses_b), np.asarray(losses_r))
    for a, b in zip(jax.tree.leaves(p8), jax.tree.leaves(p8r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compressed_training_converges():
    cfg = C.get_arch("qwen1.5-0.5b").make_reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    opt_state = AdamW(learning_rate=1e-3).init(params)
    comp_state = comp_lib.init_state(params)
    _, _, comp_state, losses = _train(
        cfg, params, opt_state, 0, 8, comp_state=comp_state)
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)
    # error buffers are being populated
    err = sum(float(jnp.sum(jnp.abs(e))) for e in jax.tree.leaves(comp_state.error))
    assert err > 0


@pytest.mark.slow
def test_train_cli_with_resume(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    ckpt = str(tmp_path / "ckpt")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "qwen1.5-0.5b", "--reduced", "--steps", "12", "--batch", "2",
           "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "5"]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done" in r.stdout
    r2 = subprocess.run(cmd + ["--resume"], capture_output=True, text=True,
                        env=env, timeout=900)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step" in r2.stdout
