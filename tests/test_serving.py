"""Serving stack: index build, zen top-k quality, exact re-rank, stats,
and the non-Euclidean (jsd / qform) build -> churn -> save/load lifecycle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.data import synthetic as syn
from repro.launch.serve import ZenServer, build_index


def _recall(ids, true_ids):
    ids, true_ids = np.asarray(ids), np.asarray(true_ids)
    return np.mean([
        len(set(ids[i]) & set(true_ids[i])) / ids.shape[1]
        for i in range(ids.shape[0])
    ])


def test_zen_server_end_to_end():
    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, 5000, 128, 16)
    index = build_index(corpus, 16)
    assert index.coords.shape == (5000, 16)

    server = ZenServer(index, rerank_factor=8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 16, 128, 16)
    d, ids = server.query(q, 10)
    assert d.shape == (16, 10) and ids.shape == (16, 10)
    # monotone non-decreasing distances per row
    assert bool((jnp.diff(d, axis=1) >= -1e-6).all())

    true_d = M.euclidean_pdist(q, corpus)
    _, tids = jax.lax.top_k(-true_d, 10)
    rec = _recall(ids, tids)
    assert rec > 0.8, f"recall@10 with rerank too low: {rec}"

    stats = server.stats()
    assert stats["queries"] == 16 and stats["batches"] == 1
    assert stats["p50_ms"] > 0


def test_zen_server_rerank_improves_recall():
    key = jax.random.PRNGKey(2)
    corpus = syn.manifold_space(key, 4000, 128, 8)
    index = build_index(corpus, 8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 12, 128, 8)
    true_d = M.euclidean_pdist(q, corpus)
    _, tids = jax.lax.top_k(-true_d, 10)

    plain = ZenServer(index, rerank_factor=0)
    rerank = ZenServer(index, rerank_factor=10)
    _, ids0 = plain.query(q, 10)
    _, ids1 = rerank.query(q, 10)
    assert _recall(ids1, tids) >= _recall(ids0, tids)


def test_zen_server_chunked_path():
    key = jax.random.PRNGKey(3)
    corpus = syn.uniform_space(key, 3000, 64)
    index = build_index(corpus, 8)
    server = ZenServer(index, chunk=512)  # forces the scan path
    q = syn.uniform_space(jax.random.fold_in(key, 1), 4, 64)
    d, ids = server.query(q, 5)
    # must agree with the dense path
    dense = ZenServer(index, chunk=10**9)
    d2, ids2 = dense.query(q, 5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d2), rtol=1e-5)
    assert (np.asarray(ids) == np.asarray(ids2)).all()


def test_index_distance_only_metric():
    # cosine corpus goes through the metric-aware normalisation path
    key = jax.random.PRNGKey(4)
    corpus = syn.relu_feature_space(key, 2000, 96, 12)
    index = build_index(corpus, 10, metric="cosine")
    server = ZenServer(index, rerank_factor=4)
    q = syn.relu_feature_space(jax.random.fold_in(key, 1), 8, 96, 12)
    d, ids = server.query(q, 5)
    assert bool(jnp.isfinite(d).all())


# -- non-Euclidean end-to-end lifecycle (jsd / qform) --------------------------


def _noneuclid_corpus(metric, key, n, m):
    """Vectors in the metric's natural domain, with genuine neighbour
    structure (uniform simplex vectors are nearly equidistant under JSD —
    recall over them measures noise, not the pipeline)."""
    if metric == "jsd":  # clustered probability vectors (paper §5.6)
        return syn.probability_space(key, n, m, max(4, m // 8))
    return syn.manifold_space(key, n, m, max(4, m // 8))


@pytest.mark.parametrize("metric", ["jsd", "qform"])
@pytest.mark.parametrize("index_kind", ["flat", "ivf"])
def test_noneuclid_serving_lifecycle(metric, index_kind, tmp_path):
    """build -> query -> churn (upsert/delete/compact) -> save/load under
    the non-Euclidean registry metrics the serving stack never exercised
    beyond euclidean/cosine. The fitted transform must keep projecting
    unseen objects (the paper's out-of-sample property holds for every
    Hilbert-embeddable metric, not just l2)."""
    key = jax.random.PRNGKey(11)
    corpus = _noneuclid_corpus(metric, key, 3000, 64)
    index = build_index(corpus, 12, metric=metric, index=index_kind,
                        n_clusters=32 if index_kind == "ivf" else None)
    server = ZenServer(index, chunk=512, nprobe=32, rerank_factor=4)
    q = _noneuclid_corpus(metric, jax.random.fold_in(key, 1), 8, 64)

    d, ids = server.query(q, 10)
    assert d.shape == (8, 10) and bool(jnp.isfinite(d).all())
    assert bool((ids >= 0).all())
    assert bool((jnp.diff(d, axis=1) >= -1e-6).all())

    # recall against the true metric over the original space (the exact
    # re-rank orders the pool by the true metric, so this measures the
    # whole projection + candidate-generation + re-rank pipeline)
    true_d = M.pairwise(metric, q, corpus)
    _, tids = jax.lax.top_k(-true_d, 10)
    rec = _recall(ids, tids)
    assert rec > 0.7, f"{metric}/{index_kind}: recall {rec}"

    # churn: project-and-insert unseen objects, tombstone others
    extra = _noneuclid_corpus(metric, jax.random.fold_in(key, 2), 60, 64)
    server.upsert(np.arange(3000, 3060), extra)
    server.delete(np.arange(25))
    assert server.index.size == 3000 + 60 - 25
    d2, ids2 = server.query(q, 10)
    assert bool(jnp.isfinite(d2).all())
    deleted_hits = np.intersect1d(np.asarray(ids2).ravel(), np.arange(25))
    assert deleted_hits.size == 0
    # the new rows are findable: querying with an upserted row's own vector
    # must surface that row first. Zen(x, x) is *not* 0 (the zenith
    # estimator adds both altitudes — rows with smaller altitude can
    # outrank the point itself), so probe with the Lwb estimator, whose
    # self-distance is exactly 0, sharing the same churned index; the
    # re-rank then pins the true-distance-0 row to rank 1.
    lwb = ZenServer(server.index, mode="lwb", chunk=512, nprobe=32,
                    rerank_factor=4)
    d3, ids3 = lwb.query(extra[:4], 5)
    np.testing.assert_array_equal(
        np.asarray(ids3)[:, 0], np.arange(3000, 3004))
    # sqrt turns the f32 cancellation noise of a zero jsd kernel into
    # ~sqrt(eps) — self-distances are "zero" only at that scale
    assert np.asarray(d3)[:, 0].max() < 1e-2

    server.compact()
    d4, ids4 = server.query(q, 10)

    # persistence: reload answers identically
    server.save(str(tmp_path / "snap"))
    back = ZenServer.load(str(tmp_path / "snap"), chunk=512, nprobe=32)
    assert back.index.transform.metric == metric
    d5, ids5 = back.query(q, 10)
    np.testing.assert_array_equal(np.asarray(ids4), np.asarray(ids5))
    np.testing.assert_array_equal(np.asarray(d4), np.asarray(d5))


@pytest.mark.parametrize("metric", ["jsd", "qform"])
def test_noneuclid_exact_rerank_uses_true_metric(metric):
    """rerank orders the candidate pool by the *registry* metric — for jsd
    that is the Jensen-Shannon distance itself, not a Euclidean surrogate."""
    from repro.index.ivf import exact_rerank

    key = jax.random.PRNGKey(12)
    corpus = _noneuclid_corpus(metric, key, 300, 32)
    q = _noneuclid_corpus(metric, jax.random.fold_in(key, 1), 4, 32)
    cand = jnp.tile(jnp.arange(300, dtype=jnp.int32), (4, 1))
    d, ids = exact_rerank(q, corpus, cand, 5, metric=metric)
    true_d = np.asarray(M.pairwise(metric, q, corpus))
    want = np.sort(true_d, axis=1)[:, :5]
    np.testing.assert_allclose(np.asarray(d), want, rtol=1e-5, atol=1e-6)


def test_noneuclid_quantized_ivf_serving():
    """storage="int8" composes with a non-Euclidean metric end to end."""
    key = jax.random.PRNGKey(13)
    corpus = _noneuclid_corpus("jsd", key, 2000, 48)
    index = build_index(corpus, 10, metric="jsd", index="ivf",
                        n_clusters=24, storage="int8")
    assert index.ivf.tile_scales is not None
    f32 = build_index(corpus, 10, metric="jsd", index="ivf", n_clusters=24)
    # enough queries that one near-tie flip (1/(Q*10) of recall) stays far
    # below the 0.02 acceptance bar
    q = _noneuclid_corpus("jsd", jax.random.fold_in(key, 1), 32, 48)
    _, i_q = ZenServer(index, nprobe=24).query(q, 10)
    _, i_f = ZenServer(f32, nprobe=24).query(q, 10)
    # same bar as the Euclidean parity suite: recall against the true
    # metric moves by at most 0.02 (raw id overlap would also count
    # equidistant near-tie flips that change nothing about quality)
    true_d = M.pairwise("jsd", q, corpus)
    _, tids = jax.lax.top_k(-true_d, 10)
    assert abs(_recall(i_q, tids) - _recall(i_f, tids)) <= 0.02
