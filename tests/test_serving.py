"""Serving stack: index build, zen top-k quality, exact re-rank, stats,
and the non-Euclidean (jsd / qform) build -> churn -> save/load lifecycle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.data import synthetic as syn
from repro.launch.serve import ZenServer, build_index


def _recall(ids, true_ids):
    ids, true_ids = np.asarray(ids), np.asarray(true_ids)
    return np.mean([
        len(set(ids[i]) & set(true_ids[i])) / ids.shape[1]
        for i in range(ids.shape[0])
    ])


def test_zen_server_end_to_end():
    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, 5000, 128, 16)
    index = build_index(corpus, 16)
    assert index.coords.shape == (5000, 16)

    server = ZenServer(index, rerank_factor=8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 16, 128, 16)
    d, ids = server.query(q, 10)
    assert d.shape == (16, 10) and ids.shape == (16, 10)
    # monotone non-decreasing distances per row
    assert bool((jnp.diff(d, axis=1) >= -1e-6).all())

    true_d = M.euclidean_pdist(q, corpus)
    _, tids = jax.lax.top_k(-true_d, 10)
    rec = _recall(ids, tids)
    assert rec > 0.8, f"recall@10 with rerank too low: {rec}"

    stats = server.stats()
    assert stats["queries"] == 16 and stats["batches"] == 1
    assert stats["p50_ms"] > 0


def test_zen_server_rerank_improves_recall():
    key = jax.random.PRNGKey(2)
    corpus = syn.manifold_space(key, 4000, 128, 8)
    index = build_index(corpus, 8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 12, 128, 8)
    true_d = M.euclidean_pdist(q, corpus)
    _, tids = jax.lax.top_k(-true_d, 10)

    plain = ZenServer(index, rerank_factor=0)
    rerank = ZenServer(index, rerank_factor=10)
    _, ids0 = plain.query(q, 10)
    _, ids1 = rerank.query(q, 10)
    assert _recall(ids1, tids) >= _recall(ids0, tids)


def test_zen_server_chunked_path():
    key = jax.random.PRNGKey(3)
    corpus = syn.uniform_space(key, 3000, 64)
    index = build_index(corpus, 8)
    server = ZenServer(index, chunk=512)  # forces the scan path
    q = syn.uniform_space(jax.random.fold_in(key, 1), 4, 64)
    d, ids = server.query(q, 5)
    # must agree with the dense path
    dense = ZenServer(index, chunk=10**9)
    d2, ids2 = dense.query(q, 5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d2), rtol=1e-5)
    assert (np.asarray(ids) == np.asarray(ids2)).all()


def test_index_distance_only_metric():
    # cosine corpus goes through the metric-aware normalisation path
    key = jax.random.PRNGKey(4)
    corpus = syn.relu_feature_space(key, 2000, 96, 12)
    index = build_index(corpus, 10, metric="cosine")
    server = ZenServer(index, rerank_factor=4)
    q = syn.relu_feature_space(jax.random.fold_in(key, 1), 8, 96, 12)
    d, ids = server.query(q, 5)
    assert bool(jnp.isfinite(d).all())


# -- non-Euclidean end-to-end lifecycle (jsd / qform) --------------------------


def _noneuclid_corpus(metric, key, n, m):
    """Vectors in the metric's natural domain, with genuine neighbour
    structure (uniform simplex vectors are nearly equidistant under JSD —
    recall over them measures noise, not the pipeline)."""
    if metric == "jsd":  # clustered probability vectors (paper §5.6)
        return syn.probability_space(key, n, m, max(4, m // 8))
    return syn.manifold_space(key, n, m, max(4, m // 8))


@pytest.mark.parametrize("metric", ["jsd", "qform"])
@pytest.mark.parametrize("index_kind", ["flat", "ivf"])
def test_noneuclid_serving_lifecycle(metric, index_kind, tmp_path):
    """build -> query -> churn (upsert/delete/compact) -> save/load under
    the non-Euclidean registry metrics the serving stack never exercised
    beyond euclidean/cosine. The fitted transform must keep projecting
    unseen objects (the paper's out-of-sample property holds for every
    Hilbert-embeddable metric, not just l2)."""
    key = jax.random.PRNGKey(11)
    corpus = _noneuclid_corpus(metric, key, 3000, 64)
    index = build_index(corpus, 12, metric=metric, index=index_kind,
                        n_clusters=32 if index_kind == "ivf" else None)
    server = ZenServer(index, chunk=512, nprobe=32, rerank_factor=4)
    q = _noneuclid_corpus(metric, jax.random.fold_in(key, 1), 8, 64)

    d, ids = server.query(q, 10)
    assert d.shape == (8, 10) and bool(jnp.isfinite(d).all())
    assert bool((ids >= 0).all())
    assert bool((jnp.diff(d, axis=1) >= -1e-6).all())

    # recall against the true metric over the original space (the exact
    # re-rank orders the pool by the true metric, so this measures the
    # whole projection + candidate-generation + re-rank pipeline)
    true_d = M.pairwise(metric, q, corpus)
    _, tids = jax.lax.top_k(-true_d, 10)
    rec = _recall(ids, tids)
    assert rec > 0.7, f"{metric}/{index_kind}: recall {rec}"

    # churn: project-and-insert unseen objects, tombstone others
    extra = _noneuclid_corpus(metric, jax.random.fold_in(key, 2), 60, 64)
    server.upsert(np.arange(3000, 3060), extra)
    server.delete(np.arange(25))
    assert server.index.size == 3000 + 60 - 25
    d2, ids2 = server.query(q, 10)
    assert bool(jnp.isfinite(d2).all())
    deleted_hits = np.intersect1d(np.asarray(ids2).ravel(), np.arange(25))
    assert deleted_hits.size == 0
    # the new rows are findable: querying with an upserted row's own vector
    # must surface that row first. Zen(x, x) is *not* 0 (the zenith
    # estimator adds both altitudes — rows with smaller altitude can
    # outrank the point itself), so probe with the Lwb estimator, whose
    # self-distance is exactly 0, sharing the same churned index; the
    # re-rank then pins the true-distance-0 row to rank 1.
    lwb = ZenServer(server.index, mode="lwb", chunk=512, nprobe=32,
                    rerank_factor=4)
    d3, ids3 = lwb.query(extra[:4], 5)
    np.testing.assert_array_equal(
        np.asarray(ids3)[:, 0], np.arange(3000, 3004))
    # sqrt turns the f32 cancellation noise of a zero jsd kernel into
    # ~sqrt(eps) — self-distances are "zero" only at that scale
    assert np.asarray(d3)[:, 0].max() < 1e-2

    server.compact()
    d4, ids4 = server.query(q, 10)

    # persistence: reload answers identically
    server.save(str(tmp_path / "snap"))
    back = ZenServer.load(str(tmp_path / "snap"), chunk=512, nprobe=32)
    assert back.index.transform.metric == metric
    d5, ids5 = back.query(q, 10)
    np.testing.assert_array_equal(np.asarray(ids4), np.asarray(ids5))
    np.testing.assert_array_equal(np.asarray(d4), np.asarray(d5))


@pytest.mark.parametrize("metric", ["jsd", "qform"])
def test_noneuclid_exact_rerank_uses_true_metric(metric):
    """rerank orders the candidate pool by the *registry* metric — for jsd
    that is the Jensen-Shannon distance itself, not a Euclidean surrogate."""
    from repro.index.ivf import exact_rerank

    key = jax.random.PRNGKey(12)
    corpus = _noneuclid_corpus(metric, key, 300, 32)
    q = _noneuclid_corpus(metric, jax.random.fold_in(key, 1), 4, 32)
    cand = jnp.tile(jnp.arange(300, dtype=jnp.int32), (4, 1))
    d, ids = exact_rerank(q, corpus, cand, 5, metric=metric)
    true_d = np.asarray(M.pairwise(metric, q, corpus))
    want = np.sort(true_d, axis=1)[:, :5]
    np.testing.assert_allclose(np.asarray(d), want, rtol=1e-5, atol=1e-6)


# -- tiered (host-offloaded) serving + degraded shards -------------------------


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _tiered_pair(key, n=3000, storage="float32"):
    """(tiered server, resident server, queries) over the same corpus."""
    corpus = syn.manifold_space(key, n, 64, 8)
    kw = dict(metric="euclidean", index="ivf", n_clusters=32, storage=storage)
    tiered = build_index(corpus, 12, offload=True, hot_clusters=4,
                         offload_shards=4, **kw)
    resident = build_index(corpus, 12, **kw)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 16, 64, 8)
    return (ZenServer(tiered, nprobe=8), ZenServer(resident, nprobe=8),
            jnp.asarray(q))


@pytest.mark.parametrize("storage", ["float32", "int8"])
def test_tiered_offload_matches_resident(storage):
    """Host-offloaded serving returns the same neighbours as the
    all-resident index at equal nprobe (same kernel, same tiles — only
    partitioned into hot + streamed-cold passes)."""
    tiered_srv, resident_srv, q = _tiered_pair(
        jax.random.PRNGKey(21), storage=storage)
    d_t, i_t = tiered_srv.query(q, 10)
    d_r, i_r = resident_srv.query(q, 10)
    np.testing.assert_array_equal(np.asarray(i_t), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(d_t), np.asarray(d_r),
                               rtol=1e-5, atol=1e-5)
    tier = tiered_srv.stats()["tier"]
    assert tier["cold_uploads"] > 0  # the cold path actually ran
    assert tier["hot_clusters"] == 4
    assert tier["bytes_uploaded"] > 0
    # the resident arrays on device are the hot subset, not the full pool
    # (the device/host *ratio* at scale is the benchmark's acceptance bar;
    # at this toy size the double-buffer allowance dominates device_bytes)
    assert tiered_srv.index.ivf._hot_coords.shape[0] < (
        np.asarray(tiered_srv.index.ivf.host_coords).shape[0])


def test_tiered_index_is_serve_only():
    tiered_srv, _, q = _tiered_pair(jax.random.PRNGKey(22))
    with pytest.raises(NotImplementedError):
        tiered_srv.delete([1, 2])
    with pytest.raises(NotImplementedError):
        tiered_srv.upsert([9999], np.zeros((1, 64), np.float32))
    with pytest.raises(NotImplementedError):
        tiered_srv.compact()
    assert not tiered_srv.index.needs_compact()
    assert not tiered_srv.maybe_compact()
    d, ids = tiered_srv.query(q, 10)  # still serves
    assert bool(jnp.isfinite(d).all())


def test_offload_requires_single_host_ivf():
    corpus = syn.manifold_space(jax.random.PRNGKey(23), 500, 32, 4)
    with pytest.raises(ValueError, match="ivf"):
        build_index(corpus, 8, offload=True)  # flat index cannot offload


def test_degraded_shard_serving_end_to_end(tmp_path):
    """Kill one logical shard's heartbeat mid-serving: queries keep
    answering (no raise), recall drops, ``stats()["degraded_shards"]``
    reports the outage, and a recovered heartbeat restores full recall."""
    clock = _Clock()
    tiered_srv, resident_srv, q = _tiered_pair(jax.random.PRNGKey(24))
    reg = tiered_srv.enable_fault_tolerance(deadline_s=5.0, clock=clock)
    assert reg.expected() == [f"shard{i}" for i in range(4)]
    for s in range(4):
        tiered_srv.heartbeat(s)

    _, true_ids = resident_srv.query(q, 10)
    _, ids_healthy = tiered_srv.query(q, 10)
    assert tiered_srv.stats()["degraded_shards"] == []
    rec_healthy = _recall(ids_healthy, true_ids)
    assert rec_healthy == 1.0

    clock.t = 6.0  # shard2 misses its deadline; the rest keep beating
    for s in (0, 1, 3):
        tiered_srv.heartbeat(s)
    d_deg, ids_deg = tiered_srv.query(q, 10)  # must not raise
    st = tiered_srv.stats()
    assert st["degraded_shards"] == ["shard2"]
    assert st["tier"]["masked_clusters"] == 8  # 32 clusters / 4 shards
    rec_degraded = _recall(ids_deg, true_ids)
    assert rec_degraded < rec_healthy
    assert bool(jnp.isfinite(d_deg).any())
    # the dead shard's clusters (c % 4 == 2) contribute no results
    assign = np.asarray(tiered_srv.index.ivf.host_ids)
    dead_members = set(
        assign.reshape(32, -1)[2::4].ravel().tolist()) - {-1}
    assert not (set(np.asarray(ids_deg).ravel().tolist()) & dead_members)

    clock.t = 7.0  # shard2 comes back
    tiered_srv.heartbeat(2)
    _, ids_back = tiered_srv.query(q, 10)
    assert tiered_srv.stats()["degraded_shards"] == []
    assert _recall(ids_back, true_ids) == rec_healthy


def test_preemption_triggers_snapshot_at_tick(tmp_path):
    """A preemption notice saves a full server snapshot at the next query
    tick; the snapshot reloads and answers identically (healthy state)."""
    tiered_srv, _, q = _tiered_pair(jax.random.PRNGKey(25))
    snap = str(tmp_path / "preempt")
    tiered_srv.enable_fault_tolerance(
        deadline_s=1e9, clock=_Clock(), snapshot_dir=snap)
    d0, i0 = tiered_srv.query(q, 10)
    tiered_srv.preemption.request()  # platform SIGTERM, modelled manually
    tiered_srv.query(q, 10)          # tick boundary: save fires here
    assert not tiered_srv.preemption.should_save()  # cleared after saving
    back = ZenServer.load(snap)
    d1, i1 = back.query(q, 10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-5, atol=1e-5)


def test_degraded_mesh_flat_serving():
    """The on-mesh alive mask degrades a row-sharded flat index the same
    way: dead shard's rows vanish from results, queries never raise."""
    import os
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.data import synthetic as syn
from repro.launch.serve import ZenServer, build_index

class Clock:
    t = 0.0
    def __call__(self): return self.t

key = jax.random.PRNGKey(31)
corpus = syn.manifold_space(key, 1024, 32, 4)
mesh = Mesh(np.array(jax.devices()).reshape(4), ("shard",))
index = build_index(corpus, 8, mesh=mesh)
srv = ZenServer(index)
clock = Clock()
srv.enable_fault_tolerance(deadline_s=5.0, clock=clock)
for s in range(4):
    srv.heartbeat(s)
q = syn.manifold_space(jax.random.fold_in(key, 1), 8, 32, 4)
d0, i0 = srv.query(q, 10)
clock.t = 6.0
for s in (0, 1, 3):
    srv.heartbeat(s)
d1, i1 = srv.query(q, 10)
assert srv.stats()["degraded_shards"] == ["shard2"]
assert np.isfinite(np.asarray(d1)).any()
# shard 2 owns rows [512, 768): none may appear while it is dead
hits = np.asarray(i1).ravel()
assert not ((hits >= 512) & (hits < 768)).any()
assert not np.array_equal(np.asarray(i0), np.asarray(i1))
print("DEGRADED-MESH-OK")
"""
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
    )
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=600)
    assert "DEGRADED-MESH-OK" in out.stdout, out.stderr[-2000:]


def test_noneuclid_quantized_ivf_serving():
    """storage="int8" composes with a non-Euclidean metric end to end."""
    key = jax.random.PRNGKey(13)
    corpus = _noneuclid_corpus("jsd", key, 2000, 48)
    index = build_index(corpus, 10, metric="jsd", index="ivf",
                        n_clusters=24, storage="int8")
    assert index.ivf.tile_scales is not None
    f32 = build_index(corpus, 10, metric="jsd", index="ivf", n_clusters=24)
    # enough queries that one near-tie flip (1/(Q*10) of recall) stays far
    # below the 0.02 acceptance bar
    q = _noneuclid_corpus("jsd", jax.random.fold_in(key, 1), 32, 48)
    _, i_q = ZenServer(index, nprobe=24).query(q, 10)
    _, i_f = ZenServer(f32, nprobe=24).query(q, 10)
    # same bar as the Euclidean parity suite: recall against the true
    # metric moves by at most 0.02 (raw id overlap would also count
    # equidistant near-tie flips that change nothing about quality)
    true_d = M.pairwise("jsd", q, corpus)
    _, tids = jax.lax.top_k(-true_d, 10)
    assert abs(_recall(i_q, tids) - _recall(i_f, tids)) <= 0.02
