"""Serving stack: index build, zen top-k quality, exact re-rank, stats."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.data import synthetic as syn
from repro.launch.serve import ZenServer, build_index


def _recall(ids, true_ids):
    ids, true_ids = np.asarray(ids), np.asarray(true_ids)
    return np.mean([
        len(set(ids[i]) & set(true_ids[i])) / ids.shape[1]
        for i in range(ids.shape[0])
    ])


def test_zen_server_end_to_end():
    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, 5000, 128, 16)
    index = build_index(corpus, 16)
    assert index.coords.shape == (5000, 16)

    server = ZenServer(index, rerank_factor=8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 16, 128, 16)
    d, ids = server.query(q, 10)
    assert d.shape == (16, 10) and ids.shape == (16, 10)
    # monotone non-decreasing distances per row
    assert bool((jnp.diff(d, axis=1) >= -1e-6).all())

    true_d = M.euclidean_pdist(q, corpus)
    _, tids = jax.lax.top_k(-true_d, 10)
    rec = _recall(ids, tids)
    assert rec > 0.8, f"recall@10 with rerank too low: {rec}"

    stats = server.stats()
    assert stats["queries"] == 16 and stats["batches"] == 1
    assert stats["p50_ms"] > 0


def test_zen_server_rerank_improves_recall():
    key = jax.random.PRNGKey(2)
    corpus = syn.manifold_space(key, 4000, 128, 8)
    index = build_index(corpus, 8)
    q = syn.manifold_space(jax.random.fold_in(key, 1), 12, 128, 8)
    true_d = M.euclidean_pdist(q, corpus)
    _, tids = jax.lax.top_k(-true_d, 10)

    plain = ZenServer(index, rerank_factor=0)
    rerank = ZenServer(index, rerank_factor=10)
    _, ids0 = plain.query(q, 10)
    _, ids1 = rerank.query(q, 10)
    assert _recall(ids1, tids) >= _recall(ids0, tids)


def test_zen_server_chunked_path():
    key = jax.random.PRNGKey(3)
    corpus = syn.uniform_space(key, 3000, 64)
    index = build_index(corpus, 8)
    server = ZenServer(index, chunk=512)  # forces the scan path
    q = syn.uniform_space(jax.random.fold_in(key, 1), 4, 64)
    d, ids = server.query(q, 5)
    # must agree with the dense path
    dense = ZenServer(index, chunk=10**9)
    d2, ids2 = dense.query(q, 5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d2), rtol=1e-5)
    assert (np.asarray(ids) == np.asarray(ids2)).all()


def test_index_distance_only_metric():
    # cosine corpus goes through the metric-aware normalisation path
    key = jax.random.PRNGKey(4)
    corpus = syn.relu_feature_space(key, 2000, 96, 12)
    index = build_index(corpus, 10, metric="cosine")
    server = ZenServer(index, rerank_factor=4)
    q = syn.relu_feature_space(jax.random.fold_in(key, 1), 8, 96, 12)
    d, ids = server.query(q, 5)
    assert bool(jnp.isfinite(d).all())
