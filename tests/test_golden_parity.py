"""Golden oracle parity: the serving stack's exact output is pinned.

``tests/golden/serving_golden.npz`` (committed; regenerated only by
``tools/make_golden.py``) holds a fixed-seed corpus plus the expected
top-k ids *and* distances of every major retrieval configuration — flat
f32, IVF at ``nprobe = n_clusters`` (exact) and at a partial probe, int8
and product-quantised (pq) storage, exact re-rank, the jsd/qform
non-Euclidean paths, a replica served through a publish -> churn ->
hot-swap cycle (mmap'd, ``launch.replicate``), the pivot ids every
``core.pivots`` strategy
selects over the fixed-seed corpus, and the baseline-reducer block:
pca/rp/lmds reduced query coordinates at ``BASELINE_K`` plus the
per-query recall@10 of zen and pca on an isotropic gaussian corpus
(the paper's low-k ordering regime, pinned so it cannot silently flip). Any PR
that shifts these bits — a kernel rewrite, an estimator reorder, a
quantisation change — fails here instead of drifting silently; an
*intentional* numerical change regenerates the file in the same commit.
"""
import importlib.util
import os

import numpy as np
import pytest

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "serving_golden.npz")
_TOOL = os.path.join(os.path.dirname(__file__), os.pardir, "tools",
                     "make_golden.py")


def _load_tool():
    spec = importlib.util.spec_from_file_location("make_golden", _TOOL)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def golden():
    with np.load(GOLDEN) as f:
        return {k: f[k] for k in f.files}


@pytest.fixture(scope="module")
def tool():
    return _load_tool()


def test_golden_file_is_complete(golden, tool):
    """Every pinned case (and its corpus) is present, with sane shapes."""
    for space in ("euclid", "jsd"):
        assert golden[f"corpus_{space}"].shape == (tool.N, tool.DIM)
        assert golden[f"queries_{space}"].shape == (tool.Q, tool.DIM)
    for name in tool.CASES:
        assert golden[f"{name}_d"].shape == (tool.Q, tool.NN)
        assert golden[f"{name}_ids"].shape == (tool.Q, tool.NN)
        assert golden[f"{name}_d"].dtype == np.float32
        assert golden[f"{name}_ids"].dtype == np.int32
    for strategy in ("random", "kmeanspp", "farthest_first", "maxvol"):
        ids = golden[f"pivots_{strategy}_ids"]
        assert ids.shape == (tool.K,) and ids.dtype == np.int32
        assert len(set(ids.tolist())) == tool.K
    assert golden["corpus_gauss"].shape == (tool.N, tool.DIM)
    assert golden["queries_gauss"].shape == (tool.Q, tool.DIM)
    for name in ("pca", "rp", "lmds"):
        assert golden[f"baseline_{name}_coords"].shape == (
            tool.Q, tool.BASELINE_K)
        assert golden[f"baseline_{name}_coords"].dtype == np.float32
    for name in ("zen", "pca", "rp", "lmds"):
        rec = golden[f"baseline_recall_{name}"]
        assert rec.shape == (tool.Q,) and rec.dtype == np.float32
        assert np.all((rec >= 0.0) & (rec <= 1.0))


@pytest.mark.parametrize("name", [
    "flat_zen", "flat_lwb", "ivf_exact", "ivf_probe4", "flat_int8",
    "ivf_int8", "flat_rerank", "flat_jsd", "ivf_qform", "ivf_pq",
    "ivf_pq_rerank", "ivf_replica_served",
])
def test_case_matches_golden(golden, tool, name):
    """Re-running a pinned configuration reproduces the committed bits."""
    d, ids = tool.run_case(name, golden)
    np.testing.assert_array_equal(
        ids, golden[f"{name}_ids"],
        err_msg=f"{name}: neighbour ids drifted from the golden file")
    np.testing.assert_array_equal(
        d, golden[f"{name}_d"],
        err_msg=f"{name}: distances drifted from the golden file "
                "(bit-exact comparison; regenerate via tools/make_golden.py "
                "only for an intentional numerical change)")


@pytest.mark.parametrize("strategy", [
    "random", "kmeanspp", "farthest_first", "maxvol",
])
def test_pivot_selection_matches_golden(golden, tool, strategy):
    """Each pivot strategy re-chooses exactly the committed pivot ids on
    the fixed-seed corpus — the selection pipeline (witness subsample,
    metric matrix, greedy/stochastic rule) is pinned end to end."""
    got = tool.pivot_golden(golden)[f"pivots_{strategy}_ids"]
    np.testing.assert_array_equal(
        got, golden[f"pivots_{strategy}_ids"],
        err_msg=f"pivot strategy {strategy!r} chose different pivots")


def test_baseline_reducers_match_golden(golden, tool):
    """pca/rp/lmds reduced coordinates and the zen/pca recall arrays are
    re-derived bit-identically from the committed gaussian corpus — pins
    ``core.baselines`` + the ``core.reducers`` protocol end to end."""
    regen = tool.baseline_golden(golden)
    for key in sorted(regen):
        np.testing.assert_array_equal(
            regen[key], golden[key],
            err_msg=f"baseline golden array {key!r} drifted")


def test_baseline_recall_ordering_zen_above_pca(golden):
    """The committed bits themselves witness the paper's low-k claim: on
    an isotropic corpus at k=4, zen's mean recall@10 strictly dominates
    the coordinate baselines' best (PCA has no low-rank structure to
    exploit there). Checked from the file, no recomputation."""
    zen = float(golden["baseline_recall_zen"].mean())
    pca = float(golden["baseline_recall_pca"].mean())
    assert zen >= pca


def test_ivf_full_probe_equals_flat(golden):
    """nprobe = n_clusters recovers the flat scan exactly — pinned both
    as a cross-check between two golden cases (no recomputation)."""
    np.testing.assert_array_equal(golden["ivf_exact_ids"],
                                  golden["flat_zen_ids"])
    np.testing.assert_array_equal(golden["ivf_exact_d"],
                                  golden["flat_zen_d"])


def test_regen_script_reproduces_committed_file(golden, tool):
    """``tools/make_golden.py`` regenerates the committed file bit-for-bit
    — the synthetic-data pipeline and every configuration are jointly
    deterministic, so the golden file can always be audited by rerunning
    the script."""
    regen = tool.build_golden()
    assert set(regen) == set(golden), "golden array set changed"
    for key in sorted(regen):
        np.testing.assert_array_equal(
            regen[key], golden[key],
            err_msg=f"regenerated array {key!r} differs from the "
                    "committed golden file")
