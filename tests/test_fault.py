"""Fault-tolerance hooks (``distributed.fault``), driven by fake clocks.

Every contract here is deterministic: step times are fed directly to the
StepMonitor, heartbeats advance an injected monotonic clock, and the
preemption guard is triggered manually — no real time, signals or threads.
"""
import numpy as np
import pytest

from repro.distributed.fault import (
    HeartbeatRegistry,
    PreemptionGuard,
    StepMonitor,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ----------------------------- StepMonitor -----------------------------------


def test_step_monitor_flags_straggler_after_warmup():
    mon = StepMonitor(warmup_steps=3, threshold=2.0)
    for s in range(6):
        assert mon.record(s, 1.0) is None
    ev = mon.record(6, 5.0)
    assert ev is not None
    assert ev.ratio == pytest.approx(5.0)
    assert mon.events == [ev]


def test_step_monitor_warmup_straggler_never_inflates_ema():
    """A straggler landing *during* warmup must not fold into the EMA —
    absorbing it would raise the bar enough to hide later stragglers."""
    mon = StepMonitor(warmup_steps=5, threshold=2.0, ema_decay=0.9)
    mon.record(0, 1.0)
    assert mon.record(1, 10.0) is None  # warmup: not flagged...
    assert mon.ema == pytest.approx(1.0)  # ...and not averaged in
    for s in range(2, 6):
        mon.record(s, 1.0)
    # a genuine 3x straggler after warmup is still visible
    assert mon.record(6, 3.0) is not None


def test_step_monitor_escalates_after_patience():
    mon = StepMonitor(warmup_steps=1, threshold=2.0, patience=3)
    for s in range(4):
        mon.record(s, 1.0)
    for s in range(4, 6):
        mon.record(s, 5.0)
        assert not mon.should_escalate
    mon.record(6, 5.0)
    assert mon.should_escalate


def test_step_monitor_normal_step_resets_patience():
    mon = StepMonitor(warmup_steps=1, threshold=2.0, patience=2)
    for s in range(4):
        mon.record(s, 1.0)
    mon.record(4, 5.0)
    mon.record(5, 1.0)  # recovered: consecutive count resets
    mon.record(6, 5.0)
    assert not mon.should_escalate


def test_step_monitor_ema_tracks_normal_steps():
    mon = StepMonitor(warmup_steps=0, ema_decay=0.5)
    mon.record(0, 1.0)
    mon.record(1, 2.0)  # within threshold: folds in
    assert mon.ema == pytest.approx(1.5)


# --------------------------- HeartbeatRegistry --------------------------------


def test_registry_alive_and_dead_transitions():
    clock = FakeClock()
    reg = HeartbeatRegistry(deadline_s=10.0, now=clock)
    reg.beat("a")
    reg.beat("b")
    assert reg.alive() == ["a", "b"] and reg.dead_hosts() == []
    clock.advance(11.0)
    reg.beat("a")
    assert reg.dead_hosts() == ["b"]
    assert reg.alive() == ["a"]
    reg.beat("b")  # b recovers
    assert reg.dead_hosts() == []


def test_registry_registered_but_never_beat_is_reported_dead():
    """Silence from birth must be indistinguishable from an early crash:
    a host the deployment *expects* (register) but that never beats goes
    dead one deadline after registration."""
    clock = FakeClock()
    reg = HeartbeatRegistry(deadline_s=5.0, now=clock)
    reg.register("ghost")
    reg.beat("live")
    assert reg.expected() == ["ghost", "live"]
    assert reg.dead_hosts() == []  # within its first deadline
    clock.advance(6.0)
    reg.beat("live")
    assert reg.dead_hosts() == ["ghost"]


def test_registry_register_is_idempotent():
    clock = FakeClock()
    reg = HeartbeatRegistry(deadline_s=5.0, now=clock)
    reg.register("a")
    clock.advance(4.0)
    reg.register("a")  # must NOT refresh the registration deadline
    clock.advance(2.0)
    assert reg.dead_hosts() == ["a"]


def test_registry_beat_implicitly_registers():
    clock = FakeClock()
    reg = HeartbeatRegistry(deadline_s=5.0, now=clock)
    reg.beat("x")
    assert reg.expected() == ["x"]
    clock.advance(6.0)
    assert reg.dead_hosts() == ["x"]


def test_registry_empty_membership():
    reg = HeartbeatRegistry(deadline_s=1.0, now=FakeClock())
    assert reg.expected() == [] and reg.dead_hosts() == [] and reg.alive() == []


# ---------------------------- PreemptionGuard ---------------------------------


def test_preemption_guard_request_save_clear_cycle():
    guard = PreemptionGuard(install_signal=False)
    assert not guard.should_save()
    guard.request()
    assert guard.should_save()
    assert guard.should_save()  # sticky until cleared
    guard.clear()
    assert not guard.should_save()
