"""End-to-end driver: batched k-NN retrieval serving over an nSimplex-Zen
reduced index (the paper's production use case).

Pipeline: synthesise a 100k x 512 corpus on a manifold -> build the reduced
index (k = 24) -> serve 16 query batches of 128 with the *streaming* Zen
top-k (never materialises the (Q, N) estimator matrix; peak per-query memory
is one --chunk tile) + exact re-rank -> report recall vs brute force and
latency percentiles. ``--sharded`` row-shards the reduced index over every
local device and searches per shard with a host-side candidate merge.

``--index ivf`` swaps the flat streaming scan for the clustered IVF path
(k-means coarse quantizer + inverted-list probes, ``repro.index``): each
query scores only its ``--nprobe`` nearest clusters — sublinear in N — and
the script prints the recall/QPS comparison against the flat scan.
``--sharded --index ivf`` row-shards the inverted lists per device.

``--churn`` exercises the mutable corpus lifecycle (delete 10%, upsert
replacements through the already-fitted transform, auto-compact);
``--checkpoint DIR`` saves the server and verifies a load round-trip
returns identical results (see docs/architecture.md).

Run:  PYTHONPATH=src python examples/serve_retrieval.py [--n 100000]
      PYTHONPATH=src python examples/serve_retrieval.py --sharded \
          [--chunk 8192]
      PYTHONPATH=src python examples/serve_retrieval.py --index ivf \
          [--nprobe 16 --clusters 0]
      PYTHONPATH=src python examples/serve_retrieval.py --index ivf \
          --churn --checkpoint /tmp/zen.ckpt
"""
import argparse
import time

import numpy as np

import jax

from repro.core import metrics as M
from repro.data import synthetic as syn
from repro.launch.serve import ZenIndex, ZenServer, build_index


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=100_000)
    p.add_argument("--dim", type=int, default=512)
    p.add_argument("--k", type=int, default=24)
    p.add_argument("--batches", type=int, default=16)
    p.add_argument("--batch-size", type=int, default=128)
    p.add_argument("--neighbors", type=int, default=10)
    p.add_argument("--chunk", type=int, default=8192,
                   help="streaming tile: per-query peak memory bound")
    p.add_argument("--sharded", action="store_true",
                   help="row-shard the index over all local devices")
    p.add_argument("--index", default="flat", choices=["flat", "ivf"],
                   help="flat streaming scan or clustered IVF probes")
    p.add_argument("--nprobe", type=int, default=32,
                   help="clusters probed per query (ivf only)")
    p.add_argument("--clusters", type=int, default=0,
                   help="IVF cluster count (0 = ~4*sqrt(N))")
    p.add_argument("--churn", action="store_true",
                   help="after serving, delete 10%% of the corpus and "
                        "upsert replacements, then keep serving")
    p.add_argument("--checkpoint", default=None, metavar="DIR",
                   help="save the server to DIR and verify a load "
                        "round-trip returns identical results")
    p.add_argument("--frontend", action="store_true",
                   help="serve through the micro-batching frontend: the "
                        "query batches are re-played as many single-query "
                        "callers, coalesced into shape-bucketed dispatches "
                        "(repro.serving), and checked bit-identical "
                        "against the direct path")
    p.add_argument("--max-batch", type=int, default=64,
                   help="largest coalesced dispatch (frontend mode)")
    p.add_argument("--cache", type=int, default=0, metavar="ROWS",
                   help="LRU result-cache rows (frontend mode; 0 disables)")
    args = p.parse_args()

    key = jax.random.PRNGKey(0)
    print(f"corpus: {args.n} x {args.dim} (manifold intrinsic dim "
          f"{args.dim // 16})")
    corpus = syn.manifold_space(key, args.n, args.dim, args.dim // 16)

    mesh = None
    if args.sharded:
        from jax.sharding import Mesh
        mesh = Mesh(np.asarray(jax.devices()), ("shard",))
        print(f"sharding index rows over {len(jax.devices())} device(s)")

    t0 = time.time()
    index = build_index(corpus, args.k, mesh=mesh, index=args.index,
                        n_clusters=args.clusters or None)
    print(f"index built in {time.time() - t0:.1f}s: "
          f"{index.size} x {args.k} "
          f"({args.dim * 4 / (args.k * 4):.0f}x memory reduction)"
          + (f"; ivf: {index.ivf.n_clusters} clusters" if index.ivf is not None
             else ""))

    server = ZenServer(index, rerank_factor=8, chunk=args.chunk,
                       nprobe=args.nprobe)
    flat_server = None
    if index.ivf is not None:  # flat baseline over the same coordinates
        flat_index = ZenIndex(transform=index.transform, coords=index.coords,
                              corpus=index.corpus)
        flat_server = ZenServer(flat_index, rerank_factor=8, chunk=args.chunk)
    recalls, flat_recalls = [], []
    for b in range(args.batches):
        q = syn.manifold_space(
            jax.random.fold_in(key, 100 + b), args.batch_size, args.dim,
            args.dim // 16)
        d, ids = server.query(q, args.neighbors)
        # ground truth by brute force in the original space
        true_d = M.euclidean_pdist(q, corpus)
        _, tids = jax.lax.top_k(-true_d, args.neighbors)
        ids_np, tids_np = np.asarray(ids), np.asarray(tids)
        recalls.append(np.mean([
            len(set(ids_np[i]) & set(tids_np[i])) / args.neighbors
            for i in range(args.batch_size)
        ]))
        if flat_server is not None:
            _, fids = flat_server.query(q, args.neighbors)
            fids_np = np.asarray(fids)
            flat_recalls.append(np.mean([
                len(set(fids_np[i]) & set(tids_np[i])) / args.neighbors
                for i in range(args.batch_size)
            ]))
    label = "ivf + rerank" if index.ivf is not None else "zen + rerank"
    print(f"recall@{args.neighbors} ({label}): {np.mean(recalls):.3f}")
    print("serving stats:", server.stats())
    if flat_server is not None:
        fs, ss = flat_server.stats(), server.stats()
        print(f"flat streaming baseline: recall@{args.neighbors} "
              f"{np.mean(flat_recalls):.3f}, p50 {fs['p50_ms']:.1f} ms "
              f"(ivf p50 {ss['p50_ms']:.1f} ms, nprobe={args.nprobe}/"
              f"{index.ivf.n_clusters})")

    if args.frontend and not args.sharded:
        # re-play one batch as many single-query callers through the
        # micro-batching frontend; every coalesced/padded/cached response
        # must be bit-identical to the direct path
        fe = ZenServer(index, rerank_factor=8, chunk=args.chunk,
                       nprobe=args.nprobe, frontend=True,
                       max_batch=args.max_batch, cache_size=args.cache)
        q = syn.manifold_space(jax.random.fold_in(key, 400),
                               args.batch_size, args.dim, args.dim // 16)
        qn = np.asarray(q, np.float32)
        t0 = time.time()
        handles = [fe.frontend.submit(qn[i], args.neighbors)
                   for i in range(args.batch_size)]
        fe.frontend.flush()
        rows = [h.result() for h in handles]
        t_fe = time.time() - t0
        d_direct, i_direct = fe.query(q, args.neighbors, direct=True)
        same = all(
            np.array_equal(rows[i][0][0], np.asarray(d_direct)[i])
            and np.array_equal(rows[i][1][0], np.asarray(i_direct)[i])
            for i in range(args.batch_size))
        st = fe.frontend.stats
        print(f"frontend: {args.batch_size} callers coalesced into "
              f"{st.dispatches} dispatch(es) in {t_fe:.3f}s "
              f"({args.batch_size / t_fe:.0f} qps), occupancy "
              f"{st.occupancy:.2f}, compile_count {st.compile_count}, "
              f"bit-identical to direct: {same}")
        if args.cache:
            for i in range(args.batch_size):  # hot replay: all hits
                fe.frontend.submit(qn[i], args.neighbors)
            fe.frontend.flush()
            print(f"frontend cache: {fe.frontend.cache.info()}")

    if args.churn and not args.sharded:
        # mutable corpus lifecycle: delete 10% of ids, upsert replacements
        # (projected with the already-fitted transform), keep serving
        rng = np.random.default_rng(0)
        n_churn = args.n // 10
        dead = rng.choice(args.n, size=n_churn, replace=False)
        t0 = time.time()
        server.delete(dead)
        t_del = time.time() - t0
        fresh = syn.manifold_space(jax.random.fold_in(key, 999), n_churn,
                                   args.dim, args.dim // 16)
        t0 = time.time()
        server.upsert(np.arange(args.n, args.n + n_churn), fresh)
        t_up = time.time() - t0
        compacted = server.maybe_compact()
        q = syn.manifold_space(jax.random.fold_in(key, 200), args.batch_size,
                               args.dim, args.dim // 16)
        _, ids = server.query(q, args.neighbors)
        assert not (set(dead.tolist())
                    & set(np.asarray(ids).ravel().tolist()))
        print(f"churn: {n_churn} deletes in {t_del:.2f}s "
              f"({n_churn / t_del:.0f}/s), {n_churn} upserts in {t_up:.2f}s "
              f"({n_churn / t_up:.0f}/s), compacted={compacted}, "
              f"live={server.index.size}")

    if args.checkpoint and not args.sharded:
        server.save(args.checkpoint)
        restored = ZenServer.load(args.checkpoint)
        q = syn.manifold_space(jax.random.fold_in(key, 300), args.batch_size,
                               args.dim, args.dim // 16)
        d0, i0 = server.query(q, args.neighbors)
        d1, i1 = restored.query(q, args.neighbors)
        same = bool(np.array_equal(np.asarray(i0), np.asarray(i1)))
        print(f"checkpoint: saved + reloaded from {args.checkpoint}; "
              f"round-trip identical results: {same}")


if __name__ == "__main__":
    main()
