"""Train a reduced LM config with the full production substrate on CPU:
checkpoint/restart (kill it mid-run and re-invoke with --resume), straggler
monitoring, deterministic data pipeline — then reduce its token embeddings
with nSimplex Zen (the DESIGN.md §4 integration point).

Run:  PYTHONPATH=src python examples/train_lm.py
      PYTHONPATH=src python examples/train_lm.py --resume   # restart path
"""
import argparse
import os
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.checkpoint import CheckpointManager
from repro.core import quality, select_references, zen_pdist
from repro.core.metrics import euclidean_pdist
from repro.data import synthetic as syn
from repro.data.pipeline import PrefetchPipeline
from repro.distributed.fault import StepMonitor
from repro.models import transformer as tfm
from repro.optim import AdamW


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=40)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--ckpt-dir", default=os.path.join(tempfile.gettempdir(),
                                                      "repro_train_lm"))
    args = p.parse_args()

    cfg = C.get_arch("qwen1.5-0.5b").make_reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=1e-3)
    opt_state = opt.init(params)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start, (params, opt_state) = ckpt.restore(like=(params, opt_state))
        print(f"resumed at step {start}")

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: tfm.loss_fn(cfg, p, batch), has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return jax.tree.map(lambda a, b: a + b, params, updates), opt_state, loss

    monitor = StepMonitor()
    pipe = PrefetchPipeline(
        lambda s: syn.lm_batch(0, s, 8, 64, cfg.vocab_size), start_step=start)
    losses = []
    try:
        for _ in range(args.steps - start):
            step, batch = next(pipe)
            t0 = time.time()
            params, opt_state, loss = step_fn(params, opt_state, batch)
            monitor.record(step, time.time() - t0)
            losses.append(float(loss))
            if step % 10 == 0:
                print(f"step {step}: loss={losses[-1]:.3f}")
            if (step + 1) % 20 == 0:
                ckpt.save_async(step + 1, (params, opt_state))
    finally:
        pipe.close()
        ckpt.wait()
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")

    # --- nSimplex-Zen over the learned embedding space ----------------------
    emb = params["embed"][:2000].astype(jnp.float32)
    tr = select_references(emb, 16, jax.random.PRNGKey(7))
    red = tr.transform(emb)
    d_true = np.asarray(euclidean_pdist(emb[:300], emb[:300]))
    d_zen = np.asarray(zen_pdist(red[:300], red[:300]))
    mask = np.triu(np.ones((300, 300), bool), 1)
    print(f"embedding space {emb.shape[1]}d -> 16d: "
          f"kruskal={quality.kruskal_stress(d_true[mask], d_zen[mask]):.4f} "
          f"rho={quality.spearman_rho(d_true[mask], d_zen[mask]):.4f}")


if __name__ == "__main__":
    main()
