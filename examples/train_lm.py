"""Train a reduced LM config with the full production substrate on CPU:
checkpoint/restart (kill it mid-run and re-invoke with --resume), straggler
monitoring, deterministic data pipeline — then reduce its token embeddings
with nSimplex Zen (the DESIGN.md §4 integration point).

Importable pieces (used by ``benchmarks/run.py --workload retrieval_e2e``):
``train_lm`` runs the loop and returns (cfg, params, losses);
``next_token_distributions`` turns trained params + token contexts into
softmax rows on the probability simplex — the coordinate-free JSD corpus
the paper's §5.6 experiments index.

Run:  PYTHONPATH=src python examples/train_lm.py
      PYTHONPATH=src python examples/train_lm.py --resume   # restart path
"""
import argparse
import os
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.checkpoint import CheckpointManager
from repro.core import quality, select_references, zen_pdist
from repro.core.metrics import euclidean_pdist
from repro.data import synthetic as syn
from repro.data.pipeline import PrefetchPipeline
from repro.distributed.fault import StepMonitor
from repro.models import transformer as tfm
from repro.optim import AdamW


def train_lm(
    steps: int = 40,
    *,
    resume: bool = False,
    ckpt_dir=None,
    batch: int = 8,
    seq: int = 64,
    data_seed: int = 0,
    data: str = "uniform",
    log=None,
):
    """Train the reduced qwen config; returns (cfg, params, losses).

    ``ckpt_dir=None`` disables checkpointing (benchmark callers); the CLI
    passes a directory so kill/--resume restarts reproduce the batch
    sequence through the deterministic pipeline. ``data="markov"`` trains
    on structured Markov token streams (``syn.lm_markov_batch``) so the
    learned next-token distributions depend on context — the corpus the
    retrieval_e2e JSD leg indexes.
    """
    cfg = C.get_arch("qwen1.5-0.5b").make_reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=1e-3)
    opt_state = opt.init(params)
    ckpt = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None

    start = 0
    if resume and ckpt is not None and ckpt.latest_step() is not None:
        start, (params, opt_state) = ckpt.restore(like=(params, opt_state))
        if log:
            log(f"resumed at step {start}")

    @jax.jit
    def step_fn(params, opt_state, batch_):
        (loss, _), grads = jax.value_and_grad(
            lambda p: tfm.loss_fn(cfg, p, batch_), has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return jax.tree.map(lambda a, b: a + b, params, updates), opt_state, loss

    monitor = StepMonitor()
    batch_fn = syn.lm_markov_batch if data == "markov" else syn.lm_batch
    pipe = PrefetchPipeline(
        lambda s: batch_fn(data_seed, s, batch, seq, cfg.vocab_size),
        start_step=start)
    losses = []
    try:
        for _ in range(steps - start):
            step, batch_ = next(pipe)
            t0 = time.time()
            params, opt_state, loss = step_fn(params, opt_state, batch_)
            monitor.record(step, time.time() - t0)
            losses.append(float(loss))
            if log and step % 10 == 0:
                log(f"step {step}: loss={losses[-1]:.3f}")
            if ckpt is not None and (step + 1) % 20 == 0:
                ckpt.save_async(step + 1, (params, opt_state))
    finally:
        pipe.close()
        if ckpt is not None:
            ckpt.wait()
    return cfg, params, losses


def next_token_distributions(cfg, params, tokens, *,
                             temperature: float = 1.0) -> jax.Array:
    """Softmax next-token rows: (N, S) int32 contexts -> (N, vocab) rows.

    Each row is the model's next-token distribution after its context — a
    point on the probability simplex (rows sum to 1), i.e. an object of the
    coordinate-free Jensen-Shannon space the paper's §5.6 experiments
    reduce with nSimplex Zen and LMDS (PCA/RP have no coordinates to use).
    ``temperature > 1`` smooths the rows: a sharply trained model emits
    near-one-hot rows whose pairwise JSD saturates at the metric's maximum
    (disjoint supports), which erases the neighbourhood structure the
    retrieval experiments measure.
    """
    logits = tfm.forward(cfg, params, jnp.asarray(tokens, jnp.int32))
    last = logits[:, -1, :].astype(jnp.float32) / float(temperature)
    return jax.nn.softmax(last, axis=-1)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=40)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--ckpt-dir", default=os.path.join(tempfile.gettempdir(),
                                                      "repro_train_lm"))
    args = p.parse_args()

    cfg, params, losses = train_lm(
        args.steps, resume=args.resume, ckpt_dir=args.ckpt_dir, log=print)
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")

    # --- nSimplex-Zen over the learned embedding space ----------------------
    emb = params["embed"][:2000].astype(jnp.float32)
    tr = select_references(emb, 16, jax.random.PRNGKey(7))
    red = tr.transform(emb)
    d_true = np.asarray(euclidean_pdist(emb[:300], emb[:300]))
    d_zen = np.asarray(zen_pdist(red[:300], red[:300]))
    mask = np.triu(np.ones((300, 300), bool), 1)
    print(f"embedding space {emb.shape[1]}d -> 16d: "
          f"kruskal={quality.kruskal_stress(d_true[mask], d_zen[mask]):.4f} "
          f"rho={quality.spearman_rho(d_true[mask], d_zen[mask]):.4f}")


if __name__ == "__main__":
    main()
