"""Replicated query-plane serving: one leader, N hot-swapping replicas.

The nSimplex-Zen index is fitted once and then applied out-of-sample, so
the fitted index is a read-mostly artifact — the production shape is a
single **leader** that owns churn and publishes atomic generation-tagged
snapshots, plus N **query-plane replicas** that watch the publish
directory, hot-swap (optionally mmap'd) without dropping in-flight
queries, and serve bit-identically to the leader (``repro.launch
.replicate``; see docs/architecture.md "Replicated serving").

The script walks the whole lifecycle:

1. build the index, wrap it in an ``IndexLeader``, publish generation 0;
2. start replicas (mmap'd, micro-batched frontend), poll -> first swap;
3. churn on the leader (deletes + upserts through the fitted transform),
   republish, replica hot-swap — and verify every replica's answers stay
   bit-identical to a direct leader query at each generation;
4. drive the fleet with the open-loop SLO harness (Poisson arrivals at a
   configured *offered* QPS, ``repro.serving.loadgen``) and print the
   latency/shed-rate report;
5. simulate a leader preemption: one final handoff publish, churn
   refused, a successor leader resumes from the published generation.

Run:  PYTHONPATH=src python examples/serve_replicated.py [--n 50000]
      PYTHONPATH=src python examples/serve_replicated.py \
          --replicas 3 --offered-qps 800 --duration 2.0
"""
import argparse
import shutil
import tempfile
import time

import numpy as np

import jax

from repro.data import synthetic as syn
from repro.launch.replicate import IndexLeader, LeaderHandedOff, QueryReplica
from repro.launch.serve import ZenServer, build_index
from repro.serving.loadgen import run_open_loop


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=50_000)
    p.add_argument("--dim", type=int, default=128)
    p.add_argument("--k", type=int, default=16)
    p.add_argument("--replicas", type=int, default=2)
    p.add_argument("--rounds", type=int, default=3,
                   help="churn -> publish -> hot-swap rounds")
    p.add_argument("--neighbors", type=int, default=10)
    p.add_argument("--offered-qps", type=float, default=400.0,
                   help="open-loop Poisson arrival rate for phase 4")
    p.add_argument("--duration", type=float, default=2.0,
                   help="open-loop arrival window, seconds")
    p.add_argument("--publish-root", default=None, metavar="DIR",
                   help="publish directory (default: a temp dir)")
    args = p.parse_args()

    key = jax.random.PRNGKey(0)
    print(f"corpus: {args.n} x {args.dim}")
    corpus = syn.manifold_space(key, args.n, args.dim, args.dim // 16)
    index = build_index(corpus, args.k, index="ivf",
                        key=jax.random.fold_in(key, 2))
    queries = np.asarray(syn.manifold_space(
        jax.random.fold_in(key, 3), 64, args.dim, args.dim // 16),
        np.float32)

    root = args.publish_root or tempfile.mkdtemp(prefix="zen-replicated-")
    try:
        # 1. leader + first publish
        leader = IndexLeader(ZenServer(index, nprobe=8), root, keep=2)
        pub = leader.publish()
        print(f"leader: published generation {pub.generation} -> "
              f"{pub.snapshot}")

        # 2. replicas: mmap'd hot-swap + micro-batched frontend
        reps = [QueryReplica(root, name=f"replica-{i}", mmap=True, nprobe=8,
                             frontend=True, cache_size=256)
                for i in range(args.replicas)]
        tracker = leader.track_replicas(deadline_s=60.0)
        for r in reps:
            r.poll()
            leader.replica_report(r.name, r.generation)
        print(f"replicas: {args.replicas} swapped to generation "
              f"{reps[0].generation}; fleet coherent: "
              f"{tracker.coherent(leader.generation)}")

        # 3. churn -> publish -> hot-swap, bit parity every round
        rng = np.random.default_rng(0)
        batch = 256
        for round_ in range(args.rounds):
            new_ids = np.arange(args.n + round_ * batch,
                                args.n + (round_ + 1) * batch)
            leader.upsert(new_ids, syn.manifold_space(
                jax.random.fold_in(key, 100 + round_), batch, args.dim,
                args.dim // 16))
            leader.delete(rng.choice(args.n, size=batch, replace=False))
            leader.publish()
            t0 = time.time()
            for r in reps:
                r.poll()
                leader.replica_report(r.name, r.generation)
            t_swap = (time.time() - t0) / len(reps)
            want = leader.server.query(queries, args.neighbors, direct=True)
            same = all(
                np.array_equal(np.asarray(g[0]), np.asarray(want[0]))
                and np.array_equal(np.asarray(g[1]), np.asarray(want[1]))
                for g in (r.query(queries, args.neighbors) for r in reps))
            print(f"round {round_}: generation {leader.generation}, "
                  f"swap {t_swap * 1e3:.0f} ms/replica, "
                  f"bit-identical to leader: {same}")

        # 4. open-loop offered load over the fleet (round-robin)
        report = run_open_loop([r.server for r in reps], queries,
                               offered_qps=args.offered_qps,
                               duration_s=args.duration,
                               n_neighbors=args.neighbors, seed=7)
        print(f"open-loop @ {report.offered_qps:.0f} qps offered over "
              f"{args.replicas} replica(s): achieved "
              f"{report.achieved_qps:.0f} qps, p50 {report.p50_ms:.1f} ms, "
              f"p99 {report.p99_ms:.1f} ms, reject rate "
              f"{report.reject_rate:.2f}, failures {report.failures}")
        print("fleet status:", leader.fleet_status())

        # 5. preemption handoff: final publish, churn refused, successor
        leader.enable_preemption()
        leader.preemption.request()  # the platform's SIGTERM, simulated
        if leader.maybe_handoff():
            print(f"leader: preempted -> handoff snapshot published at "
                  f"generation {leader.published_generation}")
        try:
            leader.delete([0])
        except LeaderHandedOff as e:
            print(f"leader: churn refused after handoff ({e})")
        from repro.launch.replicate import read_pointer
        successor = IndexLeader(
            ZenServer.load(read_pointer(root).snapshot), root, keep=2)
        successor.upsert([args.n + 10 ** 6], syn.manifold_space(
            jax.random.fold_in(key, 999), 1, args.dim, args.dim // 16))
        successor.publish()
        for r in reps:
            r.poll()
        print(f"successor: resumed churn at generation "
              f"{successor.generation}; replicas now at generation "
              f"{reps[0].generation}; poll errors: "
              f"{sum(r.poll_errors for r in reps)}")
    finally:
        if args.publish_root is None:
            shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
