"""RecSys candidate retrieval with an nSimplex-Zen-reduced index — the
paper's technique as a serving feature on a real model (the third §Perf
hillclimb cell, runnable end to end on CPU).

Pipeline: init a reduced DLRM -> embed 50k candidate items (their table
rows) -> build the Zen index at k=8 (embed_dim 16 -> 2x memory, 4x scan-byte
reduction at production dims) -> score user queries both ways and compare
top-k agreement + timing. ``--ivf`` additionally clusters the reduced
candidates (``repro.index``) and retrieves through ``--nprobe`` inverted-list
probes instead of the full flat scan, printing the recall/latency comparison.

Run:  PYTHONPATH=src python examples/recsys_retrieval.py [--ivf --nprobe 32]
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.core import select_references
from repro.core.zen import knn_search
from repro.data import synthetic as syn
from repro.models import recsys as R


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--ivf", action="store_true",
                   help="also retrieve via the clustered IVF index")
    p.add_argument("--nprobe", type=int, default=32)
    args = p.parse_args()

    cfg = C.get_arch("dlrm-rm2").make_reduced()
    params = R.init_params(cfg, jax.random.PRNGKey(0))

    # candidate item embeddings: rows of the (padded) table
    n_cand, d = 50_000, cfg.embed_dim
    cand = jax.random.normal(jax.random.PRNGKey(1), (n_cand, d)) * 0.5

    # user queries from the model's representation head
    B = 64
    batch = syn.recsys_batch(0, 0, B, cfg.vocab_sizes, cfg.n_dense)
    q = R.user_repr(cfg, params, batch)  # (B, d)

    # --- dense baseline ------------------------------------------------------
    t0 = time.time()
    scores, dense_ids = R.retrieval_topk(q, cand, k=10)
    jax.block_until_ready(dense_ids)
    t_dense = time.time() - t0

    # --- nSimplex-Zen reduced index + exact re-rank --------------------------
    k = 8
    tr = select_references(cand, k, jax.random.PRNGKey(2))
    cand_z = tr.transform(cand)           # (n_cand, k) — built offline
    fetch = 100                           # zen candidate pool, re-ranked exact

    def zen_query(q):
        qz = tr.transform(q)
        _, pool = knn_search(qz, cand_z, n_neighbors=fetch, mode="zen")
        pooled = cand[pool]               # (B, fetch, d)
        d2 = jnp.sum((q[:, None, :] - pooled) ** 2, -1)
        _, pos = jax.lax.top_k(-d2, 10)
        return jnp.take_along_axis(pool, pos, axis=1)

    zen_query_j = jax.jit(zen_query)
    zen_query_j(q).block_until_ready()    # warm up (compile)
    t0 = time.time()
    zen_ids = zen_query_j(q)
    jax.block_until_ready(zen_ids)
    t_zen = time.time() - t0

    # exact euclidean ground truth
    d2 = (
        jnp.sum(q**2, 1)[:, None] + jnp.sum(cand**2, 1)[None, :]
        - 2 * q @ cand.T
    )
    _, true_ids = jax.lax.top_k(-d2, 10)
    overlap = np.mean([
        len(set(np.asarray(zen_ids)[i]) & set(np.asarray(true_ids)[i])) / 10
        for i in range(B)
    ])
    print(f"candidates: {n_cand} x {d} -> zen index {n_cand} x {k} "
          f"({d / k:.1f}x smaller)")
    print(f"zen+rerank top-10 recall vs exact-euclidean: {overlap:.2f}")
    print(f"batch-of-{B} scoring: dense {t_dense*1e3:.1f} ms, "
          f"zen-reduced+rerank {t_zen*1e3:.1f} ms (jit-warmed)")

    if args.ivf:
        # --- clustered IVF over the same reduced candidates -----------------
        from repro.index import IVFZenIndex, exact_rerank

        t0 = time.time()
        ivf = IVFZenIndex.build(cand_z, max(16, int(4 * n_cand ** 0.5)),
                                key=jax.random.PRNGKey(3))
        t_build = time.time() - t0

        def ivf_query(q):
            qz = tr.transform(q)
            _, pool = ivf.search(qz, n_neighbors=fetch, nprobe=args.nprobe)
            return exact_rerank(q, cand, pool, 10)[1]

        ivf_query_j = jax.jit(ivf_query)
        ivf_query_j(q).block_until_ready()   # warm up (compile)
        t0 = time.time()
        ivf_ids = ivf_query_j(q)
        jax.block_until_ready(ivf_ids)
        t_ivf = time.time() - t0
        ivf_overlap = np.mean([
            len(set(np.asarray(ivf_ids)[i]) & set(np.asarray(true_ids)[i]))
            / 10 for i in range(B)
        ])
        print(f"ivf ({ivf.n_clusters} clusters, nprobe={args.nprobe}, "
              f"built in {t_build:.1f}s): top-10 recall {ivf_overlap:.2f} "
              f"vs flat-zen {overlap:.2f}; scoring {t_ivf*1e3:.1f} ms vs "
              f"flat-zen {t_zen*1e3:.1f} ms "
              f"(scans ~{args.nprobe * ivf.tiles_per_cluster * ivf.tile_rows}"
              f" of {n_cand} reduced rows per query)")

    print("at production scale (1M cand, d=64) the reduced scan moves "
          f"{64/k:.0f}x fewer bytes — see EXPERIMENTS.md §Perf retrieval cell")


if __name__ == "__main__":
    main()
