"""RecSys candidate retrieval with an nSimplex-Zen-reduced index — the
paper's technique as a serving feature on a real model (the third §Perf
hillclimb cell, runnable end to end on CPU).

Pipeline: init a reduced DLRM -> embed 50k candidate items (their table
rows) -> build the Zen index at k=8 (embed_dim 16 -> 2x memory, 4x scan-byte
reduction at production dims) -> score user queries both ways and compare
top-k agreement + timing.

Run:  PYTHONPATH=src python examples/recsys_retrieval.py
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.core import select_references
from repro.core.zen import knn_search
from repro.data import synthetic as syn
from repro.models import recsys as R


def main():
    cfg = C.get_arch("dlrm-rm2").make_reduced()
    params = R.init_params(cfg, jax.random.PRNGKey(0))

    # candidate item embeddings: rows of the (padded) table
    n_cand, d = 50_000, cfg.embed_dim
    cand = jax.random.normal(jax.random.PRNGKey(1), (n_cand, d)) * 0.5

    # user queries from the model's representation head
    B = 64
    batch = syn.recsys_batch(0, 0, B, cfg.vocab_sizes, cfg.n_dense)
    q = R.user_repr(cfg, params, batch)  # (B, d)

    # --- dense baseline ------------------------------------------------------
    t0 = time.time()
    scores, dense_ids = R.retrieval_topk(q, cand, k=10)
    jax.block_until_ready(dense_ids)
    t_dense = time.time() - t0

    # --- nSimplex-Zen reduced index + exact re-rank --------------------------
    k = 8
    tr = select_references(cand, k, jax.random.PRNGKey(2))
    cand_z = tr.transform(cand)           # (n_cand, k) — built offline
    fetch = 100                           # zen candidate pool, re-ranked exact

    def zen_query(q):
        qz = tr.transform(q)
        _, pool = knn_search(qz, cand_z, n_neighbors=fetch, mode="zen")
        pooled = cand[pool]               # (B, fetch, d)
        d2 = jnp.sum((q[:, None, :] - pooled) ** 2, -1)
        _, pos = jax.lax.top_k(-d2, 10)
        return jnp.take_along_axis(pool, pos, axis=1)

    zen_query_j = jax.jit(zen_query)
    zen_query_j(q).block_until_ready()    # warm up (compile)
    t0 = time.time()
    zen_ids = zen_query_j(q)
    jax.block_until_ready(zen_ids)
    t_zen = time.time() - t0

    # exact euclidean ground truth
    d2 = (
        jnp.sum(q**2, 1)[:, None] + jnp.sum(cand**2, 1)[None, :]
        - 2 * q @ cand.T
    )
    _, true_ids = jax.lax.top_k(-d2, 10)
    overlap = np.mean([
        len(set(np.asarray(zen_ids)[i]) & set(np.asarray(true_ids)[i])) / 10
        for i in range(B)
    ])
    print(f"candidates: {n_cand} x {d} -> zen index {n_cand} x {k} "
          f"({d / k:.1f}x smaller)")
    print(f"zen+rerank top-10 recall vs exact-euclidean: {overlap:.2f}")
    print(f"batch-of-{B} scoring: dense {t_dense*1e3:.1f} ms, "
          f"zen-reduced+rerank {t_zen*1e3:.1f} ms (jit-warmed)")
    print("at production scale (1M cand, d=64) the reduced scan moves "
          f"{64/k:.0f}x fewer bytes — see EXPERIMENTS.md §Perf retrieval cell")


if __name__ == "__main__":
    main()
