"""Coordinate-free Hilbert spaces: reduce a Jensen-Shannon metric space with
nSimplex Zen vs Landmark MDS (paper §5.6) — distances only, no coordinates.

The punchline (paper §5.6): the reduction not only shrinks memory, it
converts an expensive log-heavy JSD computation into a cheap Euclidean-form
Zen computation.

Run:  PYTHONPATH=src python examples/js_space_reduction.py
"""
import time

import numpy as np

import jax

from repro.core import LMDSTransform, NSimplexTransform, metrics as M, quality
from repro.core.zen import zen_pdist
from repro.data import synthetic as syn


def main():
    key = jax.random.PRNGKey(0)
    n, dim, k = 1500, 100, 20
    X = syn.probability_space(key, n, dim)  # l1-normalised prob vectors

    # reference / landmark sets (random, per the paper)
    ridx = np.random.default_rng(0).choice(n, k, replace=False)
    R = X[ridx]

    # --- nSimplex Zen: fit from the (k, k) JSD distance matrix -------------
    D_refs = np.array(M.jsd_pdist(R, R, assume_normalized=True))
    np.fill_diagonal(D_refs, 0.0)
    tr = NSimplexTransform.from_distances(D_refs)
    Xp = tr.transform_from_distances(M.jsd_pdist(X, R, assume_normalized=True))

    # --- LMDS on the same landmarks -----------------------------------------
    lmds = LMDSTransform(k=k).fit_from_distances(D_refs)
    Xl = lmds.transform_from_distances(M.jsd_pdist(X, R, assume_normalized=True))

    # --- quality over sampled pairs -----------------------------------------
    sub = X[:400]
    D_true = np.asarray(M.jsd_pdist(sub, sub, assume_normalized=True))
    mask = np.triu(np.ones((400, 400), bool), 1)
    delta = D_true[mask]
    zen = np.asarray(zen_pdist(Xp[:400], Xp[:400]))[mask]
    lm = np.asarray(M.euclidean_pdist(Xl[:400], Xl[:400]))[mask]

    print(f"JSD space {dim}d -> {k}d")
    for name, zeta in [("nSimplex-Zen", zen), ("LMDS", lm)]:
        print(f"{name:>14}: kruskal={quality.kruskal_stress(delta, zeta):.4f} "
              f"sammon={quality.sammon_stress(delta, zeta):.4f} "
              f"rho={quality.spearman_rho(delta, zeta):.4f}")

    # --- distance-computation speedup ----------------------------------------
    t0 = time.time()
    _ = np.asarray(M.jsd_pdist(sub, sub, assume_normalized=True))
    t_jsd = time.time() - t0
    Xp4 = Xp[:400]
    t0 = time.time()
    _ = np.asarray(zen_pdist(Xp4, Xp4))
    t_zen = time.time() - t0
    print(f"\npairwise time: jsd({dim}d)={t_jsd*1e3:.1f}ms  "
          f"zen({k}d)={t_zen*1e3:.1f}ms  -> {t_jsd/max(t_zen,1e-9):.0f}x faster")


if __name__ == "__main__":
    main()
