"""Quickstart: nSimplex Zen dimensionality reduction in ~40 lines.

Reduces a 100-dimensional Euclidean space to 10 dimensions with the paper's
three estimators and compares quality against PCA / RP baselines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    NSimplexTransform,
    PCATransform,
    RandomProjection,
    estimate_triple,
    euclidean_pdist,
    quality,
    select_references,
)
from repro.data import synthetic as syn


def main():
    key = jax.random.PRNGKey(0)
    n, m, k = 2000, 100, 10
    X = syn.uniform_space(key, n, m)

    # --- fit the nSimplex transform on k random references -----------------
    tr = select_references(X, k, jax.random.fold_in(key, 1))
    Xp = tr.transform(X)                       # (n, k) apex coordinates
    print(f"reduced {m}d -> {k}d; altitude column mean "
          f"{float(jnp.mean(Xp[:, -1])):.3f}")

    # --- the three estimators: Lwb <= d <= Upb, Zen in between -------------
    sample = X[:300]
    lwb, zen, upb = estimate_triple(tr.transform(sample), tr.transform(sample))
    d_true = euclidean_pdist(sample, sample)
    mask = ~np.eye(300, dtype=bool)
    rel = lambda a: float(np.mean(np.abs(np.asarray(a) - np.asarray(d_true))[mask]
                                  / np.asarray(d_true)[mask]))
    print(f"mean relative error  lwb={rel(lwb):.3f}  zen={rel(zen):.3f}  "
          f"upb={rel(upb):.3f}")

    # --- quality vs PCA / RP at the same k ---------------------------------
    delta = np.asarray(d_true)[mask]
    results = {"nSimplex-Zen": np.asarray(zen)[mask]}
    pca = PCATransform(k=k).fit(X[:1000])
    results["PCA"] = np.asarray(euclidean_pdist(
        pca.transform(sample), pca.transform(sample)))[mask]
    rp = RandomProjection(k=k).fit(m, key=jax.random.fold_in(key, 2))
    results["RP"] = np.asarray(euclidean_pdist(
        rp.transform(sample), rp.transform(sample)))[mask]

    print(f"\n{'transform':>14}  kruskal_stress  spearman_rho")
    for name, zeta in results.items():
        ks = quality.kruskal_stress(delta, zeta)
        rho = quality.spearman_rho(delta, zeta)
        print(f"{name:>14}  {ks:14.4f}  {rho:12.4f}")


if __name__ == "__main__":
    main()
