"""Dev-container line-coverage harness (pytest-cov stand-in).

The dev container cannot install ``pytest-cov``/``coverage``, but the CI
coverage floor (``--cov-fail-under`` in ``.github/workflows/ci.yml``) must
be ratcheted against a measured number. This harness approximates
``coverage.py``'s line metric with the stdlib only:

  * the *denominator* is every executable line of ``src/repro`` — the
    union of ``co_lines()`` over all code objects compiled from each file;
  * the *numerator* is the set of those lines fired by a ``sys.settrace``
    line hook while pytest runs (tracing is disabled inside files outside
    ``src/repro``, so the overhead stays tolerable).

Subprocess-spawned tests (the 4-device mesh suites) don't report into the
parent tracer — same blind spot PR 4 noted — so the number reads *below*
what pytest-cov sees in CI. Keep the CI floor at least a point under the
measurement from this tool.

Usage (optionally sharding the suite across invocations, merging the
line sets via --state):

    PYTHONPATH=src python tools/measure_coverage.py --state /tmp/cov.pkl \
        tests/test_a.py tests/test_b.py
    PYTHONPATH=src python tools/measure_coverage.py --state /tmp/cov.pkl \
        --report tests/test_c.py
"""
from __future__ import annotations

import argparse
import os
import pickle
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src", "repro")


def executable_lines() -> dict:
    """{abspath: set(line)} of every compilable line under src/repro."""
    out = {}
    for dirpath, _, files in os.walk(SRC):
        for name in sorted(files):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            with open(path) as f:
                try:
                    code = compile(f.read(), path, "exec")
                except SyntaxError:
                    continue
            lines, stack = set(), [code]
            while stack:
                co = stack.pop()
                lines.update(ln for _, _, ln in co.co_lines()
                             if ln is not None)
                stack.extend(c for c in co.co_consts
                             if hasattr(c, "co_lines"))
            out[path] = lines
    return out


def run_traced(pytest_args) -> dict:
    """Run pytest under a line tracer; {abspath: set(line)} executed."""
    import pytest

    hit: dict = {}

    def tracer(frame, event, arg):
        fn = frame.f_code.co_filename
        if not fn.startswith(SRC):
            return None  # don't descend into non-target files
        if event == "line":
            hit.setdefault(fn, set()).add(frame.f_lineno)
        return tracer

    sys.settrace(tracer)
    try:
        rc = pytest.main(pytest_args)
    finally:
        sys.settrace(None)
    if rc not in (0,):
        raise SystemExit(f"pytest failed (exit {rc}); coverage not valid")
    return hit


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("pytest_args", nargs="*",
                   help="files/args passed to pytest (default: the "
                        "not-slow tier-1 suite)")
    p.add_argument("--state", default=None,
                   help="pickle accumulating executed lines across "
                        "sharded invocations")
    p.add_argument("--report", action="store_true",
                   help="print the merged coverage after this shard")
    args = p.parse_args()

    pytest_args = args.pytest_args or ["-q", "-m", "not slow", "tests"]
    hit = run_traced(["-q", "-p", "no:cacheprovider", *pytest_args])

    if args.state and os.path.exists(args.state):
        with open(args.state, "rb") as f:
            prev = pickle.load(f)
        for fn, lines in prev.items():
            hit.setdefault(fn, set()).update(lines)
    if args.state:
        with open(args.state, "wb") as f:
            pickle.dump(hit, f)

    if args.report or not args.state:
        want = executable_lines()
        total = sum(len(v) for v in want.values())
        got = sum(len(want[fn] & hit.get(fn, set())) for fn in want)
        print(f"\nsrc/repro line coverage: {got}/{total} "
              f"= {100.0 * got / total:.1f}%")
        worst = sorted(
            want, key=lambda fn: len(want[fn] & hit.get(fn, set()))
            / max(len(want[fn]), 1))[:8]
        for fn in worst:
            cov = len(want[fn] & hit.get(fn, set())) / max(len(want[fn]), 1)
            print(f"  {100 * cov:5.1f}%  {os.path.relpath(fn, ROOT)}")


if __name__ == "__main__":
    main()
