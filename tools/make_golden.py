"""Regenerate the committed golden-parity corpus (tests/golden/).

The golden file pins the serving stack's *exact* numerical output across
PRs: a fixed-seed corpus + query set and the expected top-k ids/distances
of every major retrieval configuration — flat f32, IVF probed at
``nprobe = n_clusters`` (exact), int8 and product-quantised (pq) storage,
exact re-rank, the non-Euclidean jsd/qform paths, the chosen pivot
ids of every ``core.pivots`` strategy, plus a baseline-reducer block
(pca/rp/lmds coordinates and the zen-vs-pca recall ordering at low k). ``tests/test_golden_parity.py``
replays
each configuration against the stored corpus and requires bit-identical
results; it also re-runs :func:`build_golden` and requires the regenerated
arrays to match the committed file bit-for-bit, so the synthetic-data
pipeline is pinned too.

Regenerate (only when an intentional numerical change lands — commit the
diff together with the change that justifies it):

    PYTHONPATH=src python tools/make_golden.py
"""
from __future__ import annotations

import contextlib
import os
from typing import Dict

import numpy as np

import jax

from repro.data import synthetic as syn
from repro.launch.serve import ZenServer, build_index


@contextlib.contextmanager
def _force_x32():
    """Pin the golden computations to f32 regardless of ambient config.

    Some test modules enable ``jax_enable_x64`` globally at import time;
    the golden bits are defined as the serving stack's *default* (x32)
    numerics, so both generation and replay run under this guard.
    """
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", prev)

#: golden geometry — small enough to commit, big enough that top-k is
#: non-trivial (multiple IVF clusters, real neighbour structure)
N, DIM, K, Q, NN = 512, 32, 8, 16, 10
N_CLUSTERS = 16

GOLDEN_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "tests", "golden", "serving_golden.npz")

#: the pinned configurations: name -> (corpus space, build/server kwargs)
CASES = {
    "flat_zen": dict(space="euclid", metric="euclidean", index="flat"),
    "flat_lwb": dict(space="euclid", metric="euclidean", index="flat",
                     mode="lwb"),
    "ivf_exact": dict(space="euclid", metric="euclidean", index="ivf",
                      nprobe=N_CLUSTERS),
    "ivf_probe4": dict(space="euclid", metric="euclidean", index="ivf",
                       nprobe=4),
    "flat_int8": dict(space="euclid", metric="euclidean", index="flat",
                      storage="int8"),
    "ivf_int8": dict(space="euclid", metric="euclidean", index="ivf",
                     storage="int8", nprobe=N_CLUSTERS),
    "flat_rerank": dict(space="euclid", metric="euclidean", index="flat",
                        rerank_factor=4),
    "flat_jsd": dict(space="jsd", metric="jsd", index="flat",
                     rerank_factor=4),
    "ivf_qform": dict(space="euclid", metric="qform", index="ivf",
                      nprobe=N_CLUSTERS, rerank_factor=4),
    # product-quantised storage: codes + codebooks + fused LUT probe.
    # pq_m pinned (not left to the default) so the golden stays meaningful
    # if the default subspace heuristic ever changes.
    "ivf_pq": dict(space="euclid", metric="euclidean", index="ivf",
                   storage="pq", pq_m=2, nprobe=N_CLUSTERS),
    "ivf_pq_rerank": dict(space="euclid", metric="euclidean", index="ivf",
                          storage="pq", pq_m=2, nprobe=4, rerank_factor=4),
    # replica-served (repro.launch.replicate): the leader publishes,
    # churns (3 deletes + 3 upserts), republishes; the pinned bits are
    # what a hot-swapped **mmap'd replica** serves at the published
    # generation — with leader parity asserted in-case, this pins the
    # whole publish -> hot-swap -> serve path, not just the maths.
    "ivf_replica_served": dict(space="euclid", metric="euclidean",
                               index="ivf", nprobe=N_CLUSTERS,
                               replica=True),
}

#: pivot-selection golden: chosen pivot row ids per strategy over the
#: euclid corpus — pins ``core.pivots`` end to end (witness subsample,
#: distance matrix, greedy/stochastic selection)
PIVOT_KEY_SEED = 7

#: baseline-reducer golden: reduced query coordinates of the coordinate
#: baselines (pca / rp / lmds) at a paper-regime k, plus the per-query
#: recall@10 of zen and pca on an isotropic gaussian corpus — the regime
#: where the paper's ordering claim (zen above pca at low k) holds, pinned
#: so a baseline refactor can neither shift the coordinates nor silently
#: flip the ordering.
BASELINE_K = 4
BASELINE_NN = 10
BASELINE_KEY_SEED = 19


def pivot_golden(arrays: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    from repro.core.pivots import PIVOT_STRATEGIES, pivot_ids

    with _force_x32():
        corpus = jax.numpy.asarray(arrays["corpus_euclid"])
        return {
            f"pivots_{strategy}_ids": np.asarray(
                pivot_ids(corpus, K, jax.random.PRNGKey(PIVOT_KEY_SEED),
                          strategy=strategy), np.int32)
            for strategy in PIVOT_STRATEGIES
        }


def baseline_golden(arrays: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    from repro.core import make_reducer
    from repro.core import metrics as metrics_lib

    with _force_x32():
        corpus = jax.numpy.asarray(arrays["corpus_gauss"])
        queries = jax.numpy.asarray(arrays["queries_gauss"])
        truth = np.argsort(np.asarray(
            metrics_lib.euclidean_pdist(queries, corpus)), 1)[:, :BASELINE_NN]
        key = jax.random.PRNGKey(BASELINE_KEY_SEED)
        out: Dict[str, np.ndarray] = {}
        for i, name in enumerate(("zen", "pca", "rp", "lmds")):
            r = make_reducer(name, BASELINE_K).fit(
                corpus, key=jax.random.fold_in(key, i))
            Xr, Qr = r.transform(corpus), r.transform(queries)
            if name != "zen":  # zen coords are covered by the serving cases
                out[f"baseline_{name}_coords"] = np.asarray(Qr, np.float32)
            pred = np.argsort(np.asarray(r.pdist(Qr, Xr)), 1)[:, :BASELINE_NN]
            out[f"baseline_recall_{name}"] = np.asarray(
                [len(set(truth[q]) & set(pred[q])) / BASELINE_NN
                 for q in range(truth.shape[0])], np.float32)
        if (out["baseline_recall_zen"].mean()
                < out["baseline_recall_pca"].mean()):
            raise AssertionError(
                "baseline golden would pin zen below pca on the isotropic "
                "corpus — the paper's low-k ordering claim is violated")
        return out


def _spaces() -> Dict[str, np.ndarray]:
    """Fixed-seed corpus/query pairs per metric domain."""
    with _force_x32():
        return _spaces_x32()


def _spaces_x32() -> Dict[str, np.ndarray]:
    key = jax.random.PRNGKey(1234)
    return {
        "corpus_euclid": np.asarray(
            syn.manifold_space(key, N, DIM, DIM // 4), np.float32),
        "queries_euclid": np.asarray(
            syn.manifold_space(jax.random.fold_in(key, 1), Q, DIM, DIM // 4),
            np.float32),
        # probability vectors: the jsd metric's natural domain
        "corpus_jsd": np.asarray(
            syn.probability_space(jax.random.fold_in(key, 2), N, DIM,
                                  DIM // 4), np.float32),
        "queries_jsd": np.asarray(
            syn.probability_space(jax.random.fold_in(key, 3), Q, DIM,
                                  DIM // 4), np.float32),
        # isotropic full-rank gaussians: the baseline-reducer golden's
        # domain (zen's favourable regime — no low-rank structure for
        # PCA to exploit)
        "corpus_gauss": np.asarray(
            syn.gaussian_space(jax.random.fold_in(key, 4), N, DIM),
            np.float32),
        "queries_gauss": np.asarray(
            syn.gaussian_space(jax.random.fold_in(key, 5), Q, DIM),
            np.float32),
    }


def run_case(name: str, arrays: Dict[str, np.ndarray]):
    """(distances, ids) of one pinned configuration over the stored data."""
    with _force_x32():
        return _run_case_x32(name, arrays)


def _run_case_x32(name: str, arrays: Dict[str, np.ndarray]):
    cfg = dict(CASES[name])
    space = cfg.pop("space")
    replica = cfg.pop("replica", False)
    corpus = np.asarray(arrays[f"corpus_{space}"])
    queries = np.asarray(arrays[f"queries_{space}"])
    build_kw = dict(
        metric=cfg.pop("metric"), index=cfg.pop("index"),
        storage=cfg.pop("storage", "float32"),
        pq_m=cfg.pop("pq_m", None),
        key=jax.random.PRNGKey(7),
    )
    if build_kw["index"] == "ivf":
        build_kw["n_clusters"] = N_CLUSTERS
    index = build_index(jax.numpy.asarray(corpus), K, **build_kw)
    server = ZenServer(index, **cfg)
    if replica:
        return _replica_serve_x32(server, queries)
    d, ids = server.query(jax.numpy.asarray(queries), NN)
    return np.asarray(d, np.float32), np.asarray(ids, np.int32)


def _replica_serve_x32(server: ZenServer, queries: np.ndarray):
    """Leader publish -> churn -> republish -> replica mmap hot-swap -> query.

    The returned bits come from the *replica*; leader parity is asserted
    here so a regenerated golden can never silently pin a divergence
    between the two serving paths.
    """
    import tempfile

    from repro.launch.replicate import IndexLeader, QueryReplica

    with tempfile.TemporaryDirectory(prefix="zen-golden-replica-") as root:
        leader = IndexLeader(server, root, keep=4)
        leader.publish()
        rep = QueryReplica(root, mmap=True)
        assert rep.poll() and rep.generation == 0
        leader.delete([3, 4, 5])                       # generation 1
        fresh = np.asarray(
            syn.manifold_space(jax.random.PRNGKey(4242), 3, DIM, DIM // 4),
            np.float32)
        leader.upsert([N + 1, N + 2, N + 3], fresh)    # generation 2
        leader.publish()
        assert rep.poll() and rep.generation == leader.generation == 2
        d, ids = rep.query(queries, NN)
        d_leader, ids_leader = server.query(queries, NN, direct=True)
        if not (np.array_equal(np.asarray(d), np.asarray(d_leader))
                and np.array_equal(np.asarray(ids), np.asarray(ids_leader))):
            raise AssertionError(
                "replica-served golden diverged from the leader")
        return np.asarray(d, np.float32), np.asarray(ids, np.int32)


def build_golden() -> Dict[str, np.ndarray]:
    """All golden arrays: the corpora plus every case's expected output."""
    arrays = _spaces()
    for name in CASES:
        d, ids = run_case(name, arrays)
        arrays[f"{name}_d"] = d
        arrays[f"{name}_ids"] = ids
    arrays.update(pivot_golden(arrays))
    arrays.update(baseline_golden(arrays))
    return arrays


def main() -> None:
    arrays = build_golden()
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    np.savez(GOLDEN_PATH, **arrays)
    size = os.path.getsize(GOLDEN_PATH)
    print(f"wrote {GOLDEN_PATH} ({size / 1024:.1f} KiB, "
          f"{len(arrays)} arrays, {len(CASES)} cases)")


if __name__ == "__main__":
    main()
