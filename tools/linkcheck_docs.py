#!/usr/bin/env python
"""Check markdown docs for broken repo-relative links and code anchors.

Validates, over README.md and docs/*.md:

  * relative markdown links ``[text](path)`` / ``[text](path#fragment)``
    point at files that exist (external http(s)/mailto links are skipped);
  * `` `path::symbol` `` code anchors (the docs/paper_map.md convention)
    name an existing file that actually contains ``symbol``.

Exit code 0 when everything resolves, 1 otherwise (one line per problem).
Run from the repo root:  python tools/linkcheck_docs.py
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ANCHOR_RE = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|md))::([A-Za-z0-9_.]+)`")


def check_file(path: str) -> list[str]:
    problems = []
    text = open(path, encoding="utf-8").read()
    base = os.path.dirname(path)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not os.path.exists(os.path.join(base, rel)):
            problems.append(f"{path}: broken link -> {target}")
    for fname, symbol in ANCHOR_RE.findall(text):
        fpath = os.path.join(REPO, fname)
        if not os.path.exists(fpath):
            problems.append(f"{path}: anchor file missing -> {fname}")
            continue
        # the symbol is the last dotted component (Class.method -> method)
        leaf = symbol.split(".")[-1]
        body = open(fpath, encoding="utf-8").read()
        if not re.search(
                rf"(?:def|class)\s+{re.escape(leaf)}\b|^{re.escape(leaf)}\s*=",
                body, re.MULTILINE):
            problems.append(f"{path}: anchor not found -> {fname}::{symbol}")
    return problems


def main() -> int:
    targets = [os.path.join(REPO, "README.md")]
    targets += sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    problems = []
    for t in targets:
        problems += check_file(t)
    for p in problems:
        print(p)
    print(f"checked {len(targets)} files: "
          f"{'OK' if not problems else f'{len(problems)} problem(s)'}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
