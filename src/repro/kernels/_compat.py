"""Version compatibility shims for the Pallas TPU API surface.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and back,
depending on the release line); every kernel in this package goes through
:func:`compiler_params` so a single site tracks the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if CompilerParams is None:  # pragma: no cover - ancient jax
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported"
    )


def compiler_params(*, dimension_semantics, **kw):
    """Build TPU compiler params across the CompilerParams rename."""
    return CompilerParams(dimension_semantics=dimension_semantics, **kw)
