"""Version compatibility shims for the Pallas TPU API surface.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and back,
depending on the release line); every kernel in this package goes through
:func:`compiler_params` so a single site tracks the rename.

This module is also the seam for *host memory* support: the tiered tile
store (``index.ivf.TieredIVFZenIndex``) keeps cold inverted lists host-side
and stages probed buffers up through :func:`pinned_host_sharding` +
``kernels.tile_stage``. Memory kinds are a backend capability, not an API
constant, so the probe is runtime (and cached).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if CompilerParams is None:  # pragma: no cover - ancient jax
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported"
    )


def compiler_params(*, dimension_semantics, **kw):
    """Build TPU compiler params across the CompilerParams rename."""
    return CompilerParams(dimension_semantics=dimension_semantics, **kw)


@functools.lru_cache(maxsize=None)
def _pinned_host_supported(device) -> bool:
    try:
        return "pinned_host" in {m.kind for m in device.addressable_memories()}
    except Exception:  # pragma: no cover - backends without memory spaces
        return False


def pinned_host_sharding(device=None) -> Optional[jax.sharding.Sharding]:
    """Sharding that pins a host buffer for async DMA upload, if the backend
    has a ``pinned_host`` memory space (TPU; None on plain CPU/GPU builds,
    where callers fall back to an ordinary ``device_put``)."""
    device = device if device is not None else jax.devices()[0]
    if not _pinned_host_supported(device):
        return None
    return jax.sharding.SingleDeviceSharding(device, memory_kind="pinned_host")
