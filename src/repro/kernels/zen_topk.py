"""Pallas TPU kernel: streaming fused Zen/Lwb/Upb top-k retrieval.

The serving hot path (paper §6) is "find the n nearest index rows to each
query under an estimator". The dense formulation materialises the full (Q, N)
estimator matrix and runs ``lax.top_k`` over it, so per-query memory grows
linearly with index size N. This kernel never materialises that matrix: the
grid is (Q/bq, N/bn) with ``dimension_semantics=("parallel", "arbitrary")`` —
each query block walks the index tiles sequentially, fusing the estimator
(same masked-matmul + rank-1 altitude correction as ``kernels/zen.py``) with a
running top-k held in VMEM scratch:

  best_d, best_i : (bq, kw) scratch, kw = n_neighbors rounded up to a lane
  per tile:        d = estimator(q_block, x_tile)          (bq, bn)
                   merge = top_k(concat([best, d], axis=1), kw)

Peak per-query state is therefore O(kw + bn) — one tile — independent of N.
Index row ids are derived in-register from the tile position (``j*bn + iota``)
so no id tensor is streamed either. Padded tail rows (N not a multiple of bn)
are masked to +inf before the merge; padded scratch lanes (kw > n_neighbors)
start at +inf and can never win.

``zen_topk_scan`` is the schedule-equivalent jnp fallback for CPU/GPU: a
``lax.scan`` over index chunks with the same concat + top_k merge — XLA keeps
only one chunk of distances live, giving the same O(chunk) memory bound.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params
from .scoring import MODE_IDS as _MODE
from .scoring import estimate_tile as _estimate_tile
from .scoring import merge_topk as _merge_topk

Array = jax.Array


def _topk_kernel(
    q_ref,
    x_ref,
    *rest,
    true_k: int,
    n_index: int,
    n_index_blocks: int,
    mode: int,
    has_scale: bool,
):
    # with quantised storage a (bn, 1) per-row scale block rides along
    if has_scale:
        s_ref, od_ref, oi_ref, bd_ref, bi_ref = rest
    else:
        od_ref, oi_ref, bd_ref, bi_ref = rest
        s_ref = None
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, jnp.inf)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    q = q_ref[...].astype(jnp.float32)  # (bq, kp)
    x = x_ref[...].astype(jnp.float32)  # (bn, kp)
    scale = s_ref[...] if has_scale else None  # (bn, 1) dequant factors
    d = _estimate_tile(
        q, x, true_k=true_k, mode=mode, scale=scale)  # (bq, bn)

    bn = x.shape[0]
    ids = j * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    d = jnp.where(ids < n_index, d, jnp.inf)  # mask padded tail rows

    kw = bd_ref.shape[1]
    bd_ref[...], bi_ref[...] = _merge_topk(
        bd_ref[...], bi_ref[...], d, ids, kw
    )

    @pl.when(j == n_index_blocks - 1)
    def _done():
        od_ref[...] = bd_ref[...]
        oi_ref[...] = bi_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_neighbors", "mode", "block_q", "block_n", "interpret"),
)
def zen_topk(
    queries: Array,
    index: Array,
    n_neighbors: int = 10,
    mode: str = "zen",
    *,
    scales: Optional[Array] = None,
    block_q: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> Tuple[Array, Array]:
    """Streaming top-k under an estimator: (Q, k) x (N, k) -> (Q, n), (Q, n).

    ``index`` may be stored quantised (bf16: just pass the narrow array;
    int8: also pass the (N, 1) per-row ``scales``) — the tile is dequantised
    in-register right after the VMEM load, so the f32 index never exists and
    DMA traffic stays at the storage width.

    Returns (distances f32, indices int32), each (Q, n_neighbors), rows sorted
    ascending by distance. Never materialises a (Q, N) matrix.
    """
    q, kdim = queries.shape
    n, kdim2 = index.shape
    assert kdim == kdim2, (queries.shape, index.shape)
    assert n_neighbors > 0, n_neighbors
    n_neighbors = min(n_neighbors, n)  # clamp: only valid rows are returned
    bq = min(block_q, _rup(q, 8))
    bn = min(block_n, _rup(n, 128))
    kw = _rup(n_neighbors, 128)  # scratch lane width
    Qp, Np, Kp = _rup(q, bq), _rup(n, bn), _rup(kdim, 128)
    Qpad = jnp.pad(queries, ((0, Qp - q), (0, Kp - kdim)))
    Xpad = jnp.pad(index, ((0, Np - n), (0, Kp - kdim)))
    n_index_blocks = Np // bn

    in_specs = [
        pl.BlockSpec((bq, Kp), lambda i, j: (i, 0)),
        pl.BlockSpec((bn, Kp), lambda i, j: (j, 0)),
    ]
    operands = [Qpad, Xpad]
    if scales is not None:
        assert scales.shape == (n, 1), (scales.shape, n)
        # padded rows get scale 0: they dequantise to the origin and are
        # masked by the id bound below anyway
        operands.append(jnp.pad(scales.astype(jnp.float32),
                                ((0, Np - n), (0, 0))))
        in_specs.append(pl.BlockSpec((bn, 1), lambda i, j: (j, 0)))

    out_d, out_i = pl.pallas_call(
        functools.partial(
            _topk_kernel,
            true_k=kdim,
            n_index=n,
            n_index_blocks=n_index_blocks,
            mode=_MODE[mode],
            has_scale=scales is not None,
        ),
        grid=(Qp // bq, n_index_blocks),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bq, kw), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, kw), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, kw), jnp.float32),
            jax.ShapeDtypeStruct((Qp, kw), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, kw), jnp.float32),
            pltpu.VMEM((bq, kw), jnp.int32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
        name="nsimplex_zen_topk",
    )(*operands)
    return out_d[:q, :n_neighbors], out_i[:q, :n_neighbors]


@functools.partial(
    jax.jit, static_argnames=("n_neighbors", "mode", "chunk")
)
def zen_topk_scan(
    queries: Array,
    index: Array,
    n_neighbors: int = 10,
    mode: str = "zen",
    *,
    scales: Optional[Array] = None,
    chunk: int = 4096,
) -> Tuple[Array, Array]:
    """Bounded-memory jnp fallback: fori_loop of dynamic index slices.

    Peak live distance state is one (Q, chunk) block + the (Q, n_neighbors)
    running best — flat in index size, matching the kernel's memory bound.
    The index is sliced in place (no padded copy): the final chunk is clamped
    back to ``n - chunk`` and its already-visited rows masked out, so no
    O(N) temporary is ever allocated. ``scales`` (N, 1) dequantises an int8
    index chunk-by-chunk (same contract as :func:`zen_topk`).
    """
    q, kdim = queries.shape
    n = index.shape[0]
    assert n_neighbors > 0, n_neighbors
    n_neighbors = min(n_neighbors, n)  # clamp: only valid rows are returned
    chunk = min(chunk, n)
    acc = jnp.promote_types(queries.dtype, jnp.float32)
    queries = queries.astype(acc)
    n_chunks = -(-n // chunk)  # ceil

    mode_i = _MODE[mode]
    qn = jnp.sum(queries * queries, axis=1, keepdims=True)  # (Q, 1)
    qa = queries[:, -1:]  # (Q, 1) altitudes

    def body(i, carry):
        best_d, best_i = carry
        start = jnp.minimum(i * chunk, n - chunk)  # clamp the tail chunk
        blk = jax.lax.dynamic_slice_in_dim(index, start, chunk, axis=0)
        blk = blk.astype(acc)
        if scales is not None:  # dequantise one chunk at a time
            blk = blk * jax.lax.dynamic_slice_in_dim(
                scales, start, chunk, axis=0).astype(acc)
        xn = jnp.sum(blk * blk, axis=1)  # (chunk,)
        dot = jnp.matmul(
            queries[:, :-1], blk[:, :-1].T, preferred_element_type=acc
        )
        z2 = qn + xn[None, :] - 2.0 * dot
        if mode_i != 0:
            cross = 2.0 * qa * blk[:, -1][None, :]
            z2 = z2 - cross if mode_i == 1 else z2 + cross
        d = jnp.sqrt(jnp.maximum(z2, 0.0))
        ids = (start + jnp.arange(chunk, dtype=jnp.int32)).astype(jnp.int32)
        # a clamped tail revisits rows of the previous chunk: mask them out
        d = jnp.where(ids[None, :] >= i * chunk, d, jnp.inf)
        return _merge_topk(best_d, best_i, d, ids[None, :], n_neighbors)

    init = (
        jnp.full((q, n_neighbors), jnp.inf, acc),
        jnp.full((q, n_neighbors), -1, jnp.int32),
    )
    best_d, best_i = jax.lax.fori_loop(0, n_chunks, body, init)
    return best_d, best_i


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
