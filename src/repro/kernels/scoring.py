"""Shared Zen/Lwb/Upb scoring + running-top-k helpers for streaming kernels.

Both streaming retrieval kernels — the brute-force ``zen_topk`` walk over the
whole index and the clustered ``ivf_probe`` walk over probed inverted-list
tiles — fuse the same two inner loops:

  1. estimator distances between a query block and one index tile
     (masked-last-column matmul + rank-1 altitude correction, paper §4.1);
  2. a merge of that tile's distances into a running per-query best-k
     (concat + ``lax.top_k``), kept in VMEM scratch on TPU.

This module is that shared inner loop, factored out so the two kernels (and
their jnp scan fallbacks) cannot drift apart numerically. ``estimate_tile``
operates on lane-padded 2D tiles as seen inside a Pallas kernel body;
``estimate_rows`` is the batched-gather variant used by the IVF scan fallback
where every query gathers its *own* (rows, k) tile.

Both accept an optional ``scale`` for quantised index tiles
(``kernels.quantize``): the tile is multiplied by its symmetric int8 scale
in-register, immediately after the cast to f32 — the dequantised tile never
exists outside the kernel body, so VMEM/DMA traffic stays at the storage
width while every norm/matmul keeps accumulating in float32. bf16 tiles need
no scale at all: the existing ``astype(float32)`` is their dequantisation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

#: estimator name -> static integer id used inside kernel bodies
MODE_IDS = {"zen": 0, "lwb": 1, "upb": 2}


def estimate_tile(
    q: Array, x: Array, *, true_k: int, mode: int,
    scale: Optional[Array] = None,
) -> Array:
    """Fused estimator distances for one (bq, kp) x (bn, kp) tile, f32.

    ``kp`` may be lane-padded beyond the true coordinate width ``true_k``;
    padding columns and the altitude column are masked in-register. ``mode``
    is the static id from :data:`MODE_IDS`. ``scale`` (scalar or (bn, 1),
    broadcastable over ``x``) dequantises an int8 tile on the fly; ``x``
    must already be cast to f32 by the caller in that case.
    """
    if scale is not None:
        x = x * scale
    kp = q.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, kp), 1)
    keep = (col < true_k - 1).astype(jnp.float32)  # mask altitude + padding
    valid = (col < true_k).astype(jnp.float32)  # mask padding only
    qv = q * valid
    xv = x * valid
    nq = jnp.sum(qv * qv, axis=1, keepdims=True)  # (bq, 1) full norms
    nx = jnp.sum(xv * xv, axis=1, keepdims=True)  # (bn, 1)
    dot = jax.lax.dot_general(
        qv * keep,
        xv,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # altitude column zeroed on one side only — enough to drop it
    z2 = nq + nx.T - 2.0 * dot
    if mode != 0:
        is_alt = (col == true_k - 1).astype(jnp.float32)
        qa = jnp.sum(qv * is_alt, axis=1, keepdims=True)  # (bq, 1)
        xa = jnp.sum(xv * is_alt, axis=1, keepdims=True)  # (bn, 1)
        cross = 2.0 * qa * xa.T
        z2 = z2 - cross if mode == 1 else z2 + cross
    return jnp.sqrt(jnp.maximum(z2, 0.0))


def estimate_rows(
    q: Array, blk: Array, *, mode: int, scale: Optional[Array] = None
) -> Array:
    """Estimator distances between queries (Q, k) and per-query row tiles
    (Q, R, k) — the gathered-inverted-list shape of the IVF scan fallback.

    Unpadded widths (no lane masking); returns (Q, R) in the accumulation
    dtype of ``q``. ``scale`` (broadcastable over ``blk``, e.g. the
    (Q, 1, 1) per-cluster scales of the gathered tiles) dequantises int8
    tiles in place; ``blk`` must already be in the dtype of ``q`` then.
    """
    if scale is not None:
        blk = blk * scale
    qn = jnp.sum(q * q, axis=1, keepdims=True)  # (Q, 1)
    xn = jnp.sum(blk * blk, axis=-1)  # (Q, R)
    dot = jnp.einsum(
        "qk,qrk->qr", q[:, :-1], blk[..., :-1],
        preferred_element_type=q.dtype,
    )
    z2 = qn + xn - 2.0 * dot
    if mode != 0:
        cross = 2.0 * q[:, -1:] * blk[..., -1]
        z2 = z2 - cross if mode == 1 else z2 + cross
    return jnp.sqrt(jnp.maximum(z2, 0.0))


def lut_estimate_tile(lut: Array, codes: Array) -> Array:
    """LUT-gather estimator over one PQ code tile, as seen in a kernel body.

    Args:
      lut:   (M, E) f32 per-(query, cluster) ADC table (``kernels.pq
             .build_luts``); ``sum_m lut[m, code[m]]`` is the squared
             estimator distance (mode folding already applied).
      codes: (rows, M) integer codes of one tile.

    Returns (1, rows) f32 distances. The gather is expressed as a one-hot
    contraction — ``codes == iota`` mask dotted against the table over both
    the subspace and entry axes — which lowers to an MXU matmul on TPU
    (Pallas has no native vector gather from VMEM) and is exact: each row's
    result is the f32 sum of exactly M table entries, the rest multiply
    by 0.
    """
    rows, m = codes.shape
    e = lut.shape[1]
    hot = (codes.astype(jnp.int32)[:, :, None]
           == jax.lax.broadcasted_iota(jnp.int32, (rows, m, e), 2)
           ).astype(jnp.float32)
    z2 = jax.lax.dot_general(
        hot, lut.astype(jnp.float32),
        dimension_numbers=(((1, 2), (0, 1)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (rows,)
    return jnp.sqrt(jnp.maximum(z2, 0.0))[None, :]


def lut_estimate_rows(luts: Array, codes: Array) -> Array:
    """Batched LUT-gather for the PQ scan fallback: per-query code blocks.

    Args:
      luts:  (Q, M, E) f32 ADC tables of the probed cluster per query.
      codes: (Q, R, M) integer codes of the gathered tiles.

    Returns (Q, R) f32 distances — a plain ``take_along_axis`` gather, the
    jnp mirror of :func:`lut_estimate_tile`'s one-hot contraction.
    """
    idx = codes.astype(jnp.int32).transpose(0, 2, 1)    # (Q, M, R)
    g = jnp.take_along_axis(luts.astype(jnp.float32), idx, axis=2)
    z2 = jnp.sum(g, axis=1)                             # (Q, R)
    return jnp.sqrt(jnp.maximum(z2, 0.0))


def mask_invalid(d: Array, ids: Array) -> Array:
    """+inf out candidate slots whose id is negative.

    One predicate covers every kind of dead slot in the retrieval layouts —
    never-used tile padding, shard padding, *and* tombstoned (deleted) rows —
    because all of them are encoded as id ``-1``. Keeping the mask here means
    the Pallas kernels, the scan fallbacks, and the host-side id remapping in
    serving all agree on what "not a real candidate" means. Broadcasts:
    ``d`` (Q, r) against ``ids`` (Q, r) or (1, r).
    """
    return jnp.where(ids >= 0, d, jnp.inf)


def merge_topk(
    best_d: Array, best_i: Array, d: Array, ids: Array, k: int
) -> Tuple[Array, Array]:
    """Merge tile distances into the running best-k: concat + ``lax.top_k``.

    ``best_d``/``best_i`` are (Q, w) running state, ``d``/``ids`` the new
    (Q, r) candidates (``ids`` may be (1, r) and is broadcast). Returns the
    new (Q, k) state, ascending by distance.
    """
    cat_d = jnp.concatenate([best_d, d], axis=1)
    cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, d.shape)], axis=1)
    neg, pos = jax.lax.top_k(-cat_d, k)
    return -neg, jnp.take_along_axis(cat_i, pos, axis=1)
