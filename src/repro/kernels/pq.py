"""Per-cluster-residual product quantizer — the "pq" storage mode.

The paper's compression claim (apex coordinates carry little information per
axis at low target dimension) caps out at 4x under scalar int8; product
quantisation is the next rung. Each IVF member stores, instead of its k
float32 apex coordinates, M uint8 codes: the member's *residual* against its
coarse centroid is split into M contiguous subspaces of ``ds = ceil(k / M)``
dims and each sub-vector is snapped to the nearest entry of a 256-entry
per-subspace codebook, trained by the same ``index.kmeans`` Lloyd's loop as
the coarse quantizer. 4 bytes instead of 64 at (k=16, M=4) — 16x — with the
codebooks (M, 256, ds) f32 a fixed few-KB overhead.

Residuals are taken against the *globally assigned* centroid (same invariant
as ``quantize.cluster_scales``): the stored codes depend only on the global
k-means assignment, never on tile packing or shard count, which is what
keeps PQ snapshots bit-identical across device counts.

Scoring is asymmetric-distance computation (ADC, Jégou et al.): queries stay
f32, and for every (query, probed cluster) pair a ``(M, 256)`` lookup table
of per-subspace squared distances

  lut[m, j] = || (q - c)_m  -  codebook[m, j] ||^2

is built once at query time (:func:`build_luts`), so that the Zen squared
distance to a member decoding to ``x_hat = c + decode(code)`` is an M-term
table gather:

  z2(q, x_hat) = sum_m lut[m, code[m]]

The Lwb/Upb altitude cross-term ``-+ 2 q_alt x_hat_alt`` is *folded into the
table* of the subspace holding the altitude column (``x_hat_alt`` is affine
in the codeword), so the probe kernels are estimator-mode-agnostic: one
LUT-gather body (``kernels.scoring.lut_estimate_tile`` / ``_rows``) serves
all three modes, and a PQ probe is bit-for-bit the plain estimator evaluated
on the decoded coordinates.

Width padding: when ``M`` does not divide k the subspace view is zero-padded
to ``M * ds`` columns. Padded residual columns are exactly zero, Lloyd
centroids over them stay exactly zero (means and reseeds of zeros), so the
padding contributes exactly 0.0 to every table entry — no epsilon drift
between the padded and unpadded formulations.

Everything but :func:`build_luts` is host-side numpy on the control plane
(build / upsert / compact / snapshot load); ``build_luts`` is jit-traceable
and runs on the query path.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

Array = jax.Array

#: codebook entries per subspace — one uint8 code addresses exactly this
PQ_ENTRIES = 256

#: target subspace width used by :func:`default_m` (4 dims per code byte)
_TARGET_DS = 4


def default_m(kdim: int) -> int:
    """The default subspace count for k-dim coordinates: ~4 dims per code.

    ``max(1, kdim // 4)`` — e.g. k=16 -> M=4 (16x vs f32), k=8 -> M=2.
    """
    return max(1, kdim // _TARGET_DS)


def subspace_dims(kdim: int, m: int) -> int:
    """ds = ceil(k / M), the per-subspace width (columns padded to M*ds)."""
    if not 1 <= m <= kdim:
        raise ValueError(f"pq_m must be in [1, k={kdim}], got {m}")
    return -(-kdim // m)


def split_subspaces(x: np.ndarray, m: int) -> np.ndarray:
    """(n, k) f32 -> (n, M, ds) f32 subspace view, zero-padded to M*ds."""
    x = np.asarray(x, np.float32)
    n, kdim = x.shape
    ds = subspace_dims(kdim, m)
    pad = m * ds - kdim
    if pad:
        x = np.concatenate([x, np.zeros((n, pad), np.float32)], axis=1)
    return x.reshape(n, m, ds)


def train_codebooks(
    residuals: np.ndarray,
    m: int,
    *,
    key: Optional[Array] = None,
    n_iters: int = 15,
) -> np.ndarray:
    """Fit (M, 256, ds) f32 codebooks on (n, k) residuals via Lloyd's loop.

    Each subspace trains independently with ``index.kmeans.kmeans_fit``
    (k-means++ D^2 seeding, empty-cluster reseeding) under a per-subspace
    fold of ``key`` — fully deterministic for a fixed key. When the corpus
    holds fewer than 256 rows the trailing codebook entries repeat entry 0:
    an exact-duplicate entry can never win an ``argmin`` tie (first
    occurrence wins), so codes stay dense in the trained range.
    """
    # deferred: index.kmeans sits above kernels in the import order and
    # importing it at module scope would cycle through repro.index.__init__
    from repro.index.kmeans import kmeans_fit

    key = key if key is not None else jax.random.PRNGKey(0)
    sub = split_subspaces(residuals, m)  # (n, M, ds)
    n, _, ds = sub.shape
    if n == 0:
        return np.zeros((m, PQ_ENTRIES, ds), np.float32)
    entries = min(PQ_ENTRIES, n)
    books = np.zeros((m, PQ_ENTRIES, ds), np.float32)
    for i in range(m):
        cents, _ = kmeans_fit(
            jnp.asarray(sub[:, i, :]), entries,
            key=jax.random.fold_in(key, i), n_iters=n_iters)
        books[i, :entries] = np.asarray(cents, np.float32)
        if entries < PQ_ENTRIES:
            books[i, entries:] = books[i, 0]
    return books


def encode(residuals: np.ndarray, codebooks: np.ndarray) -> np.ndarray:
    """(n, k) f32 residuals -> (n, M) uint8 nearest-entry codes."""
    from repro.index.kmeans import kmeans_assign

    m, entries, _ = codebooks.shape
    assert entries == PQ_ENTRIES, codebooks.shape
    sub = split_subspaces(residuals, m)  # (n, M, ds)
    n = sub.shape[0]
    codes = np.zeros((n, m), np.uint8)
    if n == 0:
        return codes
    for i in range(m):
        a = kmeans_assign(jnp.asarray(sub[:, i, :]),
                          jnp.asarray(codebooks[i]))
        codes[:, i] = np.asarray(a, np.int64).astype(np.uint8)
    return codes


def decode(codes: np.ndarray, codebooks: np.ndarray, kdim: int) -> np.ndarray:
    """(n, M) uint8 codes -> (n, k) f32 reconstructed residuals."""
    codes = np.asarray(codes)
    m, _, ds = codebooks.shape
    assert codes.ndim == 2 and codes.shape[1] == m, codes.shape
    gathered = np.asarray(codebooks, np.float32)[
        np.arange(m)[None, :], codes.astype(np.int64)]  # (n, M, ds)
    return gathered.reshape(codes.shape[0], m * ds)[:, :kdim]


def code_bytes(n: int, m: int) -> int:
    """Resident bytes of n members' codes (the compression numerator)."""
    return n * m


def build_luts(
    queries: Array,
    centroids: Array,
    codebooks: Array,
    probes: Array,
    mode: int,
) -> Array:
    """Per-(query, probed cluster) ADC tables — (Q, P, M, 256) f32.

    Args:
      queries:   (Q, k) f32 apex query coordinates.
      centroids: (C, k) f32 coarse centroids (the residual anchors).
      codebooks: (M, 256, ds) f32 subspace codebooks.
      probes:    (Q, P) int32 probed cluster ids.
      mode:      static estimator id (``scoring.MODE_IDS``); for lwb/upb the
                 altitude cross-term is folded into the table of the
                 subspace owning the altitude column, making the downstream
                 gather mode-agnostic.

    ``sum_m lut[q, p, m, code[m]]`` equals the mode's squared estimator
    distance between query q and a member of cluster ``probes[q, p]``
    decoding to ``centroid + decode(code)``. Tables stay resident (VMEM on
    TPU) while the uint8 code tiles stream through the probe kernel.
    """
    q_n, kdim = queries.shape
    m, entries, ds = codebooks.shape
    kp = m * ds
    qp = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, kp - kdim)))
    cp = jnp.pad(centroids.astype(jnp.float32), ((0, 0), (0, kp - kdim)))
    cb = codebooks.astype(jnp.float32)
    r = qp[:, None, :] - cp[probes]                  # (Q, P, kp) residual
    r = r.reshape(q_n, probes.shape[1], m, ds)       # (Q, P, M, ds)
    rn = jnp.sum(r * r, axis=-1)                     # (Q, P, M)
    cn = jnp.sum(cb * cb, axis=-1)                   # (M, E)
    dot = jnp.einsum("qpmd,med->qpme", r, cb,
                     preferred_element_type=jnp.float32)
    lut = rn[..., None] + cn[None, None] - 2.0 * dot  # (Q, P, M, E)
    # the base table is the plain squared Euclidean ||q - x_hat||^2, which
    # IS the Lwb estimator (paper §4.1: lwb^2 = sum_i<alt (q_i - x_i)^2 +
    # (q_alt - x_alt)^2); Zen replaces the altitude term by q_alt^2 +
    # x_alt^2 (+ 2 q_alt x_alt on top of lwb^2) and Upb by (q_alt +
    # x_alt)^2 (+ 4 q_alt x_alt). Fold the correction into the table of
    # the subspace owning the altitude column: x_alt = centroid_alt +
    # codebook[ma, j, da] is affine in the codeword.
    if mode != 1:
        ma, da = (kdim - 1) // ds, (kdim - 1) % ds
        qa = queries[:, -1].astype(jnp.float32)      # (Q,)
        ca = centroids[:, -1].astype(jnp.float32)[probes]  # (Q, P)
        cba = cb[ma, :, da]                          # (E,)
        cross = qa[:, None, None] * (ca[..., None] + cba[None, None])
        mult = 2.0 if mode == 0 else 4.0
        lut = lut.at[:, :, ma, :].add(mult * cross)
    return lut
