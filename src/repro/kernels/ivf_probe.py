"""Pallas TPU kernel: fused IVF probe — gather probed cluster tiles, score,
keep a running top-k.

The clustered index (``repro.index.ivf``) stores each cluster's members in a
fixed number ``T`` of fixed-size row tiles:

  tile_coords : (C*T, tile_rows, k)   member apex coordinates
  tile_ids    : (C*T, tile_rows)      global row ids, -1 = padding

so the tiles of cluster ``c`` are the blocks ``c*T .. c*T+T-1`` and every
shape is static under jit regardless of the (data-dependent) cluster sizes.

Given per-query probe lists ``probes`` (Q, P) of cluster ids, the kernel runs
on a (Q, P*T) grid with ``probes`` as a *scalar-prefetch* operand: the block
index maps read ``probes[i, j // T] * T + j % T`` to DMA exactly the probed
tiles from HBM — un-probed clusters are never touched, which is what makes
the probe sublinear in index size. Each grid step fuses the Zen/Lwb/Upb
estimator over one tile (``kernels.scoring.estimate_tile`` — shared with the
brute-force ``zen_topk`` kernel) with the concat + ``top_k`` merge into VMEM
scratch; dead rows (id == -1: tile padding *and* tombstoned deletes — the
mutable-index path reuses the same encoding, ``kernels.scoring.mask_invalid``)
are masked to +inf before the merge. Peak
per-query state is O(kw + tile_rows), independent of both index size and
cluster-size skew.

``ivf_probe_scan`` is the schedule-equivalent jnp fallback for CPU/GPU: a
``fori_loop`` over the same (probe, tile) steps, gathering one
(Q, tile_rows, k) block per step — the same flat memory bound.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params
from .scoring import (
    MODE_IDS, estimate_rows, estimate_tile, lut_estimate_rows,
    lut_estimate_tile, mask_invalid, merge_topk,
)

Array = jax.Array


def _probe_kernel(
    probes_ref,  # scalar-prefetch (Q, P) — also consumed by the index maps
    q_ref,       # (1, kp)
    x_ref,       # (1, tile_rows, kp) — the probed tile
    id_ref,      # (1, tile_rows)
    *rest,       # [s_ref (1, 1)] od_ref oi_ref + scratch bd_ref bi_ref
    true_k: int,
    n_steps: int,
    mode: int,
    has_scale: bool,
):
    del probes_ref  # only the index maps need it
    if has_scale:  # the probed cluster's dequant scale rides along
        s_ref, od_ref, oi_ref, bd_ref, bi_ref = rest
    else:
        od_ref, oi_ref, bd_ref, bi_ref = rest
        s_ref = None
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, jnp.inf)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    q = q_ref[...].astype(jnp.float32)          # (1, kp)
    x = x_ref[0].astype(jnp.float32)            # (tile_rows, kp)
    ids = id_ref[...]                           # (1, tile_rows)
    scale = s_ref[0, 0] if has_scale else None
    d = estimate_tile(
        q, x, true_k=true_k, mode=mode, scale=scale)  # (1, tile_rows)
    d = mask_invalid(d, ids)                    # padding + tombstones

    kw = bd_ref.shape[1]
    bd_ref[...], bi_ref[...] = merge_topk(bd_ref[...], bi_ref[...], d, ids, kw)

    @pl.when(j == n_steps - 1)
    def _done():
        od_ref[...] = bd_ref[...]
        oi_ref[...] = bi_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_neighbors", "mode", "tiles_per_cluster", "interpret"),
)
def ivf_probe(
    queries: Array,
    tile_coords: Array,
    tile_ids: Array,
    probes: Array,
    n_neighbors: int = 10,
    mode: str = "zen",
    *,
    tiles_per_cluster: int,
    tile_scales: Optional[Array] = None,
    interpret: bool = False,
) -> Tuple[Array, Array]:
    """Clustered top-k probe: score only the tiles of the probed clusters.

    Args:
      queries:     (Q, k) projected queries.
      tile_coords: (C*T, tile_rows, k) packed cluster tiles — stored f32,
                   bf16 or int8 (``kernels.quantize``).
      tile_ids:    (C*T, tile_rows) int32 global row ids, -1 = padding.
      probes:      (Q, P) int32 cluster ids to visit per query.
      tiles_per_cluster: T — tiles per cluster in the packed layout.
      tile_scales: (C, 1) f32 per-cluster symmetric scales when
                   ``tile_coords`` is int8; the probed cluster's scale is
                   DMA'd through the same prefetched index map as its tiles
                   and the dequant fuses into the estimator.

    Returns (distances f32, indices int32), each (Q, n_neighbors), rows
    ascending by distance; slots beyond the number of valid candidates in the
    probed clusters come back as (+inf, -1).
    """
    q, kdim = queries.shape
    ct, tile_rows, kdim2 = tile_coords.shape
    assert kdim == kdim2, (queries.shape, tile_coords.shape)
    assert tile_ids.shape == (ct, tile_rows), tile_ids.shape
    assert probes.shape[0] == q, (probes.shape, queries.shape)
    assert ct % tiles_per_cluster == 0, (ct, tiles_per_cluster)
    T = tiles_per_cluster
    n_probe = probes.shape[1]
    n_steps = n_probe * T
    kw = _rup(n_neighbors, 128)  # scratch lane width
    Kp = _rup(kdim, 128)
    Qpad = jnp.pad(queries, ((0, 0), (0, Kp - kdim)))
    Xpad = jnp.pad(tile_coords, ((0, 0), (0, 0), (0, Kp - kdim)))

    in_specs = [
        pl.BlockSpec((1, Kp), lambda i, j, pref: (i, 0)),
        pl.BlockSpec(
            (1, tile_rows, Kp),
            lambda i, j, pref: (pref[i, j // T] * T + j % T, 0, 0),
        ),
        pl.BlockSpec(
            (1, tile_rows),
            lambda i, j, pref: (pref[i, j // T] * T + j % T, 0),
        ),
    ]
    operands = [Qpad, Xpad, tile_ids]
    if tile_scales is not None:
        assert tile_scales.shape == (ct // T, 1), (tile_scales.shape, ct, T)
        # the probed *cluster* id indexes the scales directly
        in_specs.append(pl.BlockSpec(
            (1, 1), lambda i, j, pref: (pref[i, j // T], 0)))
        operands.append(tile_scales.astype(jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q, n_steps),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, kw), lambda i, j, pref: (i, 0)),
            pl.BlockSpec((1, kw), lambda i, j, pref: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, kw), jnp.float32),
            pltpu.VMEM((1, kw), jnp.int32),
        ],
    )
    out_d, out_i = pl.pallas_call(
        functools.partial(
            _probe_kernel, true_k=kdim, n_steps=n_steps, mode=MODE_IDS[mode],
            has_scale=tile_scales is not None,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q, kw), jnp.float32),
            jax.ShapeDtypeStruct((q, kw), jnp.int32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
        name="nsimplex_ivf_probe",
    )(probes.astype(jnp.int32), *operands)
    return out_d[:, :n_neighbors], out_i[:, :n_neighbors]


@functools.partial(
    jax.jit, static_argnames=("n_neighbors", "mode", "tiles_per_cluster")
)
def ivf_probe_scan(
    queries: Array,
    tile_coords: Array,
    tile_ids: Array,
    probes: Array,
    n_neighbors: int = 10,
    mode: str = "zen",
    *,
    tiles_per_cluster: int,
    tile_scales: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Bounded-memory jnp fallback: fori_loop over (probe, tile) steps.

    Each step gathers one (Q, tile_rows, k) block of the probed clusters'
    tiles and merges into the running (Q, n_neighbors) best — peak temp
    memory is one tile per query, flat in index size and in cluster count.
    ``tile_scales`` (C, 1) dequantises int8 tiles one gathered block at a
    time (same contract as :func:`ivf_probe`).
    """
    q, kdim = queries.shape
    ct, tile_rows, _ = tile_coords.shape
    T = tiles_per_cluster
    assert ct % T == 0, (ct, T)
    n_steps = probes.shape[1] * T
    acc = jnp.promote_types(queries.dtype, jnp.float32)
    queries = queries.astype(acc)
    mode_i = MODE_IDS[mode]

    def body(j, carry):
        best_d, best_i = carry
        p, t = j // T, j % T
        c = jax.lax.dynamic_slice_in_dim(probes, p, 1, axis=1)[:, 0]
        b = c.astype(jnp.int32) * T + t             # (Q,) tile block ids
        blk = tile_coords[b].astype(acc)            # (Q, tile_rows, k)
        ids = tile_ids[b]                           # (Q, tile_rows)
        scale = None
        if tile_scales is not None:  # per-query probed-cluster scales
            scale = tile_scales[c.astype(jnp.int32)].astype(acc)[:, :, None]
        d = estimate_rows(queries, blk, mode=mode_i, scale=scale)
        d = mask_invalid(d, ids)                    # padding + tombstones
        return merge_topk(best_d, best_i, d, ids, n_neighbors)

    init = (
        jnp.full((q, n_neighbors), jnp.inf, acc),
        jnp.full((q, n_neighbors), -1, jnp.int32),
    )
    best_d, best_i = jax.lax.fori_loop(0, n_steps, body, init)
    return best_d.astype(jnp.float32), best_i


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# -- product-quantised probe ---------------------------------------------------
#
# Same schedule as the scalar probe above — (Q, P*T) grid, scalar-prefetched
# probe list, running top-k in VMEM scratch — but the streamed operand is the
# (C*T, tile_rows, M) uint8 *code* tiles (16-32x less DMA than f32 coords)
# and the estimator is an asymmetric-distance LUT gather: the per-(query,
# probed-cluster) (M, 256) tables built once by ``kernels.pq.build_luts``
# stay VMEM-resident per grid step while codes stream past. All estimator
# mode handling lives in the table construction, so the kernel body is
# mode-agnostic.


def _probe_pq_kernel(
    probes_ref,  # scalar-prefetch (Q, P)
    lut_ref,     # (1, M, E) — this (query, probe column)'s ADC table
    x_ref,       # (1, tile_rows, M) uint8 — the probed code tile
    id_ref,      # (1, tile_rows)
    od_ref,
    oi_ref,
    bd_ref,      # scratch (1, kw) f32
    bi_ref,      # scratch (1, kw) int32
    *,
    n_steps: int,
):
    del probes_ref  # only the index maps need it
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, jnp.inf)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    codes = x_ref[0]                             # (tile_rows, M) uint8
    ids = id_ref[...]                            # (1, tile_rows)
    d = lut_estimate_tile(lut_ref[0], codes)     # (1, tile_rows)
    d = mask_invalid(d, ids)                     # padding + tombstones

    kw = bd_ref.shape[1]
    bd_ref[...], bi_ref[...] = merge_topk(bd_ref[...], bi_ref[...], d, ids, kw)

    @pl.when(j == n_steps - 1)
    def _done():
        od_ref[...] = bd_ref[...]
        oi_ref[...] = bi_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_neighbors", "tiles_per_cluster", "interpret"),
)
def ivf_probe_pq(
    tile_codes: Array,
    tile_ids: Array,
    probes: Array,
    luts: Array,
    n_neighbors: int = 10,
    *,
    tiles_per_cluster: int,
    interpret: bool = False,
) -> Tuple[Array, Array]:
    """Clustered top-k probe over PQ code tiles with fused LUT scoring.

    Args:
      tile_codes: (C*T, tile_rows, M) uint8 packed member codes
                  (``kernels.pq``); cluster ``c`` owns blocks
                  ``c*T .. c*T+T-1`` exactly like the scalar layout.
      tile_ids:   (C*T, tile_rows) int32 global row ids, -1 = padding.
      probes:     (Q, P) int32 cluster ids to visit per query.
      luts:       (Q, P, M, E) f32 ADC tables (``pq.build_luts``) — the
                  table of probe column ``p`` rides to the grid step through
                  a plain block index map (no prefetch: ``p = j // T`` is
                  grid-computable) and stays in VMEM for that cluster's T
                  tiles.
      tiles_per_cluster: T.

    Returns (distances f32, indices int32), each (Q, n_neighbors),
    ascending; unfilled slots are (+inf, -1). Distances equal the estimator
    on the *decoded* member coordinates — the mode folding happened in the
    tables.
    """
    ct, tile_rows, m = tile_codes.shape
    q, n_probe = probes.shape
    assert ct % tiles_per_cluster == 0, (ct, tiles_per_cluster)
    assert luts.shape[:2] == (q, n_probe), (luts.shape, probes.shape)
    assert luts.shape[2] == m, (luts.shape, tile_codes.shape)
    assert tile_ids.shape == (ct, tile_rows), tile_ids.shape
    T = tiles_per_cluster
    n_steps = n_probe * T
    e = luts.shape[3]
    kw = _rup(n_neighbors, 128)
    # (Q, P, M, E) -> (Q*P, M, E): 3D blocks with a grid-computed leading
    # index keep the block maps rank-uniform for Mosaic
    luts3 = luts.astype(jnp.float32).reshape(q * n_probe, m, e)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q, n_steps),
        in_specs=[
            pl.BlockSpec(
                (1, m, e), lambda i, j, pref: (i * n_probe + j // T, 0, 0)),
            pl.BlockSpec(
                (1, tile_rows, m),
                lambda i, j, pref: (pref[i, j // T] * T + j % T, 0, 0),
            ),
            pl.BlockSpec(
                (1, tile_rows),
                lambda i, j, pref: (pref[i, j // T] * T + j % T, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, kw), lambda i, j, pref: (i, 0)),
            pl.BlockSpec((1, kw), lambda i, j, pref: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, kw), jnp.float32),
            pltpu.VMEM((1, kw), jnp.int32),
        ],
    )
    out_d, out_i = pl.pallas_call(
        functools.partial(_probe_pq_kernel, n_steps=n_steps),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q, kw), jnp.float32),
            jax.ShapeDtypeStruct((q, kw), jnp.int32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
        name="nsimplex_ivf_probe_pq",
    )(probes.astype(jnp.int32), luts3, tile_codes, tile_ids)
    return out_d[:, :n_neighbors], out_i[:, :n_neighbors]


@functools.partial(
    jax.jit, static_argnames=("n_neighbors", "tiles_per_cluster")
)
def ivf_probe_pq_scan(
    tile_codes: Array,
    tile_ids: Array,
    probes: Array,
    luts: Array,
    n_neighbors: int = 10,
    *,
    tiles_per_cluster: int,
) -> Tuple[Array, Array]:
    """Bounded-memory jnp fallback for the PQ probe: fori_loop over
    (probe, tile) steps, gathering one (Q, tile_rows, M) code block and its
    (Q, M, E) tables per step (same contract as :func:`ivf_probe_pq`)."""
    q = probes.shape[0]
    ct, tile_rows, _ = tile_codes.shape
    T = tiles_per_cluster
    assert ct % T == 0, (ct, T)
    n_steps = probes.shape[1] * T
    luts = luts.astype(jnp.float32)

    def body(j, carry):
        best_d, best_i = carry
        p, t = j // T, j % T
        c = jax.lax.dynamic_slice_in_dim(probes, p, 1, axis=1)[:, 0]
        b = c.astype(jnp.int32) * T + t              # (Q,) tile block ids
        blk = tile_codes[b]                          # (Q, tile_rows, M)
        ids = tile_ids[b]                            # (Q, tile_rows)
        lut_p = jax.lax.dynamic_slice_in_dim(
            luts, p, 1, axis=1)[:, 0]                # (Q, M, E)
        d = lut_estimate_rows(lut_p, blk)
        d = mask_invalid(d, ids)
        return merge_topk(best_d, best_i, d, ids, n_neighbors)

    init = (
        jnp.full((q, n_neighbors), jnp.inf, jnp.float32),
        jnp.full((q, n_neighbors), -1, jnp.int32),
    )
    best_d, best_i = jax.lax.fori_loop(0, n_steps, body, init)
    return best_d, best_i
