"""Host -> device staging of cold inverted-list tile buffers.

The tiered tile store (``index.ivf.TieredIVFZenIndex``) keeps most packed
tiles in a host-resident pool and uploads only the buffers a probe batch
needs. :func:`stage_blocks` is the single upload primitive:

* **TPU** — the buffer is placed in ``pinned_host`` memory
  (``kernels._compat.pinned_host_sharding``) and :func:`dma_copy_blocks`
  streams it block by block with explicitly double-buffered
  ``pltpu.make_async_copy`` DMAs: while block ``i`` is written out, the
  copy for block ``i+1`` is already in flight, so the probe kernel that
  consumes the result never waits on a transfer it already knew it needed.
* **CPU / GPU** — ``jax.device_put``, which is itself asynchronous: the
  store issues the put for the *next* probe chunk before scoring the
  current one, giving the same overlap without a kernel.

Both paths return an ordinary committed device array; callers never branch
on backend.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params, pinned_host_sharding

Array = jax.Array


def _copy_kernel(src_ref, out_ref, buf_ref, sem_ref):
    """Double-buffered blockwise copy: src (ANY/host) -> out (VMEM blocks)."""
    i = pl.program_id(0)
    n = pl.num_programs(0)
    slot = i % 2
    nxt = (i + 1) % 2

    @pl.when(i == 0)
    def _start_first():
        pltpu.make_async_copy(
            src_ref.at[i], buf_ref.at[slot], sem_ref.at[slot]
        ).start()

    @pl.when(i + 1 < n)
    def _prefetch_next():
        pltpu.make_async_copy(
            src_ref.at[i + 1], buf_ref.at[nxt], sem_ref.at[nxt]
        ).start()

    pltpu.make_async_copy(
        src_ref.at[i], buf_ref.at[slot], sem_ref.at[slot]
    ).wait()
    out_ref[0] = buf_ref[slot]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dma_copy_blocks(src: Array, *, interpret: bool = False) -> Array:
    """Copy a (B, ...) block array up through VMEM with overlapped DMAs.

    ``src`` may live in host (pinned) memory; each (1, ...) block is pulled
    with a manual async copy while the previous block drains to the output,
    so the transfer is fully pipelined. Grid is serial ("arbitrary"): the
    two scratch slots alternate between steps.
    """
    blk = src.shape[1:]
    return pl.pallas_call(
        _copy_kernel,
        grid=(src.shape[0],),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(
            (1,) + blk, lambda i: (i,) + (0,) * len(blk)
        ),
        out_shape=jax.ShapeDtypeStruct(src.shape, src.dtype),
        scratch_shapes=[
            pltpu.VMEM((2,) + blk, src.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="nsimplex_tile_stage",
    )(src)


def stage_blocks(host_vals: np.ndarray, *, force_kernel: bool = False) -> Array:
    """Upload one packed block buffer; returns immediately (async transfer).

    Args:
      host_vals: (B, ...) numpy (or memmap) buffer of tile blocks.
      force_kernel: run the Pallas DMA path in interpret mode off-TPU
                    (parity testing).
    """
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_kernel):
        return jax.device_put(jnp.asarray(host_vals))
    pinned = pinned_host_sharding()
    if pinned is not None:
        staged = jax.device_put(np.ascontiguousarray(host_vals), pinned)
    else:  # interpret-mode parity off-TPU: no pinned space to start from
        staged = jnp.asarray(host_vals)
    return dma_copy_blocks(staged, interpret=not on_tpu)
