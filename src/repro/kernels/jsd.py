"""Pallas TPU kernel: blocked Jensen-Shannon distance matrix (paper App. A.3).

  D(v, w) = sqrt(1 - 0.5 * sum_l [h(v_l) + h(w_l) - h(v_l + w_l)]),
  h(t) = -t log2(t), 0 log 0 := 0.

The paper motivates nSimplex for JSD spaces by JSD being ~2 orders of
magnitude more expensive than cosine; the cross term sum_l h(v_l + w_l) has no
matmul form (elementwise transcendental), so the kernel tiles (N, M) on the
grid, streams the feature dimension through VMEM in bm-chunks, and runs an
inner fori_loop of rank-1 "h-outer-product" updates on the VPU. Per-row
entropies h(v), h(w) accumulate in the same pass, avoiding a separate sweep.

Zero-padding the feature dimension is exact: h(0 + 0) = 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params

Array = jax.Array

_INNER = 16  # feature columns folded per fori_loop step


def _h(t: Array) -> Array:
    safe = jnp.where(t > 0, t, 1.0)
    return jnp.where(t > 0, -t * jnp.log2(safe), 0.0)


def _jsd_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_m_blocks: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, bm)
    y = y_ref[...].astype(jnp.float32)  # (bk, bm)
    bm = x.shape[1]

    # self-entropy partials fold into the accumulator as rank-1 row/col bias:
    # acc -= 0.5*(h(v) + h(w));  acc += 0.5*h(v+w)  chunk by chunk.
    hx = jnp.sum(_h(x), axis=1, keepdims=True)  # (bn, 1)
    hy = jnp.sum(_h(y), axis=1, keepdims=True)  # (bk, 1)

    def body(i, acc):
        xs = jax.lax.dynamic_slice_in_dim(x, i * _INNER, _INNER, axis=1)
        ys = jax.lax.dynamic_slice_in_dim(y, i * _INNER, _INNER, axis=1)
        cross = jnp.sum(_h(xs[:, None, :] + ys[None, :, :]), axis=-1)
        return acc + cross

    steps = bm // _INNER
    cross = jax.lax.fori_loop(
        0, steps, body, jnp.zeros(acc_ref.shape, jnp.float32)
    )
    acc_ref[...] += 0.5 * (cross - hx - hy.T)

    @pl.when(pl.program_id(2) == n_m_blocks - 1)
    def _done():
        o_ref[...] = jnp.sqrt(
            jnp.clip(1.0 + acc_ref[...], 0.0, 1.0)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_k", "block_m", "interpret")
)
def jsd_pdist(
    X: Array,
    Y: Array,
    *,
    block_n: int = 128,
    block_k: int = 128,
    block_m: int = 256,
    interpret: bool = False,
) -> Array:
    """(N, m) x (K, m) l1-normalised rows -> (N, K) Jensen-Shannon distances."""
    n, m = X.shape
    k, m2 = Y.shape
    assert m == m2, (X.shape, Y.shape)
    bn, bk = min(block_n, _rup(n, 8)), min(block_k, _rup(k, 128))
    bm = min(block_m, _rup(m, _INNER))
    bm = _rup(bm, _INNER)
    Np, Kp, Mp = _rup(n, bn), _rup(k, bk), _rup(m, bm)
    Xp = jnp.pad(X, ((0, Np - n), (0, Mp - m)))
    Yp = jnp.pad(Y, ((0, Kp - k), (0, Mp - m)))
    n_m_blocks = Mp // bm

    out = pl.pallas_call(
        functools.partial(_jsd_kernel, n_m_blocks=n_m_blocks),
        grid=(Np // bn, Kp // bk, n_m_blocks),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bm), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Kp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bk), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
        name="nsimplex_jsd",
    )(Xp, Yp)
    return out[:n, :k]


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
