"""Pallas TPU kernel: blocked pairwise squared-Euclidean distance matrix.

out[i, j] = ||X[i]||^2 + ||Y[j]||^2 - 2 <X[i], Y[j]>

This is the nSimplex transform's hot loop (N objects x K references over m
original dimensions) and the first stage of every metric-space query. The
kernel is matmul-shaped: grid (N/bn, K/bk, m/bm); each (i, j) tile accumulates
partial norms and the -2xy dot product over m-chunks in a float32 VMEM scratch
accumulator, so the MXU runs the dot while the VPU fuses the norm terms.
Feature-dim padding with zeros is exact (zeros change neither norms nor dots).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params

Array = jax.Array


def _pdist_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_m_blocks: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, bm)
    y = y_ref[...].astype(jnp.float32)  # (bk, bm)
    # partial squared norms for this m-chunk
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # (bn, 1)
    yn = jnp.sum(y * y, axis=1, keepdims=True)  # (bk, 1)
    dot = jax.lax.dot_general(
        x,
        y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bn, bk)
    acc_ref[...] += xn + yn.T - 2.0 * dot

    @pl.when(pl.program_id(2) == n_m_blocks - 1)
    def _done():
        o_ref[...] = jnp.maximum(acc_ref[...], 0.0).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_k", "block_m", "interpret")
)
def pdist_sq(
    X: Array,
    Y: Array,
    *,
    block_n: int = 128,
    block_k: int = 128,
    block_m: int = 512,
    interpret: bool = False,
) -> Array:
    """(N, m) x (K, m) -> (N, K) squared Euclidean distances, f32.

    Shapes need not be padded by the caller; padding happens here.
    """
    n, m = X.shape
    k, m2 = Y.shape
    assert m == m2, (X.shape, Y.shape)
    bn, bk, bm = min(block_n, _rup(n, 8)), min(block_k, _rup(k, 128)), min(
        block_m, _rup(m, 128)
    )
    Np, Kp, Mp = _rup(n, bn), _rup(k, bk), _rup(m, bm)
    Xp = jnp.pad(X, ((0, Np - n), (0, Mp - m)))
    Yp = jnp.pad(Y, ((0, Kp - k), (0, Mp - m)))
    n_m_blocks = Mp // bm

    out = pl.pallas_call(
        functools.partial(_pdist_kernel, n_m_blocks=n_m_blocks),
        grid=(Np // bn, Kp // bk, n_m_blocks),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bm), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Kp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bk), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
        name="nsimplex_pdist",
    )(Xp, Yp)
    return out[:n, :k]


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
