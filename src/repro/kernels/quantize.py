"""Storage-dtype subsystem for index tiles: bf16 casts and symmetric int8.

The paper's point that apex coordinates carry little information per axis at
low target dimension k is exactly why the *serving index* is the right place
to spend fewer bits per coordinate: the (N, k) / (C*T, tile_rows, k) resident
arrays dominate index memory and scan bandwidth, while the estimator math
(``kernels.scoring``) keeps accumulating in float32 regardless of how the
tiles are stored. Four storage modes:

  float32   the identity — what every index used before this subsystem;
  bfloat16  a plain cast (same exponent range as f32, 8-bit mantissa): half
            the bytes, no scale state, exact for values that are already
            bf16-representable;
  int8      symmetric linear quantisation ``v ~= q * s`` with ``q`` in
            [-127, 127] and a shared positive scale ``s = absmax / 127``
            per *group* — per index row for the flat layout (robust to the
            far-sentinel dead rows of the mutable flat index), per cluster
            for the IVF tile layout (cluster membership is decided by the
            *global* coarse quantizer, so the scales — and with them the
            quantised values — are identical for any shard count or tile
            repacking; that is what keeps quantised snapshots bit-identical
            across device counts);
  pq        per-cluster-residual product quantisation (``kernels.pq``):
            each member stores M uint8 codebook codes instead of k floats
            (16–32x), scored through per-query asymmetric-distance lookup
            tables. IVF-only — the residual is taken against the member's
            coarse centroid, so there is nothing to encode against in the
            flat layout (``encode_rows`` rejects it).

The mode menu is the single source of truth: every CLI ``--storage`` flag,
error message and benchmark sweep derives its list from
:data:`STORAGE_DTYPES` / :data:`SCALAR_STORAGE_DTYPES` (asserted by a test
that greps the CLI help), so adding a mode cannot leave a stale three-entry
list behind.

Dequantisation is fused into the probe kernels (``scoring.estimate_tile`` /
``estimate_rows`` multiply the tile by its scale in-register right after the
VMEM load), so the f32 form of a tile never exists outside the compute units
and DMA traffic drops with the storage width.

Everything here is host-side numpy: quantisation happens on the control
plane (build / upsert / compact / checkpoint load), never on the query path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

try:  # the bf16 numpy dtype ships with jax via ml_dtypes
    import ml_dtypes

    BFLOAT16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes is a jax dependency
    BFLOAT16 = None

#: the element-wise (scalar) storage modes: every index layout — flat or
#: IVF — supports these, and the quantised-retrieval benchmark sweeps them
SCALAR_STORAGE_DTYPES = ("float32", "bfloat16", "int8")

#: accepted values of the ``storage=`` knob, in decreasing width; "pq"
#: (product-quantised codes, ``kernels.pq``) is IVF-only
STORAGE_DTYPES = SCALAR_STORAGE_DTYPES + ("pq",)

#: symmetric int8 quantisation range (-128 is never produced)
INT8_MAX = 127.0

#: scale floor — an all-zero group quantises to zeros with a harmless
#: positive scale instead of dividing by zero
_SCALE_FLOOR = 1e-30


def check_storage(storage: str) -> str:
    if storage not in STORAGE_DTYPES:
        raise ValueError(
            f"storage must be one of {STORAGE_DTYPES}, got {storage!r}")
    if storage == "bfloat16" and BFLOAT16 is None:  # pragma: no cover
        raise ValueError("bfloat16 storage needs the ml_dtypes package")
    return storage


def storage_help() -> str:
    """The one-line ``--storage`` CLI help text, derived from the menu.

    Centralised so every entry point (``launch.serve``, benchmark CLIs)
    prints the same, complete mode list — a new storage mode shows up in
    every ``--help`` without touching the call sites.
    """
    return (f"resident dtype of the searchable index tiles, one of "
            f"{'/'.join(STORAGE_DTYPES)} (bf16 halves, int8 quarters, pq "
            f"packs M uint8 codes per row — IVF only; estimator "
            f"accumulation stays f32)")


def np_dtype(storage: str):
    """The numpy dtype index values are resident in under ``storage``."""
    check_storage(storage)
    return {"float32": np.dtype(np.float32), "bfloat16": BFLOAT16,
            "int8": np.dtype(np.int8), "pq": np.dtype(np.uint8)}[storage]


def symmetric_scales(absmax: np.ndarray) -> np.ndarray:
    """Per-group scales ``s = max(absmax, floor) / 127`` as float32."""
    return (np.maximum(np.asarray(absmax, np.float32), _SCALE_FLOOR)
            / INT8_MAX).astype(np.float32)


def quantize(x: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Symmetric int8 quantisation of ``x`` with broadcastable ``scales``.

    The group's absmax element lands exactly on +-127 (round of exactly
    127.0), which pins the scale: re-deriving scales from the dequantised
    values reproduces them, so dequantise -> requantise round-trips are
    lossless for untouched groups.
    """
    q = np.rint(np.asarray(x, np.float32) / np.asarray(scales, np.float32))
    return np.clip(q, -INT8_MAX, INT8_MAX).astype(np.int8)


def dequantize(values: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """f32 reconstruction ``q * s`` (broadcastable scales)."""
    return (np.asarray(values, np.float32)
            * np.asarray(scales, np.float32)).astype(np.float32)


def row_scales(x: np.ndarray) -> np.ndarray:
    """(N, 1) per-row scales of a flat (N, k) coordinate array."""
    return symmetric_scales(np.abs(np.asarray(x, np.float32)).max(
        axis=-1, keepdims=True))


def cluster_scales(
    coords: np.ndarray, assign: np.ndarray, n_clusters: int
) -> np.ndarray:
    """(C, 1) per-cluster scales from member coords and their assignment.

    Computed over *all* members of each cluster before any shard split or
    tile packing — the scale depends only on the (global) assignment, never
    on layout, which is the invariant the reshard-on-load path relies on.
    """
    absmax = np.zeros(n_clusters, np.float32)
    if len(assign):
        per_row = np.abs(np.asarray(coords, np.float32)).max(axis=-1)
        np.maximum.at(absmax, np.asarray(assign, np.int64), per_row)
    return symmetric_scales(absmax)[:, None]


def encode_rows(
    x: np.ndarray, storage: str
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Encode a flat (N, k) f32 array: ``(values, row scales or None)``.

    Scalar modes only — "pq" codes are defined relative to a coarse
    centroid, which the flat layout does not have, so it is rejected here
    (use ``index.ivf`` with ``storage="pq"``).
    """
    check_storage(storage)
    if storage == "pq":
        raise ValueError(
            "storage='pq' is IVF-only (codes are per-cluster residuals); "
            "the flat layout supports " + "/".join(SCALAR_STORAGE_DTYPES))
    x = np.asarray(x, np.float32)
    if storage == "float32":
        return x, None
    if storage == "bfloat16":
        return x.astype(BFLOAT16), None
    s = row_scales(x)
    return quantize(x, s), s
