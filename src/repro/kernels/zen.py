"""Pallas TPU kernel: fused Zen / Lwb / Upb estimator matrix (paper §4.1).

For projected points X (N, k), Y (M, k), last coordinate = altitude:

  Zen^2 = ||x||^2 + ||y||^2 - 2 <x[:k-1], y[:k-1]>
  Lwb^2 = Zen^2 - 2 x_{k-1} y_{k-1}
  Upb^2 = Zen^2 + 2 x_{k-1} y_{k-1}

One kernel computes any of the three: the dot product masks the altitude
column in-register (iota mask against the static true width), the altitude
cross term is an MXU-free rank-1 update. k is small (<= a few hundred), so the
whole feature dimension is one block; the grid tiles (N, M) only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params

Array = jax.Array

_MODE = {"zen": 0, "lwb": 1, "upb": 2}


def _zen_kernel(x_ref, y_ref, o_ref, *, true_k: int, mode: int):
    x = x_ref[...].astype(jnp.float32)  # (bn, kp)
    y = y_ref[...].astype(jnp.float32)  # (bm, kp)
    kp = x.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, kp), 1)
    keep = (col < true_k - 1).astype(jnp.float32)  # mask altitude + padding
    valid = (col < true_k).astype(jnp.float32)  # mask padding only
    xv = x * valid
    yv = y * valid
    nx = jnp.sum(xv * xv, axis=1, keepdims=True)  # (bn, 1) full norms
    ny = jnp.sum(yv * yv, axis=1, keepdims=True)  # (bm, 1)
    dot = jax.lax.dot_general(
        xv * keep,
        yv,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # altitude column zeroed on one side only — enough to drop it from <.,.>
    z2 = nx + ny.T - 2.0 * dot
    if mode != 0:
        is_alt = (col == true_k - 1).astype(jnp.float32)
        xa = jnp.sum(xv * is_alt, axis=1, keepdims=True)  # (bn, 1)
        ya = jnp.sum(yv * is_alt, axis=1, keepdims=True)  # (bm, 1)
        cross = 2.0 * xa * ya.T
        z2 = z2 - cross if mode == 1 else z2 + cross
    o_ref[...] = jnp.sqrt(jnp.maximum(z2, 0.0)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("mode", "block_n", "block_m", "interpret")
)
def zen_estimate(
    X: Array,
    Y: Array,
    mode: str = "zen",
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool = False,
) -> Array:
    """(N, k) x (M, k) -> (N, M) estimator distances, f32."""
    n, k = X.shape
    m, k2 = Y.shape
    assert k == k2, (X.shape, Y.shape)
    bn, bm = min(block_n, _rup(n, 8)), min(block_m, _rup(m, 128))
    Np, Mp, Kp = _rup(n, bn), _rup(m, bm), _rup(k, 128)
    Xp = jnp.pad(X, ((0, Np - n), (0, Kp - k)))
    Yp = jnp.pad(Y, ((0, Mp - m), (0, Kp - k)))

    out = pl.pallas_call(
        functools.partial(_zen_kernel, true_k=k, mode=_MODE[mode]),
        grid=(Np // bn, Mp // bm),
        in_specs=[
            pl.BlockSpec((bn, Kp), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, Kp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Mp), jnp.float32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
        name="nsimplex_zen",
    )(Xp, Yp)
    return out[:n, :m]


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
