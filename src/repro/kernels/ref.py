"""Pure-jnp oracles for every Pallas kernel in this package.

These are the single source of truth the kernels are tested against
(interpret=True on CPU; compiled on TPU). They deliberately reuse nothing from
the kernel implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pdist_sq_ref(X: Array, Y: Array) -> Array:
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    d2 = (
        jnp.sum(X * X, 1)[:, None]
        + jnp.sum(Y * Y, 1)[None, :]
        - 2.0 * (X @ Y.T)
    )
    return jnp.maximum(d2, 0.0)


def zen_estimate_ref(X: Array, Y: Array, mode: str = "zen") -> Array:
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    base = jnp.sum(
        (X[:, None, :-1] - Y[None, :, :-1]) ** 2, axis=-1
    )
    xa, ya = X[:, -1], Y[:, -1]
    if mode == "zen":
        z2 = base + (xa**2)[:, None] + (ya**2)[None, :]
    elif mode == "lwb":
        z2 = base + (xa[:, None] - ya[None, :]) ** 2
    elif mode == "upb":
        z2 = base + (xa[:, None] + ya[None, :]) ** 2
    else:
        raise ValueError(mode)
    return jnp.sqrt(jnp.maximum(z2, 0.0))


def _h(t: Array) -> Array:
    safe = jnp.where(t > 0, t, 1.0)
    return jnp.where(t > 0, -t * jnp.log2(safe), 0.0)


def jsd_pdist_ref(X: Array, Y: Array) -> Array:
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    hx = jnp.sum(_h(X), axis=1)
    hy = jnp.sum(_h(Y), axis=1)
    cross = jnp.sum(_h(X[:, None, :] + Y[None, :, :]), axis=-1)
    K = 1.0 - 0.5 * (hx[:, None] + hy[None, :] - cross)
    return jnp.sqrt(jnp.clip(K, 0.0, 1.0))
