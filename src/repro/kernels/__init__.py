"""Pallas TPU kernels for the nSimplex hot loops, validated in interpret mode.

Layout (per repo convention):
  pdist.py / zen.py / jsd.py — pl.pallas_call kernels with explicit BlockSpecs
  zen_topk.py                — streaming fused estimator + running top-k
  ivf_probe.py               — clustered probe over scalar-prefetched tiles
  scoring.py                 — estimator + top-k-merge inner loop shared by
                               zen_topk and ivf_probe (and their fallbacks)
  quantize.py                — bf16 / symmetric-int8 storage for index tiles
                               (dequant fuses into the scoring inner loop)
  pq.py                      — per-cluster-residual product quantizer codec
                               + ADC lookup tables (the "pq" storage mode)
  ops.py                     — jit'd public wrappers with backend dispatch
  ref.py                     — pure-jnp oracles, the correctness source of truth
"""
from . import ivf_probe, ops, pq, quantize, ref, scoring, zen_topk
from .ops import jsd_pdist, pdist_sq, zen_estimate

__all__ = [
    "ivf_probe", "ops", "pq", "quantize", "ref", "scoring", "zen_topk",
    "pdist_sq", "zen_estimate", "jsd_pdist",
]
