"""Pallas TPU kernels for the nSimplex hot loops, validated in interpret mode.

Layout (per repo convention):
  pdist.py / zen.py / jsd.py — pl.pallas_call kernels with explicit BlockSpecs
  zen_topk.py                — streaming fused estimator + running top-k
  ops.py                     — jit'd public wrappers with backend dispatch
  ref.py                     — pure-jnp oracles, the correctness source of truth
"""
from . import ops, ref, zen_topk
from .ops import jsd_pdist, pdist_sq, zen_estimate

__all__ = ["ops", "ref", "zen_topk", "pdist_sq", "zen_estimate", "jsd_pdist"]
