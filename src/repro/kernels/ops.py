"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: compiled Pallas on TPU backends; on CPU (this container) the
wrappers run the *same kernel body* under ``interpret=True`` when
``force_kernel=True`` (tests / small shapes), and otherwise fall back to the
pure-jnp reference, which XLA:CPU fuses well. The numerics of all three paths
agree to f32 tolerance (asserted in tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ivf_probe as _ivf_probe
from . import jsd as _jsd
from . import pdist as _pdist
from . import ref as _ref
from . import zen as _zen
from . import zen_topk as _zen_topk

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pdist_sq(X: Array, Y: Array, *, force_kernel: bool = False, **block_kw) -> Array:
    """Pairwise squared Euclidean distances (N, K); kernel-accelerated."""
    if _on_tpu():
        return _pdist.pdist_sq(X, Y, **block_kw)
    if force_kernel:
        return _pdist.pdist_sq(X, Y, interpret=True, **block_kw)
    return _ref.pdist_sq_ref(X, Y)


def pdist(X: Array, Y: Array, **kw) -> Array:
    return jnp.sqrt(pdist_sq(X, Y, **kw))


def zen_estimate(
    X: Array, Y: Array, mode: str = "zen", *, force_kernel: bool = False, **block_kw
) -> Array:
    """Zen/Lwb/Upb estimator matrix (N, M); kernel-accelerated."""
    if _on_tpu():
        return _zen.zen_estimate(X, Y, mode, **block_kw)
    if force_kernel:
        return _zen.zen_estimate(X, Y, mode, interpret=True, **block_kw)
    return _ref.zen_estimate_ref(X, Y, mode)


def zen_topk(
    queries: Array,
    index: Array,
    n_neighbors: int = 10,
    mode: str = "zen",
    *,
    scales: Array = None,
    force_kernel: bool = False,
    chunk: int = 4096,
    **block_kw,
):
    """Streaming top-k retrieval under an estimator; kernel-accelerated.

    Args:
      queries:     (Q, k) projected query coordinates.
      index:       (N, k) projected index coordinates, stored f32, bf16 or
                   int8 (``kernels.quantize``); dequantisation fuses into
                   the estimator on every path.
      n_neighbors: results per query (clamped to N).
      mode:        estimator: "zen", "lwb" or "upb".
      scales:      (N, 1) f32 per-row symmetric scales when ``index`` is
                   int8; None otherwise.
      force_kernel: run the Pallas kernel in interpret mode off-TPU.
      chunk:       row tile of the scan fallback (its memory bound).

    Returns (distances f32, indices int32), each (Q, n_neighbors),
    ascending by distance, without ever materialising the (Q, N) estimator
    matrix. Dispatch: fused Pallas kernel on TPU (or under ``force_kernel``
    via interpret mode); otherwise the lax.scan fallback with the same
    O(chunk)-per-query memory bound.

    >>> import jax, jax.numpy as jnp
    >>> X = jax.random.normal(jax.random.PRNGKey(0), (100, 8), jnp.float32)
    >>> d, ids = zen_topk(X[:2], X, n_neighbors=3)
    >>> d.shape, ids.shape
    ((2, 3), (2, 3))
    >>> bool((ids >= 0).all())   # only real rows are returned
    True
    """
    if _on_tpu():
        return _zen_topk.zen_topk(
            queries, index, n_neighbors, mode, scales=scales, **block_kw
        )
    if force_kernel:
        return _zen_topk.zen_topk(
            queries, index, n_neighbors, mode, scales=scales,
            interpret=True, **block_kw
        )
    return _zen_topk.zen_topk_scan(
        queries, index, n_neighbors, mode, scales=scales, chunk=chunk
    )


def ivf_probe(
    queries: Array,
    tile_coords: Array,
    tile_ids: Array,
    probes: Array,
    n_neighbors: int = 10,
    mode: str = "zen",
    *,
    tiles_per_cluster: int,
    tile_scales: Array = None,
    force_kernel: bool = False,
):
    """Clustered IVF top-k probe over packed cluster tiles; kernel-accelerated.

    Dispatch: scalar-prefetch Pallas kernel on TPU (or under ``force_kernel``
    via interpret mode) — only the probed clusters' tiles are ever DMA'd;
    otherwise a fori_loop gather fallback with the same one-tile-per-step
    memory bound. ``tile_coords`` may be stored bf16 or int8
    (``kernels.quantize``); int8 tiles carry (C, 1) per-cluster
    ``tile_scales`` and are dequantised inside the estimator on every path.
    Returns (distances, indices), each (Q, n_neighbors); unfilled slots are
    (+inf, -1).
    """
    if _on_tpu():
        return _ivf_probe.ivf_probe(
            queries, tile_coords, tile_ids, probes, n_neighbors, mode,
            tiles_per_cluster=tiles_per_cluster, tile_scales=tile_scales,
        )
    if force_kernel:
        return _ivf_probe.ivf_probe(
            queries, tile_coords, tile_ids, probes, n_neighbors, mode,
            tiles_per_cluster=tiles_per_cluster, tile_scales=tile_scales,
            interpret=True,
        )
    return _ivf_probe.ivf_probe_scan(
        queries, tile_coords, tile_ids, probes, n_neighbors, mode,
        tiles_per_cluster=tiles_per_cluster, tile_scales=tile_scales,
    )


def ivf_probe_pq(
    tile_codes: Array,
    tile_ids: Array,
    probes: Array,
    luts: Array,
    n_neighbors: int = 10,
    *,
    tiles_per_cluster: int,
    force_kernel: bool = False,
):
    """Clustered top-k probe over PQ code tiles; kernel-accelerated.

    The product-quantised sibling of :func:`ivf_probe`: the streamed operand
    is the (C*T, tile_rows, M) uint8 code tiles and scoring is an
    asymmetric-distance LUT gather against the per-(query, probed cluster)
    (M, 256) tables in ``luts`` (``kernels.pq.build_luts`` — estimator mode
    is folded into the tables, so no ``mode`` argument here). Dispatch
    mirrors every other kernel: scalar-prefetch Pallas kernel on TPU (or
    under ``force_kernel`` via interpret mode), fori_loop gather fallback
    elsewhere. Returns (distances, indices), each (Q, n_neighbors);
    unfilled slots are (+inf, -1).
    """
    if _on_tpu():
        return _ivf_probe.ivf_probe_pq(
            tile_codes, tile_ids, probes, luts, n_neighbors,
            tiles_per_cluster=tiles_per_cluster,
        )
    if force_kernel:
        return _ivf_probe.ivf_probe_pq(
            tile_codes, tile_ids, probes, luts, n_neighbors,
            tiles_per_cluster=tiles_per_cluster, interpret=True,
        )
    return _ivf_probe.ivf_probe_pq_scan(
        tile_codes, tile_ids, probes, luts, n_neighbors,
        tiles_per_cluster=tiles_per_cluster,
    )


def jsd_pdist(
    X: Array, Y: Array, *, force_kernel: bool = False, **block_kw
) -> Array:
    """Jensen-Shannon distance matrix (N, K); kernel-accelerated."""
    if _on_tpu():
        return _jsd.jsd_pdist(X, Y, **block_kw)
    if force_kernel:
        return _jsd.jsd_pdist(X, Y, interpret=True, **block_kw)
    return _ref.jsd_pdist_ref(X, Y)
