"""Serving frontend: micro-batching scheduler + projection/result cache.

The paper's deployment story is cheap online queries — a query needs only
its k reference distances to be projected and scored — so serving cost is
dominated by how efficiently query traffic is fed to the fused top-k /
IVF-probe kernels. This package sits between callers and the index:

  * ``scheduler.MicroBatchScheduler`` coalesces concurrent ``submit()``
    calls into one kernel dispatch per tick, pads each dispatch to a
    power-of-two query bucket and a fixed ``n_neighbors`` menu (so the jit
    cache holds a handful of entries instead of one per caller shape), and
    splits oversized coalesced batches at ``max_batch``.
  * ``cache.LRUCache`` is the projection/result cache, keyed on the
    query's canonical f32 bytes plus (mode, width, nprobe, rerank,
    index generation) — churn bumps the generation and silently
    invalidates every stale entry.
  * ``stats.FrontendStats`` carries the SLO instrumentation: p50/p95/p99
    latency, batch occupancy, cache hit rate, dispatch-shape (compile)
    count, reject-on-full backpressure counters, and replica hot-swap
    accounting.
  * ``loadgen.run_open_loop`` measures all of it under *offered* load:
    Poisson arrivals at a configured QPS (open-loop — no coordinated
    omission), latency-vs-offered-load curves, p99 under overload with
    the backpressure shedding, single servers or replica fleets
    (``launch.replicate``) round-robin.

``launch.serve.ZenServer(frontend=True)`` wires the three together; the
scheduler takes an injectable clock/ticker so tests drive it step by step
with no real threads sleeping (``tests/test_frontend.py``).
"""
from .cache import LRUCache, query_fingerprint
from .loadgen import OpenLoopReport, poisson_arrivals, run_open_loop
from .scheduler import (
    DEFAULT_NEIGHBOR_MENU,
    FrontendOverloadError,
    MicroBatchScheduler,
    QueryHandle,
    bucket_neighbors,
    bucket_q,
)
from .stats import FrontendStats

__all__ = [
    "DEFAULT_NEIGHBOR_MENU",
    "FrontendOverloadError",
    "FrontendStats",
    "LRUCache",
    "MicroBatchScheduler",
    "OpenLoopReport",
    "QueryHandle",
    "bucket_neighbors",
    "bucket_q",
    "poisson_arrivals",
    "query_fingerprint",
    "run_open_loop",
]
