"""LRU projection/result cache for the serving frontend.

A cache entry is the *served* answer for one query row at one dispatch
width: the (n_bucket,) distances and external ids that came out of the
bucketed projection + search (+ optional exact re-rank) pipeline. Because
every dispatch path — direct or scheduled — computes at the same bucketed
shapes, a cached row is bit-identical to what a fresh dispatch would
return, so hits are indistinguishable from recomputation.

Keys quantise the query to its canonical float32 byte string
(:func:`query_fingerprint`) and append everything the answer depends on:
estimator mode, bucketed fetch/output widths, ``nprobe``, the re-rank
factor, and the **index generation** — a counter ``ZenIndex`` /
``IVFZenIndex`` bump on every upsert/delete/compact. Churn therefore never
serves stale results: old-generation entries can no longer be looked up
and age out of the LRU ring naturally.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable, Optional, Tuple

import numpy as np


def query_fingerprint(row: np.ndarray) -> bytes:
    """Canonical byte string of one query row (the cache's quantisation).

    The row is cast to contiguous little-endian float32 first, so the same
    logical query hits the cache whether the caller passed float64, a
    non-contiguous slice, or a jax array — while queries that differ in
    even one f32 ulp never alias (cache hits must stay bit-identical to a
    fresh dispatch).
    """
    return np.ascontiguousarray(row, dtype="<f4").tobytes()


def result_key(
    fingerprint: bytes,
    mode: str,
    fetch_width: int,
    n_bucket: int,
    nprobe: int,
    rerank_factor: int,
    generation: int,
) -> Tuple[Hashable, ...]:
    """Full cache key of one served query row (see module docstring)."""
    return (fingerprint, mode, fetch_width, n_bucket, nprobe,
            rerank_factor, generation)


class LRUCache:
    """Bounded least-recently-used map with hit/miss accounting.

    Not thread-safe on its own — the scheduler serialises access under its
    queue lock. ``capacity <= 0`` disables the cache entirely (every
    ``get`` misses, ``put`` is a no-op), which lets callers keep one code
    path for the cached and uncached configurations.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._data: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.stale_evictions = 0  # dropped by evict_stale (generation swap)

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: Hashable) -> Optional[Any]:
        """Return the cached value (refreshing recency) or ``None``."""
        if self.capacity <= 0:
            self.misses += 1
            return None
        try:
            value = self._data.pop(key)
        except KeyError:
            self.misses += 1
            return None
        self._data[key] = value  # re-insert at the MRU end
        self.hits += 1
        return value

    def put(self, key: Hashable, value: Any) -> None:
        """Insert (or refresh) an entry, evicting the LRU tail if full."""
        if self.capacity <= 0:
            return
        if key in self._data:
            self._data.pop(key)
        elif len(self._data) >= self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1
        self._data[key] = value

    def evict_stale(self, generation: int) -> int:
        """Drop entries keyed on any generation other than ``generation``.

        Correctness never needs this — a key embeds its generation, so a
        stale entry can no longer be *looked up* after churn or a replica
        hot-swap. But the dead entries still occupy LRU capacity and would
        evict live ones; a replica calls this right after swapping to a
        freshly published generation (``MicroBatchScheduler.on_index_swap``)
        so the cache restarts the new generation at full capacity. Returns
        the number of entries dropped (also counted in ``stale_evictions``).
        """
        stale = [k for k in self._data
                 if isinstance(k, tuple) and k and k[-1] != generation]
        for k in stale:
            del self._data[k]
        self.stale_evictions += len(stale)
        return len(stale)

    def clear(self) -> None:
        """Drop every entry and restart the hit/miss accounting."""
        self._data.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.stale_evictions = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def info(self) -> dict:
        return {
            "entries": len(self._data),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "stale_evictions": self.stale_evictions,
            "hit_rate": round(self.hit_rate, 4),
        }
