"""SLO instrumentation for the serving frontend.

One ``FrontendStats`` object per scheduler collects everything an operator
needs to see whether the frontend is earning its keep:

  * **latency** — per-request submit-to-complete wall time (measured with
    the scheduler's injectable clock, so simulation tests get exact
    deterministic values), reported as p50/p95/p99 over a bounded window;
  * **batch occupancy** — real rows per dispatch over the padded bucket
    size; low occupancy means the tick interval is too short or traffic
    too thin for batching to pay;
  * **cache hit rate** — forwarded from the LRU projection/result cache;
  * **compile pressure** — the set of distinct dispatch shapes
    ``(Q_bucket, fetch_width, n_bucket)`` seen so far; its size bounds the
    number of jit cache entries the query path can create, and must stay
    at most the bucket-menu size;
  * **backpressure** — submitted/rejected/completed row counters for the
    bounded admission queue.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Tuple

import numpy as np

#: latency samples kept for the percentile window (oldest dropped first)
LATENCY_WINDOW = 4096


class FrontendStats:
    """Counters + bounded latency window for one scheduler (see module doc)."""

    def __init__(self, window: int = LATENCY_WINDOW):
        self.submitted = 0        # rows accepted into the frontend
        self.rejected = 0         # rows refused by reject-on-full
        self.completed = 0        # rows answered (cache hits included)
        self.failures = 0         # rows resolved with a dispatch error
        self.cache_hits = 0
        self.cache_misses = 0
        self.dispatches = 0       # kernel dispatches issued
        self.dispatched_rows = 0  # real rows across all dispatches
        self.padded_rows = 0      # padded (bucketed) rows across dispatches
        self.ticks = 0
        self.swaps = 0            # replica hot-swaps absorbed (replication)
        self.serving_generation = None  # generation after the last swap
        self.dispatch_shapes: set = set()  # distinct (Qp, w, n_bucket)
        self._latency_s: Deque[float] = deque(maxlen=window)

    # -- recording hooks -----------------------------------------------------
    def record_submit(self, rows: int) -> None:
        self.submitted += rows

    def record_reject(self, rows: int) -> None:
        self.rejected += rows

    def record_failure(self, rows: int) -> None:
        """Rows whose dispatch raised (their handles carry the error)."""
        self.failures += rows

    def record_cache(self, hits: int, misses: int) -> None:
        self.cache_hits += hits
        self.cache_misses += misses

    def record_tick(self) -> None:
        self.ticks += 1

    def record_swap(self, generation: int) -> None:
        """One replica hot-swap to a newly published index generation."""
        self.swaps += 1
        self.serving_generation = int(generation)

    def record_dispatch(
        self, shape: Tuple[int, int, int], real_rows: int, padded_rows: int
    ) -> None:
        """One kernel dispatch: its bucketed shape and fill level."""
        self.dispatches += 1
        self.dispatched_rows += real_rows
        self.padded_rows += padded_rows
        self.dispatch_shapes.add(shape)

    def record_complete(self, rows: int, latency_s: float) -> None:
        self.completed += rows
        self._latency_s.append(latency_s)

    # -- derived -------------------------------------------------------------
    @property
    def occupancy(self) -> float:
        """Mean dispatch fill: real rows / padded bucket rows."""
        return (self.dispatched_rows / self.padded_rows
                if self.padded_rows else 0.0)

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    @property
    def compile_count(self) -> int:
        """Distinct dispatch shapes — an upper bound on query-path compiles."""
        return len(self.dispatch_shapes)

    def latency_percentiles(self) -> dict:
        """p50/p95/p99 over the window — NaN until the first sample lands.

        An idle frontend must not report a perfect p99: fabricating a 0.0 ms
        sample would satisfy any SLO check before a single query ran.
        """
        if not self._latency_s:
            nan = float("nan")
            return {"p50_ms": nan, "p95_ms": nan, "p99_ms": nan}
        lat = np.asarray(self._latency_s, np.float64)
        return {
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p95_ms": float(np.percentile(lat, 95) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
        }

    def snapshot(self) -> dict:
        """Flat dict for ``ZenServer.stats()`` / logging. Latency percentile
        keys are omitted until at least one sample exists — absent beats a
        NaN that breaks naive JSON serialisation of operator dashboards."""
        out = {
            "submitted": self.submitted,
            "rejected": self.rejected,
            "completed": self.completed,
            "failures": self.failures,
            "ticks": self.ticks,
            "dispatches": self.dispatches,
            "batch_occupancy": round(self.occupancy, 4),
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": round(self.cache_hit_rate, 4),
            "compile_count": self.compile_count,
        }
        if self.swaps:
            out["swaps"] = self.swaps
            out["serving_generation"] = self.serving_generation
        if self._latency_s:
            out.update(self.latency_percentiles())
        return out
