"""Micro-batching query scheduler with shape-bucketed dispatch.

Many small callers, one kernel stream: concurrent ``submit()`` calls land
in a bounded admission queue; each ``tick()`` drains the queue, groups the
pending rows by their bucketed result shape, coalesces every group into
dispatches of at most ``max_batch`` rows, pads each dispatch's row count
to a **power-of-two Q bucket** (floor 2 — Q=1 lowers to a matvec whose
distance bits differ at 1 ulp from the batched matmul, so it is never
dispatched), and hands the padded block to ``ZenServer._query_block``.
The direct (unscheduled) path pads to the same buckets, so every response
— scheduled, cached, or direct — is bit-identical, and the jit cache
holds one entry per (Q bucket, width bucket) pair instead of one per
caller shape.

Determinism is a design requirement, not an accident: the scheduler never
sleeps on its own. ``tick()`` is a plain synchronous function; tests call
it step by step with a fake injected ``clock`` and observe exactly which
dispatches happen (``tests/test_frontend.py``). Production callers start
the optional ticker thread (``start()``) which just calls ``tick()``
every ``tick_interval`` seconds; ``ZenServer.query`` falls back to
ticking inline when no ticker is running, so single-threaded use needs no
threads at all.

Backpressure is reject-on-full: ``submit`` raises
:class:`FrontendOverloadError` when the queue cannot take the request's
uncached rows, and the reject is counted in ``FrontendStats`` — shedding
load at admission keeps the latency of accepted requests bounded instead
of letting the queue grow without limit.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cache import LRUCache, query_fingerprint, result_key
from .stats import FrontendStats

#: fixed output-width menu: requested n_neighbors is rounded up to the next
#: entry (and to the next power of two beyond the menu), so the kernels only
#: ever compile for these widths
DEFAULT_NEIGHBOR_MENU = (8, 16, 32, 64, 128)

#: smallest dispatched row count — Q=1 is padded up because XLA:CPU lowers
#: it to a matvec whose reduction order differs from the batched matmul
MIN_Q_BUCKET = 2


class FrontendOverloadError(RuntimeError):
    """Raised by ``submit`` when the bounded admission queue is full."""


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def bucket_q(q: int, max_batch: Optional[int] = None) -> int:
    """Power-of-two row bucket for a dispatch of ``q`` real rows.

    >>> [bucket_q(q) for q in (1, 2, 3, 8, 9)]
    [2, 2, 4, 8, 16]
    """
    b = max(_next_pow2(max(q, 1)), MIN_Q_BUCKET)
    return min(b, max_batch) if max_batch else b


def bucket_neighbors(
    n: int, menu: Sequence[int] = DEFAULT_NEIGHBOR_MENU
) -> int:
    """Round a requested ``n_neighbors`` up to the fixed width menu.

    Values beyond the menu keep rounding to the next power of two, so the
    jit cache stays bounded even for off-menu requests.

    >>> [bucket_neighbors(n) for n in (1, 8, 10, 100, 200)]
    [8, 8, 16, 128, 256]
    """
    for m in menu:
        if n <= m:
            return int(m)
    return _next_pow2(n)


class QueryHandle:
    """Future-like response slot for one submitted query batch.

    Rows resolve independently (cache hits immediately, misses when their
    dispatch lands); ``result()`` blocks until every row is filled. The
    buffers are plain numpy so resolution never touches the device.
    """

    def __init__(self, n_rows: int, n_neighbors: int, clock):
        self._d = np.full((n_rows, n_neighbors), np.inf, np.float32)
        self._ids = np.full((n_rows, n_neighbors), -1, np.int32)
        self._remaining = n_rows
        self._clock = clock
        self._t_submit = clock()
        self._event = threading.Event()
        self._error: Optional[BaseException] = None
        self.latency_s: Optional[float] = None
        if n_rows == 0:
            self._event.set()
            self.latency_s = 0.0

    def _fill_row(self, row: int, d: np.ndarray, ids: np.ndarray) -> None:
        if self._error is not None:  # already failed: nothing to deliver
            return
        n = self._d.shape[1]
        self._d[row] = d[:n]
        self._ids[row] = ids[:n]
        self._remaining -= 1
        if self._remaining == 0:
            self.latency_s = self._clock() - self._t_submit
            self._event.set()

    def _fail(self, error: BaseException) -> None:
        """Resolve the handle with an error (dispatch failure): ``result``
        re-raises instead of blocking the caller forever."""
        if not self._event.is_set():
            self._error = error
            self.latency_s = self._clock() - self._t_submit
            self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(
        self, timeout: Optional[float] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(distances, ids), each (Q, n_neighbors) — blocks until resolved.

        Re-raises the dispatch error if the serving attempt failed."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                "query not resolved — is the scheduler ticking? (call "
                "tick()/flush(), or start() the ticker thread)")
        if self._error is not None:
            raise self._error
        return self._d, self._ids


class _Slot:
    """One pending query row: its handle position plus dispatch geometry."""

    __slots__ = ("handle", "row", "qrow", "fingerprint", "n_bucket", "width")

    def __init__(self, handle, row, qrow, fingerprint, n_bucket, width):
        self.handle = handle
        self.row = row
        self.qrow = qrow                  # (m,) f32 raw query vector
        self.fingerprint = fingerprint    # canonical f32 bytes of qrow
        self.n_bucket = n_bucket          # bucketed result width
        self.width = width                # bucketed candidate fetch width


class MicroBatchScheduler:
    """Coalesce concurrent query submissions into bucketed dispatches.

    Args:
      server:        the ``ZenServer`` whose ``_query_block`` serves padded
                     blocks (also supplies mode/nprobe/rerank and the index
                     generation for cache keys).
      max_batch:     largest dispatched row count (rounded up to a power of
                     two); oversized coalesced groups are split into
                     ``max_batch``-row dispatches.
      queue_limit:   bounded admission queue, in rows; ``submit`` raises
                     :class:`FrontendOverloadError` beyond it.
      cache_size:    LRU projection/result cache capacity in rows
                     (0 disables caching).
      neighbor_menu: fixed output-width menu (see :func:`bucket_neighbors`).
      clock:         injectable monotonic time source (tests pass a fake).
      tick_interval: ticker thread period in seconds (only used by
                     ``start()``; ``tick()`` itself never sleeps).
    """

    def __init__(
        self,
        server,
        *,
        max_batch: int = 64,
        queue_limit: int = 4096,
        cache_size: int = 0,
        neighbor_menu: Sequence[int] = DEFAULT_NEIGHBOR_MENU,
        clock=time.monotonic,
        tick_interval: float = 0.002,
    ):
        if max_batch < MIN_Q_BUCKET:
            raise ValueError(f"max_batch must be >= {MIN_Q_BUCKET}")
        self.server = server
        self.max_batch = _next_pow2(max_batch)
        self.queue_limit = int(queue_limit)
        self.neighbor_menu = tuple(neighbor_menu)
        self.clock = clock
        self.tick_interval = tick_interval
        self.cache = LRUCache(cache_size)
        self.stats = FrontendStats()
        self._pending: List[_Slot] = []
        self._lock = threading.Lock()
        self._ticker: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- admission -----------------------------------------------------------
    def _geometry(self, n_neighbors: int) -> Tuple[int, int]:
        """(n_bucket, fetch width) of a request — same math as the direct
        path (``ZenServer._query_geometry``), so cache entries written by
        one path are readable by the other."""
        return self.server._query_geometry(n_neighbors)

    def _cache_key(self, slot: _Slot, generation: Optional[int] = None):
        s = self.server
        gen = s.index.generation if generation is None else generation
        return result_key(
            slot.fingerprint, s.mode, slot.width, slot.n_bucket, s.nprobe,
            s.rerank_factor, gen)

    def submit(self, queries, n_neighbors: int = 10) -> QueryHandle:
        """Enqueue a (Q, m) or (m,) query; returns a :class:`QueryHandle`.

        Cached rows resolve immediately; the rest wait for a tick. Raises
        :class:`FrontendOverloadError` (counting the reject, resolving
        nothing) when the uncached rows would overflow ``queue_limit``.

        Queries are canonicalised to float32 at admission — the serving
        frontend (like the cache fingerprint) is defined on the stack's
        default f32 numerics. Callers running under ``jax_enable_x64``
        who need f64 query precision should use the direct path
        (``ZenServer.query(..., direct=True)``).
        """
        q = np.asarray(queries, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        handle = QueryHandle(q.shape[0], n_neighbors, self.clock)
        if q.shape[0] == 0:
            return handle
        n_bucket, width = self._geometry(n_neighbors)
        slots = [
            _Slot(handle, i, q[i], query_fingerprint(q[i]), n_bucket, width)
            for i in range(q.shape[0])
        ]
        with self._lock:
            # every handle/stats/cache mutation happens under the queue
            # lock: a ticker thread may resolve this handle's uncached
            # rows the moment they land in _pending, and the row
            # countdown / counters are not atomic on their own
            hits = [(s, self.cache.get(self._cache_key(s))) for s in slots]
            misses = [s for s, v in hits if v is None]
            if len(misses) > self.queue_limit:
                # a retry can never succeed — don't dress this up as
                # transient overload (ZenServer.query routes such batches
                # to the direct path instead of submitting them)
                self.stats.record_reject(q.shape[0])
                raise FrontendOverloadError(
                    f"request of {len(misses)} uncached rows exceeds "
                    f"queue_limit={self.queue_limit}; split it or use the "
                    "direct path (ZenServer.query(..., direct=True))")
            if len(self._pending) + len(misses) > self.queue_limit:
                self.stats.record_reject(q.shape[0])
                raise FrontendOverloadError(
                    f"admission queue full ({len(self._pending)}/"
                    f"{self.queue_limit} rows pending); retry later or "
                    "raise queue_limit")
            self.stats.record_submit(q.shape[0])
            self.stats.record_cache(len(slots) - len(misses), len(misses))
            for s, value in hits:
                if value is not None:
                    s.handle._fill_row(s.row, *value)
            if handle.done():
                self.stats.record_complete(q.shape[0], handle.latency_s)
            self._pending.extend(misses)
        return handle

    @property
    def backlog(self) -> int:
        """Rows currently waiting for a dispatch."""
        with self._lock:
            return len(self._pending)

    def on_index_swap(self, generation: int) -> None:
        """Absorb a replica hot-swap to published ``generation``.

        Correctness needs nothing here — every cache key embeds its
        generation, so entries written against the pre-swap index can no
        longer be looked up the moment ``server.index`` points at the new
        snapshot. This hook is the bookkeeping that rides along: count the
        swap in :class:`FrontendStats` and drop the now-unreachable stale
        entries so they stop occupying LRU capacity
        (``LRUCache.evict_stale``). Called by ``launch.replicate``'s
        ``QueryReplica`` after each swap.
        """
        with self._lock:
            self.stats.record_swap(generation)
            self.cache.evict_stale(generation)

    # -- dispatch ------------------------------------------------------------
    def tick(self) -> int:
        """Drain the queue: coalesce, pad, dispatch. Returns dispatch count.

        Synchronous and sleep-free — the deterministic unit the simulation
        tests drive directly, and the only thing the ticker thread does.
        """
        tick_hook = getattr(self.server, "on_tick", None)
        if tick_hook is not None:  # shard liveness + preemption-save refresh
            tick_hook()            # (launch.serve.ZenServer fault tolerance)
        with self._lock:
            pending, self._pending = self._pending, []
        self.stats.record_tick()
        if not pending:
            return 0
        groups: Dict[Tuple[int, int], List[_Slot]] = {}
        for slot in pending:  # FIFO within each result-shape group
            groups.setdefault((slot.width, slot.n_bucket), []).append(slot)
        n_dispatches = 0
        for (width, n_bucket), slots in groups.items():
            for lo in range(0, len(slots), self.max_batch):
                chunk = slots[lo:lo + self.max_batch]
                try:
                    self._dispatch(chunk, width, n_bucket)
                except Exception as exc:  # noqa: BLE001 — fail the waiters,
                    # not the ticker: the popped slots would otherwise hang
                    # their callers forever and kill the tick loop
                    with self._lock:
                        self.stats.record_failure(len(chunk))
                        for slot in chunk:
                            slot.handle._fail(exc)
                else:  # a raised dispatch issued no kernel — don't count it
                    n_dispatches += 1
        return n_dispatches

    def _dispatch(
        self, slots: List[_Slot], width: int, n_bucket: int
    ) -> None:
        """One padded kernel dispatch for ``slots`` (all same geometry)."""
        rows = np.stack([s.qrow for s in slots])
        qp = bucket_q(rows.shape[0], self.max_batch)
        if qp > rows.shape[0]:  # pad with copies of a real row: any valid
            # vector works, the padding rows are sliced off unobserved
            pad = np.broadcast_to(rows[0], (qp - rows.shape[0],
                                            rows.shape[1]))
            rows = np.concatenate([rows, pad])
        # one index snapshot for both the compute and the cache keys:
        # concurrent churn swapping server.index mid-dispatch must not
        # store pre-churn results under the post-churn generation
        index = self.server.index
        d, ids = self.server._query_block(rows, width, n_bucket, index=index)
        d, ids = np.asarray(d), np.asarray(ids)
        with self._lock:  # see submit(): handles/stats/cache share the lock
            self.stats.record_dispatch((qp, width, n_bucket), len(slots), qp)
            done: List[QueryHandle] = []
            for i, slot in enumerate(slots):
                # copies, not views: a row view would pin the whole (Qp,
                # n_bucket) dispatch arrays in the cache
                self.cache.put(self._cache_key(slot, index.generation),
                               (d[i].copy(), ids[i].copy()))
                slot.handle._fill_row(slot.row, d[i], ids[i])
                if slot.handle.done() and slot.handle not in done:
                    done.append(slot.handle)
            for handle in done:
                self.stats.record_complete(handle._d.shape[0],
                                           handle.latency_s)

    def flush(self) -> None:
        """Tick until the queue is empty (inline driving, no ticker)."""
        while True:
            with self._lock:
                if not self._pending:
                    return
            self.tick()

    # -- optional ticker thread ---------------------------------------------
    @property
    def running(self) -> bool:
        return self._ticker is not None and self._ticker.is_alive()

    def start(self) -> "MicroBatchScheduler":
        """Start the background ticker (idempotent). Returns self."""
        if not self.running:
            self._stop.clear()
            self._ticker = threading.Thread(
                target=self._tick_loop, name="zen-frontend-ticker",
                daemon=True)
            self._ticker.start()
        return self

    def stop(self) -> None:
        """Stop the ticker and drain whatever is still queued."""
        self._stop.set()
        if self._ticker is not None:
            self._ticker.join(timeout=5.0)
            self._ticker = None
        self.flush()

    def _tick_loop(self) -> None:
        while not self._stop.wait(self.tick_interval):
            self.tick()
