"""Open-loop load generation: offered QPS, not achieved QPS.

A closed-loop driver (issue, wait, issue) slows itself down exactly when
the server slows down, so its latency numbers silently exclude the
overload region — the classic *coordinated omission* trap. This harness is
**open-loop**: query arrivals are a Poisson process at a configured
*offered* rate, drawn up front (:func:`poisson_arrivals`), and each
arrival is submitted at its scheduled time whether or not earlier queries
have finished. Under overload the bounded admission queue sheds load
(``FrontendOverloadError`` rejects are counted, not retried) and the
latency of *accepted* requests stays bounded — which is the whole point of
reject-on-full backpressure, now measured instead of asserted.

Two further methodology choices:

* **Latency is measured from the scheduled arrival time**, not from the
  moment the driver got around to submitting — a late submit is the
  driver's queueing delay and the client would have experienced it.
* **Replica fleets are driven round-robin from one loop**, each replica's
  scheduler ticked at its own ``tick_interval`` cadence. A replica's
  capacity is therefore its admission budget (``max_batch`` rows per
  tick), the same knob that bounds it in production; aggregate goodput
  scaling with replica count is measured against that per-replica budget.

Everything is injectable (``clock``, ``sleep``, the arrival seed), so the
deterministic replication suite drives the identical code path on a fake
clock with zero real waiting; ``benchmarks/run.py`` runs it on wall time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .scheduler import FrontendOverloadError

__all__ = ["poisson_arrivals", "OpenLoopReport", "run_open_loop"]


def poisson_arrivals(offered_qps: float, duration_s: float,
                     seed: int = 0) -> np.ndarray:
    """Arrival times (seconds, ascending, < ``duration_s``) of a Poisson
    process at rate ``offered_qps`` — i.i.d. exponential inter-arrivals
    from a fixed-seed generator, so a sweep re-runs the same schedule.
    """
    if offered_qps <= 0:
        raise ValueError(f"offered_qps must be > 0, got {offered_qps}")
    rng = np.random.default_rng(seed)
    # draw in one vectorised batch with safety margin, extend if unlucky
    n_expect = max(16, int(offered_qps * duration_s * 1.5) + 16)
    gaps = rng.exponential(1.0 / offered_qps, n_expect)
    t = np.cumsum(gaps)
    while t.size and t[-1] < duration_s:  # pragma: no cover - rare tail
        more = rng.exponential(1.0 / offered_qps, n_expect)
        t = np.concatenate([t, t[-1] + np.cumsum(more)])
    return t[t < duration_s]


@dataclasses.dataclass
class OpenLoopReport:
    """One open-loop run at one offered rate (all latencies in ms)."""

    offered_qps: float
    duration_s: float          # configured arrival window
    elapsed_s: float           # wall time until the last response resolved
    submitted: int             # arrivals accepted by admission
    rejected: int              # arrivals shed by reject-on-full
    completed: int             # responses resolved
    failures: int              # responses resolved with a dispatch error
    timeouts: int              # responses never resolved within the guard
    achieved_qps: float        # completed / elapsed
    p50_ms: float
    p95_ms: float
    p99_ms: float

    @property
    def reject_rate(self) -> float:
        total = self.submitted + self.rejected
        return self.rejected / total if total else 0.0

    def row(self) -> dict:
        """Flat dict for benchmark JSON snapshots."""
        out = dataclasses.asdict(self)
        out["reject_rate"] = round(self.reject_rate, 4)
        return out


def _percentiles_ms(latency_s: List[float]) -> Tuple[float, float, float]:
    if not latency_s:
        nan = float("nan")
        return nan, nan, nan
    lat = np.asarray(latency_s, np.float64) * 1e3
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 95)),
            float(np.percentile(lat, 99)))


def run_open_loop(
    servers,
    queries: np.ndarray,
    *,
    offered_qps: float,
    duration_s: float,
    n_neighbors: int = 10,
    seed: int = 0,
    clock=time.monotonic,
    sleep=time.sleep,
    max_sleep_s: float = 0.002,
    drain_timeout_s: float = 30.0,
    on_submit=None,
) -> OpenLoopReport:
    """Drive one server — or a replica fleet, round-robin — open-loop.

    Args:
      servers:      a ``ZenServer`` with an attached frontend, or a
                    sequence of them (each arrival goes to ``servers[i %
                    R]``). ``launch.replicate.QueryReplica`` fleets pass
                    ``[r.server for r in replicas]``.
      queries:      (M, m) pool of query vectors; arrival ``i`` submits row
                    ``i % M`` (one row per arrival, so offered QPS is in
                    queries/second).
      offered_qps:  Poisson arrival rate.
      duration_s:   arrival window; the loop then drains outstanding
                    handles (bounded by ``drain_timeout_s``).
      clock/sleep:  injectable time sources. The deterministic tests pass a
                    fake clock and ``sleep=clock.advance`` so the identical
                    loop runs with zero real waiting.
      max_sleep_s:  idle-wait quantum between events (wall-clock runs).
      on_submit:    optional hook ``(arrival_index, server_index)`` — the
                    simulation suite uses it to interleave churn/publish/
                    poll at exact points.

    Returns an :class:`OpenLoopReport`. Per-server capacity is the
    admission budget: each scheduler is ticked at most once per its
    ``tick_interval``, dispatching at most ``max_batch`` rows — so a fleet
    of R replicas has R× the admission budget of one, and the report
    measures how much of that budget turns into goodput at this offered
    rate.
    """
    fleet = list(servers) if isinstance(servers, (list, tuple)) else [servers]
    if not fleet:
        raise ValueError("need at least one server")
    for s in fleet:
        if s.frontend is None:
            raise ValueError(
                "open-loop driving needs the micro-batched frontend "
                "(ZenServer(frontend=True)): backpressure and admission "
                "budgets live there")
    q = np.asarray(queries, np.float32)
    arrivals = poisson_arrivals(offered_qps, duration_s, seed)
    t0 = clock()
    next_tick = [0.0] * len(fleet)
    pending: List[Tuple[object, float]] = []  # (handle, scheduled arrival)
    latency_s: List[float] = []
    submitted = rejected = completed = failures = 0
    i = 0
    while True:
        now = clock() - t0
        # 1) submit every arrival that is due
        while i < len(arrivals) and arrivals[i] <= now:
            target = i % len(fleet)
            if on_submit is not None:
                on_submit(i, target)
            try:
                handle = fleet[target].frontend.submit(
                    q[i % q.shape[0]], n_neighbors)
            except FrontendOverloadError:
                rejected += 1
            else:
                submitted += 1
                pending.append((handle, arrivals[i]))
            i += 1
        # 2) tick each scheduler at its own cadence (admission budget)
        for j, s in enumerate(fleet):
            if now >= next_tick[j]:
                s.frontend.tick()
                next_tick[j] = now + s.frontend.tick_interval
        # 3) reap resolved handles (latency from scheduled arrival)
        if pending:
            now = clock() - t0
            still = []
            for handle, t_arr in pending:
                if handle.done():
                    try:
                        handle.result(0)
                    except Exception:  # noqa: BLE001 - counted, not raised
                        failures += 1
                    else:
                        completed += 1
                        latency_s.append(now - t_arr)
                else:
                    still.append((handle, t_arr))
            pending = still
        # 4) done? (all arrivals submitted, nothing outstanding)
        if i >= len(arrivals) and not pending:
            break
        # drain guard: a dead ticker must not hang the harness forever
        if now > duration_s + drain_timeout_s:
            break
        # 5) idle until the next event
        targets = [next_tick[j] for j in range(len(fleet))]
        if i < len(arrivals):
            targets.append(float(arrivals[i]))
        dt = min(targets) - (clock() - t0)
        if dt > 0:
            sleep(min(dt, max_sleep_s))
    timeouts = len(pending)
    elapsed = max(clock() - t0, 1e-9)
    p50, p95, p99 = _percentiles_ms(latency_s)
    return OpenLoopReport(
        offered_qps=float(offered_qps), duration_s=float(duration_s),
        elapsed_s=float(elapsed), submitted=submitted, rejected=rejected,
        completed=completed, failures=failures, timeouts=timeouts,
        achieved_qps=completed / elapsed, p50_ms=p50, p95_ms=p95,
        p99_ms=p99)
