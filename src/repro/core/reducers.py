"""Uniform fit/transform protocol over every DR method the paper compares.

The four baseline transforms (``core.baselines``) and ``NSimplexTransform``
grew slightly different surfaces — RP wants a PRNG key at fit time, LMDS is
distance-only with differently named methods, Zen scores reduced points with
its own estimator instead of the Euclidean metric. Harness code (the
``retrieval_e2e`` workload, ``benchmarks/paper_quality.py``-style quality
curves, ``build_index``-shaped serving glue) should not special-case each
method, so this module wraps them behind one protocol:

    r = make_reducer("pca", k=8)            # or zen / rp / mds / lmds
    r = r.fit(witness, key=key)             # same signature for every method
    Xr = r.transform(X)                     # (N, k) reduced coordinates
    D  = r.pdist(Xr, Yr)                    # reduced-space distance matrix

``pdist`` is the method's *own* reduced-space comparator: the Zen estimator
for nSimplex (paper §4), plain Euclidean for the coordinate baselines — so
recall/stress curves compare each method the way its paper runs it.

Metric support differs by construction, not by accident: ``zen`` and
``lmds`` fit from distances alone and accept any registry metric (the
coordinate-free Hilbert case, e.g. ``metric="jsd"``); ``pca``/``rp``/``mds``
are Euclidean-coordinate methods and raise on anything else — which is
exactly the paper's §5.6 differentiating claim.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import metrics as metrics_lib
from .baselines import LMDSTransform, MDSTransform, PCATransform, RandomProjection
from .projection import NSimplexTransform
from .pivots import select_references
from .zen import zen_pdist

Array = jax.Array

#: every reducer name ``make_reducer`` accepts, in paper order
REDUCER_NAMES: Tuple[str, ...] = ("zen", "pca", "rp", "mds", "lmds")

#: reducers that fit from pairwise distances alone (coordinate-free spaces)
DISTANCE_ONLY: Tuple[str, ...] = ("zen", "lmds")


def _require_euclidean(name: str, metric: str) -> None:
    if metric != "euclidean":
        raise ValueError(
            f"{name} is a Euclidean-coordinate method and cannot fit a "
            f"{metric!r} space; distance-only methods ({'/'.join(DISTANCE_ONLY)}) "
            "handle coordinate-free metrics"
        )


@dataclasses.dataclass
class ZenReducer:
    """nSimplex Zen behind the uniform protocol (references from witness)."""

    k: int
    metric: str = "euclidean"
    transform_: Optional[NSimplexTransform] = None
    name: str = "zen"

    def fit(self, witness: Array, *, key: Optional[jax.Array] = None
            ) -> "ZenReducer":
        key = key if key is not None else jax.random.PRNGKey(0)
        tr = select_references(witness, self.k, key, metric=self.metric)
        return dataclasses.replace(self, transform_=tr)

    def transform(self, X: Array) -> Array:
        return self.transform_.transform(X)

    def pdist(self, Xr: Array, Yr: Array) -> Array:
        return zen_pdist(Xr, Yr)


@dataclasses.dataclass
class PCAReducer:
    k: int
    metric: str = "euclidean"
    transform_: Optional[PCATransform] = None
    name: str = "pca"

    def fit(self, witness: Array, *, key: Optional[jax.Array] = None
            ) -> "PCAReducer":
        _require_euclidean(self.name, self.metric)
        return dataclasses.replace(
            self, transform_=PCATransform(k=self.k).fit(witness))

    def transform(self, X: Array) -> Array:
        return self.transform_.transform(X)

    def pdist(self, Xr: Array, Yr: Array) -> Array:
        return metrics_lib.euclidean_pdist(Xr, Yr)


@dataclasses.dataclass
class RPReducer:
    k: int
    metric: str = "euclidean"
    transform_: Optional[RandomProjection] = None
    name: str = "rp"

    def fit(self, witness: Array, *, key: Optional[jax.Array] = None
            ) -> "RPReducer":
        _require_euclidean(self.name, self.metric)
        key = key if key is not None else jax.random.PRNGKey(0)
        return dataclasses.replace(
            self, transform_=RandomProjection(k=self.k).fit(witness, key=key))

    def transform(self, X: Array) -> Array:
        return self.transform_.transform(X)

    def pdist(self, Xr: Array, Yr: Array) -> Array:
        return metrics_lib.euclidean_pdist(Xr, Yr)


@dataclasses.dataclass
class MDSReducer:
    k: int
    metric: str = "euclidean"
    transform_: Optional[MDSTransform] = None
    name: str = "mds"

    def fit(self, witness: Array, *, key: Optional[jax.Array] = None
            ) -> "MDSReducer":
        _require_euclidean(self.name, self.metric)
        return dataclasses.replace(
            self, transform_=MDSTransform(k=self.k).fit(witness))

    def transform(self, X: Array) -> Array:
        return self.transform_.transform(X)

    def pdist(self, Xr: Array, Yr: Array) -> Array:
        return metrics_lib.euclidean_pdist(Xr, Yr)


@dataclasses.dataclass
class LMDSReducer:
    """Landmark MDS behind the protocol: coordinates in, coordinates out.

    ``fit`` draws ``n_landmarks`` witness rows (default ``max(2k, k+2)``,
    de Silva & Tenenbaum's over-determination guidance), computes their
    pairwise distances under ``metric`` and triangulates out-of-sample
    points from their distances to the landmarks — so the same object also
    serves coordinate-free metrics (``metric="jsd"``) where PCA/RP/MDS
    structurally cannot fit.
    """

    k: int
    metric: str = "euclidean"
    n_landmarks: Optional[int] = None
    transform_: Optional[LMDSTransform] = None
    landmarks_: Optional[Array] = None
    name: str = "lmds"

    def fit(self, witness: Array, *, key: Optional[jax.Array] = None
            ) -> "LMDSReducer":
        witness = jnp.asarray(witness)
        l = self.n_landmarks or max(2 * self.k, self.k + 2)
        l = min(l, witness.shape[0])
        if key is not None:
            pick = jax.random.choice(
                key, witness.shape[0], (l,), replace=False)
            landmarks = witness[pick]
        else:
            landmarks = witness[:l]
        D = metrics_lib.pairwise(self.metric, landmarks, landmarks)
        D = jnp.where(jnp.eye(l, dtype=bool), 0.0, D)
        tr = LMDSTransform(k=self.k).fit_from_distances(D)
        return dataclasses.replace(self, transform_=tr, landmarks_=landmarks)

    def transform(self, X: Array) -> Array:
        dists = metrics_lib.pairwise(self.metric, jnp.asarray(X),
                                     self.landmarks_)
        return self.transform_.transform_from_distances(dists)

    def pdist(self, Xr: Array, Yr: Array) -> Array:
        return metrics_lib.euclidean_pdist(Xr, Yr)


_REDUCERS = {
    "zen": ZenReducer,
    "pca": PCAReducer,
    "rp": RPReducer,
    "mds": MDSReducer,
    "lmds": LMDSReducer,
}


def make_reducer(name: str, k: int, *, metric: str = "euclidean", **kw):
    """One protocol object for ``name`` in ``REDUCER_NAMES`` (unfitted)."""
    try:
        cls = _REDUCERS[name]
    except KeyError:
        raise ValueError(
            f"unknown reducer {name!r}; choose from {REDUCER_NAMES}"
        ) from None
    return cls(k=k, metric=metric, **kw)
