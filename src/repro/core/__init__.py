"""Core nSimplex Zen library: the paper's contribution as composable JAX modules."""
from .metrics import (
    cosine_pdist,
    euclidean_pdist,
    get_metric,
    jsd_pdist,
    l1_normalize,
    l2_normalize,
    pairwise,
    qform_pdist,
    self_pairwise,
    sqeuclidean_pdist,
    triangular_pdist,
)
from .projection import NSimplexTransform, fit_transform, select_references
from .simplex import (
    BaseSimplex,
    apex_project,
    build_base_simplex,
    gram_from_distances,
    simplex_is_degenerate,
)
from .zen import estimate_pdist, estimate_triple, knn_search, lwb_pdist, upb_pdist, zen_pdist
from .baselines import LMDSTransform, MDSTransform, PCATransform, RandomProjection
from .reducers import DISTANCE_ONLY, REDUCER_NAMES, make_reducer
from . import pivots
from . import quality

__all__ = [
    "NSimplexTransform",
    "BaseSimplex",
    "apex_project",
    "build_base_simplex",
    "gram_from_distances",
    "simplex_is_degenerate",
    "select_references",
    "fit_transform",
    "estimate_pdist",
    "estimate_triple",
    "knn_search",
    "zen_pdist",
    "lwb_pdist",
    "upb_pdist",
    "PCATransform",
    "RandomProjection",
    "MDSTransform",
    "LMDSTransform",
    "make_reducer",
    "REDUCER_NAMES",
    "DISTANCE_ONLY",
    "pivots",
    "quality",
    "get_metric",
    "pairwise",
    "self_pairwise",
    "euclidean_pdist",
    "sqeuclidean_pdist",
    "cosine_pdist",
    "jsd_pdist",
    "triangular_pdist",
    "qform_pdist",
    "l1_normalize",
    "l2_normalize",
]
