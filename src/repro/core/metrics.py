"""Distance metrics over Hilbert-embeddable spaces (paper Appendix A).

Every metric is exposed in two forms:
  * ``<name>_pdist(X, Y) -> (N, M)`` pairwise distance matrix, jit/vmap friendly,
  * via the registry ``get_metric(name)`` returning a ``Metric`` record with the
    pairwise function, pre-normalisation and Hilbert-embeddability flag.

All pairwise computations accumulate in float32 (or float64 if enabled) even for
bf16 inputs; matmul-shaped paths use ``preferred_element_type``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-12


def _acc_dtype(x: Array) -> jnp.dtype:
    return jnp.promote_types(x.dtype, jnp.float32)


def sqeuclidean_pdist(X: Array, Y: Array) -> Array:
    """Pairwise squared Euclidean distances, matmul-shaped for the MXU."""
    acc = _acc_dtype(X)
    x2 = jnp.sum(X.astype(acc) ** 2, axis=-1)
    y2 = jnp.sum(Y.astype(acc) ** 2, axis=-1)
    xy = jnp.matmul(X, Y.T, preferred_element_type=acc)
    d2 = x2[:, None] + y2[None, :] - 2.0 * xy
    if Y is X:
        # self-distances are definitionally zero; the matmul form leaves
        # O(eps*||x||^2) roundoff there, which sqrt inflates to O(sqrt(eps))
        d2 = d2 * (1.0 - jnp.eye(d2.shape[0], dtype=d2.dtype))
    return jnp.maximum(d2, 0.0)


def euclidean_pdist(X: Array, Y: Array) -> Array:
    return jnp.sqrt(sqeuclidean_pdist(X, Y))


def l2_normalize(X: Array, eps: float = _EPS) -> Array:
    n = jnp.linalg.norm(X, axis=-1, keepdims=True)
    return X / jnp.maximum(n, eps)


def l1_normalize(X: Array, eps: float = _EPS) -> Array:
    """Project onto the probability simplex (for JSD / triangular)."""
    Xp = jnp.maximum(X, 0.0)
    s = jnp.sum(Xp, axis=-1, keepdims=True)
    return Xp / jnp.maximum(s, eps)


def cosine_pdist(X: Array, Y: Array) -> Array:
    """Paper Eq. (11): Euclidean distance over L2-normalised vectors."""
    Xn = l2_normalize(X)
    Yn = Xn if Y is X else l2_normalize(Y)
    return euclidean_pdist(Xn, Yn)


def _h(x: Array) -> Array:
    """h(x) = -x log2(x), with 0 log 0 := 0 (paper Eq. 14)."""
    safe = jnp.where(x > 0, x, 1.0)
    return jnp.where(x > 0, -x * jnp.log2(safe), 0.0)


def jsd_pdist(X: Array, Y: Array, *, assume_normalized: bool = False) -> Array:
    """Jensen-Shannon distance (paper Eqs. 12-14). Inputs are l1-normalised
    probability vectors; set ``assume_normalized=False`` to normalise here.

    K(v, w) = 1 - 0.5 * sum_i [h(v_i) + h(w_i) - h(v_i + w_i)];  D = sqrt(K).
    The cross term sum_i h(v_i + w_i) is the O(N*M*m) hot loop (see kernels/jsd).
    """
    if not assume_normalized:
        X, Y = l1_normalize(X), l1_normalize(Y)
    acc = _acc_dtype(X)
    X = X.astype(acc)
    Y = Y.astype(acc)
    hx = jnp.sum(_h(X), axis=-1)  # (N,)
    hy = jnp.sum(_h(Y), axis=-1)  # (M,)
    # cross[i, j] = sum_k h(x_ik + y_jk); O(N*M*m) elementwise.
    cross = jnp.sum(_h(X[:, None, :] + Y[None, :, :]), axis=-1)
    K = 1.0 - 0.5 * (hx[:, None] + hy[None, :] - cross)
    return jnp.sqrt(jnp.maximum(K, 0.0))


def triangular_pdist(X: Array, Y: Array, *, assume_normalized: bool = False) -> Array:
    """Triangular distance (paper Eq. 15), cheap JSD estimator; 0/0 := 0."""
    if not assume_normalized:
        X, Y = l1_normalize(X), l1_normalize(Y)
    acc = _acc_dtype(X)
    num = (X[:, None, :].astype(acc) - Y[None, :, :].astype(acc)) ** 2
    den = X[:, None, :].astype(acc) + Y[None, :, :].astype(acc)
    frac = jnp.where(den > 0, num / jnp.maximum(den, _EPS), 0.0)
    return jnp.sqrt(0.5 * jnp.sum(frac, axis=-1))


def qform_pdist(X: Array, Y: Array, M: Array) -> Array:
    """Quadratic-form distance (paper Eq. 16) with PSD matrix ``M``.

    D(v,w)^2 = v'Mv + w'Mw - 2 v'Mw : three matmuls, no N*M*m intermediate.
    """
    acc = _acc_dtype(X)
    XM = jnp.matmul(X, M, preferred_element_type=acc)
    YM = XM if Y is X else jnp.matmul(Y, M, preferred_element_type=acc)
    xmx = jnp.sum(XM * X, axis=-1)
    ymy = xmx if Y is X else jnp.sum(YM * Y, axis=-1)
    xmy = jnp.matmul(XM, Y.T, preferred_element_type=acc)
    d2 = xmx[:, None] + ymy[None, :] - 2.0 * xmy
    if Y is X:  # exact-zero self distances (cf. sqeuclidean_pdist)
        d2 = d2 * (1.0 - jnp.eye(d2.shape[0], dtype=d2.dtype))
    return jnp.sqrt(jnp.maximum(d2, 0.0))


@dataclasses.dataclass(frozen=True)
class _DefaultQformMatrix:
    """Deterministic PSD matrix for the registry ``qform`` metric.

    The registry needs a parameter-free pairwise function, so the form
    matrix is fixed per input dimension: the Kac-Murdock-Szego correlation
    matrix ``M[i, j] = rho^|i - j|`` — strictly positive definite for
    ``|rho| < 1``, so the distance is a true Hilbert-embeddable metric
    (it is the Euclidean distance of the ``chol(M)``-transformed vectors).
    Neighbouring axes correlate, which is the textbook quadratic-form use
    case (e.g. colour-histogram bins). Callers with a domain matrix should
    use :func:`qform_pdist` directly.
    """

    rho: float = 0.5

    def __call__(self, m: int) -> Array:
        idx = jnp.arange(m)
        return self.rho ** jnp.abs(idx[:, None] - idx[None, :])


default_qform_matrix = _DefaultQformMatrix()


@dataclasses.dataclass(frozen=True)
class Metric:
    name: str
    pdist: Callable[[Array, Array], Array]
    normalize: Optional[Callable[[Array], Array]]
    hilbert_embeddable: bool
    has_coordinates: bool  # False => only distance-based DR (nSimplex / LMDS) applies


def _make_registry() -> dict:
    return {
        "euclidean": Metric("euclidean", euclidean_pdist, None, True, True),
        "sqeuclidean": Metric("sqeuclidean", sqeuclidean_pdist, None, False, True),
        "cosine": Metric(
            "cosine",
            lambda X, Y: euclidean_pdist(X, Y),  # callers pre-normalise
            l2_normalize,
            True,
            True,
        ),
        "jsd": Metric(
            "jsd",
            lambda X, Y: jsd_pdist(X, Y, assume_normalized=True),
            l1_normalize,
            True,
            False,
        ),
        "triangular": Metric(
            "triangular",
            lambda X, Y: triangular_pdist(X, Y, assume_normalized=True),
            l1_normalize,
            True,
            False,
        ),
        "qform": Metric(
            "qform",
            lambda X, Y: qform_pdist(
                X, Y, default_qform_matrix(X.shape[-1])),
            None,
            True,
            True,
        ),
    }


_REGISTRY = _make_registry()


def get_metric(name: str) -> Metric:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown metric {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def pairwise(name: str, X: Array, Y: Array) -> Array:
    """Normalise (if the metric requires it) and compute the pairwise matrix."""
    m = get_metric(name)
    if m.normalize is not None:
        Xn = m.normalize(X)
        Y = Xn if Y is X else m.normalize(Y)  # keep the self-pdist identity
        X = Xn
    return m.pdist(X, Y)


def self_pairwise(name: str, X: Array) -> Array:
    return pairwise(name, X, X)
