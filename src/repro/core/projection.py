"""NSimplexTransform — the paper's DR technique as a composable library object.

Usage (coordinate spaces):

    tr = NSimplexTransform(metric="euclidean", k=32)
    tr = tr.fit(refs)              # refs: (k, m) reference objects
    Xp = tr.transform(X)           # (N, k) apex coordinates
    D  = zen.estimate_pdist(Xp, Xp, "zen")

Usage (coordinate-free Hilbert spaces, e.g. Jensen-Shannon — paper §5.6):

    tr = NSimplexTransform.from_distances(D_refs)      # (k, k) ref distances
    Xp = tr.transform_from_distances(D_x_refs)         # (N, k) dists to refs

The fitted state is a pytree (works under jit / pjit / checkpointing); the
reference set is tiny (k <= a few hundred), so it is replicated across the mesh
while the data batch dimension is sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import metrics as metrics_lib
from . import simplex as simplex_lib

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NSimplexTransform:
    """nSimplex projection sigma_R : (U, d) -> R^k (paper §4).

    Attributes:
      k:      number of reference objects == output dimensionality.
      metric: name from ``core.metrics`` ("euclidean", "cosine", "jsd",
              "triangular", ...), or "precomputed" in distance-only mode.
      jitter: relative Gram-diagonal regulariser for nearly degenerate
              reference sets (0.0 = exact).
      refs:   (k, m) fitted reference objects, or ``None`` in distance-only
              mode.
      base:   the fitted ``BaseSimplex`` (Cholesky factor + cached norms).

    A fitted transform projects *unseen* objects indefinitely — only the k
    reference distances are needed per object — which is what the mutable
    serving index (``launch.serve.ZenServer.upsert``) relies on.

    >>> import jax, jax.numpy as jnp
    >>> X = jax.random.normal(jax.random.PRNGKey(0), (40, 8), jnp.float32)
    >>> tr = NSimplexTransform(k=4, metric="euclidean").fit(X[:4])
    >>> tuple(tr.transform(X).shape)   # (N, k) apex coordinates
    (40, 4)
    >>> bool(tr.is_fitted)
    True
    """

    k: int
    metric: str = "euclidean"
    jitter: float = 0.0
    # fitted state
    refs: Optional[Array] = None          # (k, m) or None in distance-only mode
    base: Optional[simplex_lib.BaseSimplex] = None

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.refs, self.base), (self.k, self.metric, self.jitter)

    @classmethod
    def tree_unflatten(cls, aux, children):
        k, metric, jitter = aux
        refs, base = children
        return cls(k=k, metric=metric, jitter=jitter, refs=refs, base=base)

    # -- fitting -------------------------------------------------------------
    def fit(self, refs: Array) -> "NSimplexTransform":
        """Fit from (k, m) reference objects in a coordinate space.

        Args:
          refs: (k, m) reference objects; normalised per the metric's rule
                (e.g. L2 for cosine) before the pairwise distance matrix is
                taken.

        Returns a new fitted transform (``self`` is unchanged).
        Raises ValueError when ``refs`` does not hold exactly ``k`` rows.
        """
        refs = jnp.asarray(refs)
        if refs.shape[0] != self.k:
            raise ValueError(f"expected {self.k} references, got {refs.shape[0]}")
        m = metrics_lib.get_metric(self.metric)
        if m.normalize is not None:
            refs = m.normalize(refs)
        D = m.pdist(refs, refs)
        # exact zero diagonal (numeric noise breaks the Gram construction)
        D = D * (1.0 - jnp.eye(self.k, dtype=D.dtype))
        base = simplex_lib.build_base_simplex(D, jitter=self.jitter)
        return dataclasses.replace(self, refs=refs, base=base)

    @classmethod
    def from_distances(
        cls, D_refs: Array, *, metric: str = "precomputed", jitter: float = 0.0
    ) -> "NSimplexTransform":
        """Fit from a (k, k) reference distance matrix (coordinate-free spaces)."""
        D_refs = jnp.asarray(D_refs)
        k = D_refs.shape[0]
        base = simplex_lib.build_base_simplex(D_refs, jitter=jitter)
        return cls(k=k, metric=metric, refs=None, base=base)

    @property
    def is_fitted(self) -> bool:
        return self.base is not None

    def degenerate(self) -> Array:
        self._check_fitted()
        return simplex_lib.simplex_is_degenerate(self.base)

    # -- transform -----------------------------------------------------------
    def reference_distances(self, X: Array) -> Array:
        """(N, k) distances from each row of X to every reference object."""
        self._check_fitted()
        if self.refs is None:
            raise ValueError(
                "transform(X) needs coordinate references; use "
                "transform_from_distances for distance-only transforms"
            )
        m = metrics_lib.get_metric(self.metric)
        if m.normalize is not None:
            X = m.normalize(X)
        return m.pdist(X, self.refs)

    def transform(self, X: Array) -> Array:
        """Project (N, m) objects to (N, k) apex coordinates.

        The last output column is the altitude (>= 0); the Zen/Lwb/Upb
        estimators (``core.zen``) treat it specially.
        """
        return simplex_lib.apex_project(self.base, self.reference_distances(X))

    def transform_from_distances(self, dists: Array) -> Array:
        """Project from precomputed (N, k) object-to-reference distances.

        The coordinate-free path (paper §5.6): ``dists[i, j]`` is the
        original-space distance from object i to reference j. Returns (N, k)
        apex coordinates, same contract as :meth:`transform`.
        """
        self._check_fitted()
        return simplex_lib.apex_project(self.base, dists)

    def __call__(self, X: Array) -> Array:
        return self.transform(X)

    def _check_fitted(self):
        if self.base is None:
            raise ValueError("NSimplexTransform is not fitted")


def select_references(
    X: Array,
    k: int,
    key: jax.Array,
    *,
    metric: str = "euclidean",
    max_tries: int = 8,
    jitter: float = 0.0,
) -> NSimplexTransform:
    """Randomly select k references from a witness set and fit, re-drawing on a
    degenerate simplex (paper §7.2: 'easy to check during simplex construction
    at which point a different choice of reference object can be made')."""
    last = None
    for _ in range(max_tries):
        key, sub = jax.random.split(key)
        idx = jax.random.choice(sub, X.shape[0], (k,), replace=False)
        tr = NSimplexTransform(k=k, metric=metric, jitter=jitter).fit(X[idx])
        last = tr
        if not bool(tr.degenerate()):
            return tr
    return last  # caller may still inspect .degenerate()


def fit_transform(
    X: Array,
    k: int,
    key: jax.Array,
    *,
    metric: str = "euclidean",
    pivots: str = "random",
) -> tuple[NSimplexTransform, Array]:
    """Select k references under a pivot strategy, fit, and project X.

    ``pivots`` is one of ``core.pivots.PIVOT_STRATEGIES``; the default
    "random" reproduces the historical behaviour exactly (same key stream).
    """
    if pivots == "random":
        tr = select_references(X, k, key, metric=metric)
    else:
        # deferred: core.pivots imports this module (strategy fallback)
        from . import pivots as pivots_lib
        tr = pivots_lib.select_references(
            X, k, key, metric=metric, strategy=pivots)
    return tr, tr.transform(X)
