"""Zen / Lwb / Upb estimators over nSimplex-projected coordinates (paper §4.1).

For projected points x, y in R^k (last coordinate = altitude):

  base_dist(x,y) = sum_{i<k} (x_i - y_i)^2
  Lwb(x,y) = sqrt(base_dist + (x_k - y_k)^2)      # = l2, lower bound of d
  Upb(x,y) = sqrt(base_dist + (x_k + y_k)^2)      # upper bound of d
  Zen(x,y) = sqrt(base_dist + x_k^2 + y_k^2)      # zenith estimator

All three share one matmul:  with full squared norms nx = ||x||^2 (altitude
included) and the dot product restricted to the first k-1 coordinates
p = x[:k-1] . y[:k-1]:

  Zen^2 = nx + ny - 2 p
  Lwb^2 = Zen^2 - 2 x_k y_k
  Upb^2 = Zen^2 + 2 x_k y_k

so the pairwise estimator matrix is one (masked-last-column) matmul plus a
rank-1 correction — the shape the Pallas ``zen`` kernel implements on TPU.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops

Array = jax.Array

MODES = ("zen", "lwb", "upb")


def _acc(x: Array) -> jnp.dtype:
    return jnp.promote_types(x.dtype, jnp.float32)


def estimate_pdist(X: Array, Y: Array, mode: str = "zen") -> Array:
    """Pairwise estimator matrix (N, M) between projected sets X (N,k), Y (M,k)."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    acc = _acc(X)
    Xa, Ya = X.astype(acc), Y.astype(acc)
    nx = jnp.sum(Xa * Xa, axis=-1)
    ny = jnp.sum(Ya * Ya, axis=-1)
    p = jnp.matmul(Xa[:, :-1], Ya[:, :-1].T, preferred_element_type=acc)
    z2 = nx[:, None] + ny[None, :] - 2.0 * p
    if mode != "zen":
        cross = jnp.outer(Xa[:, -1], Ya[:, -1])
        z2 = z2 - 2.0 * cross if mode == "lwb" else z2 + 2.0 * cross
    return jnp.sqrt(jnp.maximum(z2, 0.0))


def zen_pdist(X: Array, Y: Array) -> Array:
    return estimate_pdist(X, Y, "zen")


def lwb_pdist(X: Array, Y: Array) -> Array:
    return estimate_pdist(X, Y, "lwb")


def upb_pdist(X: Array, Y: Array) -> Array:
    return estimate_pdist(X, Y, "upb")


def estimate_triple(X: Array, Y: Array) -> Tuple[Array, Array, Array]:
    """(lwb, zen, upb) evaluated as a triple sharing one matmul (paper §4.1)."""
    acc = _acc(X)
    Xa, Ya = X.astype(acc), Y.astype(acc)
    nx = jnp.sum(Xa * Xa, axis=-1)
    ny = jnp.sum(Ya * Ya, axis=-1)
    p = jnp.matmul(Xa[:, :-1], Ya[:, :-1].T, preferred_element_type=acc)
    z2 = nx[:, None] + ny[None, :] - 2.0 * p
    cross = 2.0 * jnp.outer(Xa[:, -1], Ya[:, -1])
    sq = lambda a: jnp.sqrt(jnp.maximum(a, 0.0))
    return sq(z2 - cross), sq(z2), sq(z2 + cross)


@partial(jax.jit, static_argnames=("n_neighbors", "mode"))
def _dense_topk(
    queries: Array, index: Array, n_neighbors: int, mode: str
) -> Tuple[Array, Array]:
    """Reference dense path: full (Q, N) estimator matrix + lax.top_k."""
    d = estimate_pdist(queries, index, mode)
    neg, ids = jax.lax.top_k(-d, n_neighbors)
    return -neg, ids


def knn_search(
    queries: Array,
    index: Array,
    n_neighbors: int = 10,
    mode: str = "zen",
    chunk: int = 0,
    *,
    scales: Array = None,
    stream: bool = None,
    force_kernel: bool = False,
) -> Tuple[Array, Array]:
    """Top-k nearest neighbours of ``queries`` in ``index`` under an estimator.

    Args:
      queries: (Q, k) projected queries.
      index:   (N, k) projected index, stored f32, bf16 or int8
               (``kernels.quantize``).
      chunk:   if > 0, stream the index in blocks of this many rows (bounded
               memory: keeps a running top-k instead of the full (Q, N) matrix).
      scales:  (N, 1) f32 per-row symmetric scales when ``index`` is int8;
               the streaming paths fuse the dequant into the estimator, the
               dense path reconstructs the f32 index once.
      stream:  force the streaming path on (True) or off (False); by default
               it is chosen automatically — always on TPU (fused Pallas
               kernel), and on other backends whenever ``chunk`` is set and
               the index is larger than one chunk.
      force_kernel: run the Pallas kernel in interpret mode off-TPU
               (tests / parity checks).

    Returns:
      (distances, indices), each (Q, n_neighbors), ascending distance.

    The streaming path dispatches through ``kernels.ops.zen_topk``: the fused
    Pallas kernel on TPU, a lax.scan with identical merge semantics elsewhere.
    Peak per-query memory is one index tile — flat in N — versus the dense
    path's O(N).
    """
    n_neighbors = min(n_neighbors, index.shape[0])
    use_stream = stream
    if use_stream is None:  # auto: always stream on TPU, else when chunked
        use_stream = (
            bool(chunk) and index.shape[0] > chunk
        ) or jax.default_backend() == "tpu"
    if use_stream or force_kernel:
        return kernel_ops.zen_topk(
            queries,
            index,
            n_neighbors,
            mode,
            scales=scales,
            force_kernel=force_kernel,
            chunk=chunk or 4096,
        )
    if scales is not None:  # dense reference path: dequantise once
        index = index.astype(jnp.float32) * scales.astype(jnp.float32)
    return _dense_topk(queries, index, n_neighbors, mode)
