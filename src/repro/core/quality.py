"""Quality measures for DR transforms (paper §5.1 and Appendix E).

All measures take flat arrays of original distances ``delta`` and reduced
distances ``zeta`` over the same sampled object pairs (i < j), except the
kNN-recall DCG which takes ranked id lists.

``kruskal_stress`` uses an exact pool-adjacent-violators (PAVA) isotonic
regression; PAVA is inherently sequential so it runs host-side in numpy —
it is an evaluation-only path, never inside a training step.
"""
from __future__ import annotations

import math
from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp

Array = jax.Array


def _pava(y: np.ndarray, w: np.ndarray | None = None) -> np.ndarray:
    """Least-squares isotonic (non-decreasing) fit; O(n) pool-adjacent-violators."""
    y = np.asarray(y, np.float64)
    n = y.shape[0]
    w = np.ones(n) if w is None else np.asarray(w, np.float64)
    means = y.copy()
    weights = w.copy()
    # blocks as index ranges
    starts = np.arange(n)
    ends = np.arange(n)
    top = 0  # stack pointer
    for i in range(1, n):
        top += 1
        means[top] = y[i]
        weights[top] = w[i]
        starts[top] = i
        ends[top] = i
        while top > 0 and means[top - 1] > means[top]:
            tot = weights[top - 1] + weights[top]
            means[top - 1] = (
                weights[top - 1] * means[top - 1] + weights[top] * means[top]
            ) / tot
            weights[top - 1] = tot
            ends[top - 1] = ends[top]
            top -= 1
    out = np.empty(n)
    for b in range(top + 1):
        out[starts[b] : ends[b] + 1] = means[b]
    return out


def isotonic_fit(zeta: np.ndarray, delta: np.ndarray) -> np.ndarray:
    """Kruskal disparities d*: the least-squares monotone (isotonic) fit of the
    reduced distances ``zeta`` with respect to the ordering induced by the true
    distances ``delta`` (paper Eq. 4 / Eq. 30). Returned in input order.

    This is the standard Kruskal construction: if zeta is any purely monotone
    function of delta, the fit is exact and the stress is zero — the property
    the paper states explicitly in Appendix E.1.
    """
    zeta = np.asarray(zeta, np.float64)
    delta = np.asarray(delta, np.float64)
    order = np.argsort(delta, kind="stable")
    fit_sorted = _pava(zeta[order])
    out = np.empty_like(fit_sorted)
    out[order] = fit_sorted
    return out


def kruskal_stress(delta, zeta) -> float:
    """Kruskal stress-1 (paper Eq. 4 / Eq. 30)."""
    delta = np.asarray(delta, np.float64).ravel()
    zeta = np.asarray(zeta, np.float64).ravel()
    d_star = isotonic_fit(zeta, delta)
    denom = np.sum(zeta**2)
    if denom <= 0:
        return float("inf")
    return float(np.sqrt(np.sum((zeta - d_star) ** 2) / denom))


def sammon_stress(delta, zeta, eps: float = 1e-12) -> float:
    """Sammon stress (paper Eq. 31)."""
    delta = np.asarray(delta, np.float64).ravel()
    zeta = np.asarray(zeta, np.float64).ravel()
    safe = np.maximum(delta, eps)
    return float(np.sum((delta - zeta) ** 2 / safe) / np.maximum(np.sum(delta), eps))


def quadratic_loss(delta, zeta) -> float:
    """Quadratic loss (paper Eq. 32)."""
    delta = np.asarray(delta, np.float64).ravel()
    zeta = np.asarray(zeta, np.float64).ravel()
    return float(np.sum((delta - zeta) ** 2))


def _tie_averaged_ranks(a: np.ndarray) -> np.ndarray:
    """1-indexed ranks where tied values share the average of their ranks
    (the "average" method, matching ``scipy.stats.spearmanr``)."""
    order = np.argsort(a, kind="stable")
    ranks = np.empty(a.size, np.float64)
    ranks[order] = np.arange(1, a.size + 1, dtype=np.float64)
    _, inv, counts = np.unique(a, return_inverse=True, return_counts=True)
    sums = np.zeros(counts.size, np.float64)
    np.add.at(sums, inv, ranks)
    return sums[inv] / counts[inv]


def spearman_rho(delta, zeta) -> float:
    """Spearman rank correlation over sampled pairwise distances (Eq. 33).

    Ranks are tie-averaged: quantized (int8) and JSD near-equidistant
    corpora produce many exactly-tied distances, and dense integer ranks
    would order ties arbitrarily and bias rho. With ties present the
    ``1 - 6*sum(d^2)/(t^3 - t)`` shortcut is no longer exact, so rho is
    computed as the Pearson correlation of the averaged ranks — identical
    to the shortcut when all values are distinct. NaN for fewer than two
    pairs (the shortcut divides by zero) or a constant input.
    """
    delta = np.asarray(delta, np.float64).ravel()
    zeta = np.asarray(zeta, np.float64).ravel()
    t = delta.shape[0]
    if t < 2:
        return float("nan")
    dr = _tie_averaged_ranks(delta)
    zr = _tie_averaged_ranks(zeta)
    dr -= dr.mean()
    zr -= zr.mean()
    denom = math.sqrt(float(np.sum(dr * dr)) * float(np.sum(zr * zr)))
    if denom == 0.0:
        return float("nan")
    return float(np.sum(dr * zr) / denom)


# -- kNN recall as logistic-relevance DCG (paper Appendix E.3) ---------------


def rank_relevance(i: np.ndarray, n: int = 1000) -> np.ndarray:
    """Paper Eq. (34): inverse-sigmoid relevance of the i-th true neighbour
    (1-indexed ranks), midpoint n/2 and width n/10 so the significant
    region scales with the result-list length n. At realistic serving k
    (10–128) a fixed n=1000 sigmoid would rate every rank ~0.993 and any
    shuffle of the list would still score ~1.0.
    """
    i = np.asarray(i, np.float64)
    return 1.0 - 1.0 / (1.0 + np.exp(-(i - n / 2.0) / (n / 10.0)))


def dcg_recall(true_ids: np.ndarray, approx_ids: np.ndarray) -> float:
    """Paper Eq. (35), normalised to [0, 1] by the perfect-correlation DCG.

    Args:
      true_ids:   (n,) ids of the true nearest neighbours, best first.
      approx_ids: (n,) ids returned by the DR-space search, best first.
    """
    true_ids = np.asarray(true_ids).ravel()
    approx_ids = np.asarray(approx_ids).ravel()
    n = true_ids.shape[0]
    pos_in_true = {int(t): i + 1 for i, t in enumerate(true_ids)}  # 1-indexed
    i = np.arange(1, n + 1, dtype=np.float64)
    discount = np.log2(i + 1.0)
    # relevance of the object found at approx rank i = R(rank in true list);
    # a miss lands at rank 2n, deep past the sigmoid cliff (relevance ~0)
    ranks = np.array(
        [pos_in_true.get(int(a), 2 * n) for a in approx_ids], np.float64
    )
    rel = rank_relevance(ranks, n)
    dcg = np.sum((np.power(2.0, rel) - 1.0) / discount)
    ideal = np.sum((np.power(2.0, rank_relevance(i, n)) - 1.0) / discount)
    return float(dcg / ideal)


def batch_dcg_recall(true_ids: np.ndarray, approx_ids: np.ndarray) -> float:
    """Mean DCG recall over a batch of queries: (Q, n) id arrays."""
    return float(
        np.mean([dcg_recall(t, a) for t, a in zip(true_ids, approx_ids)])
    )


def recall_at_k(true_ids: np.ndarray, approx_ids: np.ndarray) -> float:
    """Plain (unweighted) set-overlap recall@k, meaned over a query batch.

    Args:
      true_ids:   (Q, k) — or (k,) — ids of the true nearest neighbours.
      approx_ids: (Q, k') ids returned by an approximate search; order is
                  ignored and negative ids (padding slots from clustered /
                  sharded searches) never count as hits.

    Returns |true ∩ approx| / k averaged over queries — the standard ANN
    benchmark recall, complementing the rank-weighted ``batch_dcg_recall``.
    """
    true_ids = np.atleast_2d(np.asarray(true_ids))
    approx_ids = np.atleast_2d(np.asarray(approx_ids))
    if true_ids.shape[0] != approx_ids.shape[0]:
        raise ValueError(
            f"query counts differ: {true_ids.shape} vs {approx_ids.shape}"
        )
    k = true_ids.shape[1]
    if k == 0:
        return 0.0
    hits = [
        len(set(t.tolist()) & set(a[a >= 0].tolist()))
        for t, a in zip(true_ids, approx_ids)
    ]
    return float(np.mean(hits) / k)


# -- normalised quality profiles (paper Appendix E.4) ------------------------


def quality_profile(delta, zeta, *, qmax: float | None = None) -> Dict[str, float]:
    """All pairwise-distance measures normalised into [0, 1] (1 = perfect)."""
    k = kruskal_stress(delta, zeta)
    s = sammon_stress(delta, zeta)
    q = quadratic_loss(delta, zeta)
    rho = spearman_rho(delta, zeta)
    out = {
        "kruskal": float(np.clip(1.0 - k, 0.0, 1.0)),
        "sammon": float(np.clip(1.0 - s, 0.0, 1.0)),
        "spearman": float(np.clip(rho, 0.0, 1.0)),
        "quadratic_raw": q,
    }
    if qmax is not None and qmax > 0:
        out["quadratic"] = float(np.clip((qmax - q) / qmax, 0.0, 1.0))
    return out


def pairwise_sample(
    X: Array, n_objects: int, key: jax.Array
) -> tuple[Array, Array]:
    """Sample ``n_objects`` rows and return (subset, upper-triangular index pairs)."""
    idx = jax.random.choice(key, X.shape[0], (min(n_objects, X.shape[0]),), replace=False)
    sub = X[idx]
    n = sub.shape[0]
    iu = jnp.triu_indices(n, k=1)
    return sub, iu


def flatten_upper(D: Array) -> Array:
    n = D.shape[0]
    iu = jnp.triu_indices(n, k=1)
    return D[iu]
