"""nSimplex base-simplex construction and apex projection.

Two implementations live here:

1. **TPU-native (the framework path)** — the paper's inductive algorithms
   re-expressed as dense linear algebra (DESIGN.md §2):

   * base simplex  = Cholesky factor of the reference Gram matrix,
   * apex addition = batched lower-triangular solve + altitude.

   Both are jit-friendly, batched, and MXU-shaped.

2. **Paper-faithful oracle** (``nsimplex_build_reference`` /
   ``apex_addition_reference``) — Algorithms 1 and 2 of the paper, verbatim
   sequential numpy. Used as the correctness oracle in tests and as the
   paper-faithful baseline in benchmarks.

Conventions match the paper: the base simplex of ``k`` references lives in
R^(k-1) as a lower-triangular matrix ``Sigma`` of shape (k, k-1) whose first row
is the origin; an apex has ``k`` coordinates, the last one being its altitude
(non-negative) above the base hyperplane.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np

import jax
import jax.numpy as jnp

Array = jax.Array


class BaseSimplex(NamedTuple):
    """Base simplex over k reference objects.

    Attributes:
      chol:   (k-1, k-1) lower-triangular Cholesky factor L; row i are the
              coordinates of vertex i+1 (vertex 0 is the origin).
      diag_g: (k-1,) squared norms of vertices 1..k-1  (= diag of the Gram
              matrix), cached for the apex solve.
      d0:     (k,) distances from reference 0 to every reference (d0[0] = 0).
    """

    chol: Array
    diag_g: Array
    d0: Array

    @property
    def k(self) -> int:
        return self.chol.shape[0] + 1

    def vertices(self) -> Array:
        """(k, k-1) vertex coordinate matrix (paper's lower-triangular Sigma)."""
        return jnp.concatenate(
            [jnp.zeros((1, self.chol.shape[0]), self.chol.dtype), self.chol], axis=0
        )


def gram_from_distances(D: Array) -> Array:
    """Gram matrix of vertices 1..k-1 with vertex 0 at the origin.

    G_ij = <v_i, v_j> = (d(r0,ri)^2 + d(r0,rj)^2 - d(ri,rj)^2) / 2.
    """
    d0 = D[0, 1:]
    D2 = D[1:, 1:] ** 2
    return 0.5 * (d0[:, None] ** 2 + d0[None, :] ** 2 - D2)


def build_base_simplex(D: Array, *, jitter: float = 0.0) -> BaseSimplex:
    """Construct the base simplex from the (k, k) reference distance matrix.

    The Cholesky factor of the Gram matrix *is* the paper's inductively built
    vertex matrix (rows 1..k-1); equality is asserted against the faithful
    oracle in tests. ``jitter`` (relative to mean diagonal) regularises nearly
    degenerate reference sets.
    """
    D = jnp.asarray(D)
    acc = jnp.promote_types(D.dtype, jnp.float32)
    D = D.astype(acc)
    G = gram_from_distances(D)
    if jitter:
        G = G + jitter * jnp.mean(jnp.diag(G)) * jnp.eye(G.shape[0], dtype=acc)
    L = jnp.linalg.cholesky(G)
    return BaseSimplex(chol=L, diag_g=jnp.diag(G), d0=D[0, :])


def simplex_is_degenerate(base: BaseSimplex, *, rtol: float = 1e-5) -> Array:
    """True if the reference set spans fewer than k-1 dimensions (paper §7.2).

    Detected from the Cholesky diagonal: a (near-)zero altitude at row i means
    reference i lies (almost) in the span of references 0..i-1.
    """
    d = jnp.diag(base.chol)
    scale = jnp.sqrt(jnp.maximum(jnp.max(base.diag_g), 1e-30))
    return jnp.logical_or(jnp.any(~jnp.isfinite(d)), jnp.any(d < rtol * scale))


def apex_project(base: BaseSimplex, dists: Array) -> Array:
    """Project a batch of objects into R^k from their reference distances.

    Args:
      base:  the fitted base simplex over k references.
      dists: (N, k) distances d(u_n, r_i) in the original space.

    Returns:
      (N, k) apex coordinates; the last column is the altitude (>= 0).

    The solve is the batched TPU-native equivalent of the paper's per-object
    ApexAddition loop:  L x = b with
      b_i = (d(u,r0)^2 + ||v_i||^2 - d(u,ri)^2) / 2 ,
    then altitude = sqrt(max(d(u,r0)^2 - ||x||^2, 0)).
    """
    acc = jnp.promote_types(dists.dtype, jnp.float32)
    dists = jnp.asarray(dists).astype(acc)
    if dists.ndim == 1:
        dists = dists[None, :]
    delta0_sq = dists[:, 0] ** 2  # (N,)
    b = 0.5 * (delta0_sq[:, None] + base.diag_g[None, :] - dists[:, 1:] ** 2)
    # (k-1, N) triangular solve: one MXU-friendly op for the whole batch.
    x = jax.scipy.linalg.solve_triangular(
        base.chol.astype(acc), b.T, lower=True
    ).T  # (N, k-1)
    alt_sq = delta0_sq - jnp.sum(x * x, axis=-1)
    altitude = jnp.sqrt(jnp.maximum(alt_sq, 0.0))
    return jnp.concatenate([x, altitude[:, None]], axis=-1)


# ---------------------------------------------------------------------------
# Paper-faithful oracles (Algorithms 1 and 2, sequential; numpy float64)
# ---------------------------------------------------------------------------


def nsimplex_build_reference(D: np.ndarray) -> np.ndarray:
    """Algorithm 1 (nSimplexBuild), verbatim inductive construction.

    Args:
      D: (n+1, n+1) distance matrix among the reference points.

    Returns:
      Sigma: (n+1, n) lower-triangular vertex coordinate matrix.
    """
    D = np.asarray(D, dtype=np.float64)
    n_plus_1 = D.shape[0]
    n = n_plus_1 - 1
    if n == 1:
        return np.array([[0.0], [D[0, 1]]])
    sigma_base = nsimplex_build_reference(D[:n, :n])  # (n, n-1)
    distances = D[:n, n]
    apex = apex_addition_reference(sigma_base, distances)  # (n,)
    sigma = np.zeros((n_plus_1, n))
    sigma[:n, : n - 1] = sigma_base
    sigma[n, :] = apex
    return sigma


def apex_addition_reference(sigma_base: np.ndarray, distances: np.ndarray) -> np.ndarray:
    """Algorithm 2 (ApexAddition), verbatim sequential loop.

    Args:
      sigma_base: (n, n-1) base simplex vertex matrix.
      distances:  (n,) distances from the unknown apex to each base vertex.

    Returns:
      (n,) apex coordinates; last component is the (non-negative) altitude.
    """
    sigma_base = np.asarray(sigma_base, dtype=np.float64)
    distances = np.asarray(distances, dtype=np.float64)
    n = sigma_base.shape[0]
    out = np.zeros(n)
    out[0] = distances[0]
    for i in range(1, n):  # paper's i = 2..n (1-indexed)
        base_row = np.zeros(n)
        base_row[: n - 1] = sigma_base[i]
        l = np.linalg.norm(base_row - out)
        delta = distances[i]
        x = sigma_base[i, i - 1]
        y = out[i - 1]
        out[i - 1] = y - (delta**2 - l**2) / (2.0 * x)
        out[i] = np.sqrt(max(y**2 - out[i - 1] ** 2, 0.0))
    return out


def apex_project_reference(D_refs: np.ndarray, dists: np.ndarray) -> np.ndarray:
    """Project a batch with the paper-faithful per-object loop (oracle)."""
    D_refs = np.asarray(D_refs, dtype=np.float64)
    k = D_refs.shape[0]
    sigma = nsimplex_build_reference(D_refs)  # (k, k-1)
    dists = np.atleast_2d(np.asarray(dists, dtype=np.float64))
    out = np.zeros((dists.shape[0], k))
    for idx in range(dists.shape[0]):
        out[idx] = apex_addition_reference(sigma, dists[idx])
    return out


def verify_base_simplex(D: Array, base: BaseSimplex, *, atol: float = 1e-4) -> Tuple[bool, float]:
    """Check that pairwise vertex distances reproduce the reference distances."""
    V = base.vertices()
    d2 = (
        jnp.sum(V**2, -1)[:, None]
        + jnp.sum(V**2, -1)[None, :]
        - 2 * V @ V.T
    )
    # self-distances are definitionally zero; the matrix-op form leaves
    # O(eps*||v||^2) roundoff there which sqrt would inflate to O(sqrt(eps))
    d2 = d2 * (1.0 - jnp.eye(d2.shape[0], dtype=d2.dtype))
    got = jnp.sqrt(jnp.maximum(d2, 0.0))
    err = float(jnp.max(jnp.abs(got - jnp.asarray(D, got.dtype))))
    return err <= atol, err
