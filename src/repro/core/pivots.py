"""Principled base-simplex (pivot) selection — the ``pivots=`` knob.

The quality of every nSimplex estimate is set at fit time by the base
simplex: the k reference objects ("pivots") whose pairwise distances the
apex projection is built on. The paper (Connor, Vadicamo & Rabitti) selects
them uniformly at random and re-draws on degeneracy
(``core.projection.select_references``), noting only that the choice is
checkable during simplex construction. This module adds the classical
principled alternatives as drop-in strategies:

  random          the paper's baseline (delegates to ``core.projection`` —
                  bit-identical numerics to every earlier release);
  kmeanspp        D^2 sampling (the k-means++ seeding rule): each next pivot
                  is drawn with probability proportional to its squared
                  distance to the nearest already-chosen pivot — spread with
                  a controlled amount of randomness;
  farthest_first  the deterministic greedy 2-approximation of the k-center
                  problem: start at the max-eccentricity witness, repeatedly
                  add the point farthest from the chosen set;
  maxvol          greedy simplex-volume maximisation *using the nSimplex
                  machinery itself*: after seeding with the farthest pair,
                  each next pivot is the witness with the largest altitude
                  over the current base simplex — the altitude IS the
                  distance to the affine hull of the chosen pivots
                  (``core.simplex.apex_project``), so this directly grows
                  the volume term that keeps the Cholesky construction
                  well-conditioned.

All strategies operate on a witness *distance matrix*, never on raw
coordinates, so they work unchanged in coordinate-free Hilbert spaces (jsd,
qform, ... — any ``core.metrics`` entry). The O(n^2) matrix is bounded by
subsampling the witness set to ``max_witness`` rows (deterministically, from
the caller's key) before selection.

Determinism contract: for a fixed key, corpus and strategy the chosen pivot
*ids* are identical across runs and backends (asserted by the golden-parity
suite) — farthest_first and maxvol are fully deterministic given the
witness subsample; kmeanspp consumes the key through ``jax.random``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from . import metrics as metrics_lib
from . import projection as projection_lib
from . import simplex as simplex_lib

Array = jax.Array

#: the pivot-selection menu (the ``pivots=`` knob takes exactly these)
PIVOT_STRATEGIES = ("random", "kmeanspp", "farthest_first", "maxvol")

#: witness subsample cap for the O(n^2) distance-matrix strategies
MAX_WITNESS = 2048


def check_strategy(strategy: str) -> None:
    """Raise ValueError on an unknown pivot strategy (single menu owner)."""
    if strategy not in PIVOT_STRATEGIES:
        raise ValueError(
            f"unknown pivot strategy {strategy!r}; expected one of "
            + "/".join(PIVOT_STRATEGIES))


def _as_dist(D: np.ndarray) -> np.ndarray:
    # np.array, not np.asarray: a dtype-matching device array (x64 mode)
    # converts zero-copy to a *read-only* view, and the greedy loops below
    # mutate their working copies in place.
    D = np.array(D, np.float64)
    n = D.shape[0]
    assert D.shape == (n, n), D.shape
    return D


def farthest_first_indices(D: np.ndarray, k: int) -> np.ndarray:
    """Deterministic farthest-first traversal over a (n, n) distance matrix.

    Starts at the maximum-eccentricity row (largest mean distance to the
    rest — a boundary point, not an arbitrary one), then greedily appends
    ``argmax_x min_{p in chosen} D[x, p]``. Ties break to the lowest index
    (numpy argmax), keeping the result reproducible.
    """
    D = _as_dist(D)
    n = D.shape[0]
    chosen = [int(np.argmax(D.mean(axis=1)))]
    mind = D[:, chosen[0]].copy()
    while len(chosen) < k:
        mind[chosen] = -np.inf
        nxt = int(np.argmax(mind))
        chosen.append(nxt)
        mind = np.minimum(mind, D[:, nxt])
    return np.asarray(chosen, np.int64)


def kmeanspp_indices(D: np.ndarray, k: int, key: Array) -> np.ndarray:
    """k-means++ (D^2) pivot sampling over a (n, n) distance matrix.

    The first pivot is uniform; each next one is drawn with probability
    proportional to its squared distance to the nearest chosen pivot
    (the same seeding rule as ``index.kmeans``, but metric-general: it only
    reads the matrix). A degenerate all-zero tail (duplicate witnesses)
    falls back to the first unchosen index.
    """
    D = _as_dist(D)
    n = D.shape[0]
    key, sub = jax.random.split(key)
    chosen = [int(jax.random.randint(sub, (), 0, n))]
    d2 = D[:, chosen[0]] ** 2
    while len(chosen) < k:
        d2[chosen] = 0.0
        total = float(d2.sum())
        if total <= 0.0:  # duplicates everywhere: deterministic fill
            rest = [i for i in range(n) if i not in set(chosen)]
            chosen.append(rest[0])
        else:
            key, sub = jax.random.split(key)
            nxt = int(jax.random.choice(
                sub, n, (), p=jnp.asarray(d2 / total, jnp.float32)))
            if nxt in set(chosen):  # f32 renorm noise: take the argmax
                nxt = int(np.argmax(d2))
            chosen.append(nxt)
        d2 = np.minimum(d2, D[:, chosen[-1]] ** 2)
    return np.asarray(chosen, np.int64)


def maxvol_indices(
    D: np.ndarray, k: int, *, jitter: float = 0.0
) -> np.ndarray:
    """Greedy max-volume pivots via the apex projection's own altitude.

    Seeds with the globally farthest pair, then repeatedly builds the base
    simplex of the chosen set (``core.simplex.build_base_simplex``),
    projects every witness onto it, and appends the witness with the
    largest altitude — its distance to the affine hull of the current
    pivots, i.e. exactly the height whose product the simplex volume is.
    Fully deterministic.
    """
    D = _as_dist(D)
    n = D.shape[0]
    if k == 1:
        return np.asarray([int(np.argmax(D.mean(axis=1)))], np.int64)
    flat = int(np.argmax(D))
    chosen = sorted({flat // n, flat % n})
    if len(chosen) == 1:  # all-duplicate corner: any second point
        chosen.append((chosen[0] + 1) % n)
    while len(chosen) < k:
        sub = jnp.asarray(D[np.ix_(chosen, chosen)], jnp.float32)
        base = simplex_lib.build_base_simplex(sub, jitter=jitter)
        coords = simplex_lib.apex_project(
            base, jnp.asarray(D[:, chosen], jnp.float32))
        alt = np.array(coords[:, -1], np.float64)  # writable copy (x64 mode)
        alt[~np.isfinite(alt)] = -np.inf
        alt[chosen] = -np.inf
        nxt = int(np.argmax(alt))
        if not np.isfinite(alt[nxt]):  # fully degenerate witness set:
            # every altitude collapsed — keep the ids distinct regardless
            nxt = next(i for i in range(n) if i not in set(chosen))
        chosen.append(nxt)
    return np.asarray(chosen, np.int64)


def select_pivot_indices(
    D: np.ndarray,
    k: int,
    strategy: str,
    *,
    key: Optional[Array] = None,
    jitter: float = 0.0,
) -> np.ndarray:
    """Dispatch: (n, n) witness distance matrix -> (k,) pivot row indices.

    ``key`` is consumed by the stochastic strategies (random, kmeanspp) and
    ignored by the deterministic ones. Works for any metric — callers in
    coordinate-free spaces pass their precomputed matrix directly.
    """
    check_strategy(strategy)
    D = _as_dist(D)
    n = D.shape[0]
    if not 1 <= k <= n:
        raise ValueError(f"need 1 <= k <= n={n} pivots, got k={k}")
    if strategy == "random":
        key = key if key is not None else jax.random.PRNGKey(0)
        return np.asarray(
            jax.random.choice(key, n, (k,), replace=False), np.int64)
    if strategy == "kmeanspp":
        key = key if key is not None else jax.random.PRNGKey(0)
        return kmeanspp_indices(D, k, key)
    if strategy == "farthest_first":
        return farthest_first_indices(D, k)
    return maxvol_indices(D, k, jitter=jitter)


def pivot_ids(
    X: Array,
    k: int,
    key: Array,
    *,
    strategy: str,
    metric: str = "euclidean",
    max_witness: int = MAX_WITNESS,
    jitter: float = 0.0,
) -> np.ndarray:
    """Chosen pivot *row ids into X* for a strategy (golden/ablation probe).

    Subsamples the witness set to ``max_witness`` rows (deterministic in
    ``key``), builds the metric's pairwise matrix once, and maps the local
    selection back to global row ids.
    """
    check_strategy(strategy)
    X = jnp.asarray(X)
    n = X.shape[0]
    wkey, skey = jax.random.split(key)
    if n > max_witness:
        wit = np.sort(np.asarray(
            jax.random.choice(wkey, n, (max_witness,), replace=False),
            np.int64))
    else:
        wit = np.arange(n, dtype=np.int64)
    m = metrics_lib.get_metric(metric)
    W = X[jnp.asarray(wit)]
    if m.normalize is not None:
        W = m.normalize(W)
    D = np.array(m.pdist(W, W), np.float64)  # writable copy (x64 mode)
    np.fill_diagonal(D, 0.0)
    local = select_pivot_indices(D, k, strategy, key=skey, jitter=jitter)
    return wit[local]


def select_references(
    X: Array,
    k: int,
    key: Array,
    *,
    metric: str = "euclidean",
    strategy: str = "random",
    max_witness: int = MAX_WITNESS,
    jitter: float = 0.0,
    max_tries: int = 8,
) -> projection_lib.NSimplexTransform:
    """Strategy-aware replacement for ``core.projection.select_references``.

    ``strategy="random"`` delegates to the original redraw loop untouched —
    same key stream, same references, bit-identical coordinates to every
    earlier release (the golden suite pins this). The principled strategies
    pick pivots from a witness distance matrix (:func:`pivot_ids`) and fit;
    should the resulting simplex still be degenerate (duplicate witnesses,
    rank-deficient corpora), they fall back to the random redraw loop
    rather than serve a broken base.
    """
    check_strategy(strategy)
    if strategy == "random":
        return projection_lib.select_references(
            X, k, key, metric=metric, max_tries=max_tries, jitter=jitter)
    X = jnp.asarray(X)
    idx = pivot_ids(X, k, key, strategy=strategy, metric=metric,
                    max_witness=max_witness, jitter=jitter)
    tr = projection_lib.NSimplexTransform(
        k=k, metric=metric, jitter=jitter).fit(X[jnp.asarray(idx)])
    if bool(tr.degenerate()):
        return projection_lib.select_references(
            X, k, key, metric=metric, max_tries=max_tries, jitter=jitter)
    return tr
