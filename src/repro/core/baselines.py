"""Baseline DR transforms the paper compares against (Section 3), in JAX.

* PCA  — witness-set SVD, top-k principal components (paper §3.2).
* RP   — Achlioptas sparse random projection, Eq. (2) (paper §3.1).
* MDS  — classical (Torgerson) MDS on a witness set with the paper's
         out-of-sample extension: a least-squares linear map fitted from the
         witness coordinates to the MDS embedding (§3.3 'Procrustes +
         pseudo-inverse').
* LMDS — Landmark MDS (de Silva & Tenenbaum), distance-only triangulation;
         applies to coordinate-free Hilbert spaces (paper §3.4, §5.6).

Each transform follows the same fit/transform protocol as NSimplexTransform so
quality harnesses and benchmarks treat them uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PCATransform:
    k: int
    mean: Optional[Array] = None
    components: Optional[Array] = None  # (m, k)
    explained_variance: Optional[Array] = None  # (min(l, m),) all eigenvalues

    def tree_flatten(self):
        return (self.mean, self.components, self.explained_variance), (self.k,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], *children)

    def fit(self, witness: Array) -> "PCATransform":
        W = jnp.asarray(witness, jnp.float32)
        mean = jnp.mean(W, axis=0)
        Wc = W - mean
        # economy SVD: components = right singular vectors
        _, s, vt = jnp.linalg.svd(Wc, full_matrices=False)
        var = (s**2) / jnp.maximum(W.shape[0] - 1, 1)
        return dataclasses.replace(
            self, mean=mean, components=vt[: self.k].T, explained_variance=var
        )

    def transform(self, X: Array) -> Array:
        return (jnp.asarray(X, jnp.float32) - self.mean) @ self.components

    def dims_for_variance(self, frac: float = 0.8) -> int:
        """Paper Eq. (3): #components explaining ``frac`` of total variance.

        Clamped to [1, n_eigenvalues]: with ``frac=1.0`` the f32 cumsum can
        land a hair below 1.0, which would otherwise index one past the
        spectrum.
        """
        ev = self.explained_variance
        c = jnp.cumsum(ev) / jnp.sum(ev)
        return int(jnp.clip(jnp.searchsorted(c, frac) + 1, 1, ev.shape[0]))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RandomProjection:
    """Achlioptas database-friendly RP (paper Eq. 2), scaled by 1/sqrt(k)."""

    k: int
    matrix: Optional[Array] = None  # (m, k)

    def tree_flatten(self):
        return (self.matrix,), (self.k,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], *children)

    def fit(self, m_or_witness, *, key: jax.Array) -> "RandomProjection":
        m = m_or_witness if isinstance(m_or_witness, int) else m_or_witness.shape[-1]
        u = jax.random.uniform(key, (m, self.k))
        vals = jnp.sqrt(3.0) * (
            jnp.where(u < 1.0 / 6.0, 1.0, 0.0) - jnp.where(u >= 5.0 / 6.0, 1.0, 0.0)
        )
        return dataclasses.replace(self, matrix=vals / jnp.sqrt(float(self.k)))

    def transform(self, X: Array) -> Array:
        return jnp.asarray(X, jnp.float32) @ self.matrix


def classical_mds_embed(D: Array, k: int) -> tuple[Array, Array, Array]:
    """Torgerson MDS: embed an (l, l) distance matrix into R^k.

    Returns (coords (l,k), eigenvalues (k,), mean_sq_dist_columns (l,)).
    """
    D = jnp.asarray(D, jnp.float32)
    l = D.shape[0]
    D2 = D**2
    J = jnp.eye(l) - jnp.full((l, l), 1.0 / l)
    B = -0.5 * J @ D2 @ J
    evals, evecs = jnp.linalg.eigh(B)  # ascending
    evals, evecs = evals[::-1][:k], evecs[:, ::-1][:, :k]
    pos = jnp.maximum(evals, 0.0)
    coords = evecs * jnp.sqrt(pos)[None, :]
    return coords, evals, jnp.mean(D2, axis=1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MDSTransform:
    """Classical MDS + linear out-of-sample map (Euclidean domains, §3.3)."""

    k: int
    mean: Optional[Array] = None
    linear: Optional[Array] = None  # (m, k) least-squares map
    stress_coords: Optional[Array] = None  # witness embedding (diagnostics)

    def tree_flatten(self):
        return (self.mean, self.linear, self.stress_coords), (self.k,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], *children)

    def fit(self, witness: Array, D: Optional[Array] = None) -> "MDSTransform":
        W = jnp.asarray(witness, jnp.float32)
        if D is None:
            n2 = jnp.sum(W**2, 1)
            D = jnp.sqrt(jnp.maximum(n2[:, None] + n2[None, :] - 2 * W @ W.T, 0.0))
        coords, _, _ = classical_mds_embed(D, self.k)
        mean = jnp.mean(W, axis=0)
        Wc = W - mean
        # pseudo-inverse least-squares map R^m -> R^k (paper's Procrustes+pinv)
        linear = jnp.linalg.pinv(Wc) @ coords
        return dataclasses.replace(self, mean=mean, linear=linear, stress_coords=coords)

    def transform(self, X: Array) -> Array:
        return (jnp.asarray(X, jnp.float32) - self.mean) @ self.linear


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LMDSTransform:
    """Landmark MDS (distance-only; works on coordinate-free spaces).

    fit: classical MDS over the (l, l) landmark distance matrix.
    transform: for object u with squared distances delta (l,) to landmarks,
      x(u) = -0.5 * pinv_coords @ (delta - mean_delta)
    where pinv_coords_j = evec_j / sqrt(eval_j)  (de Silva & Tenenbaum 2004).
    """

    k: int
    pinv_coords: Optional[Array] = None  # (k, l)
    mean_sq: Optional[Array] = None  # (l,)
    landmarks: Optional[Array] = None  # optional coordinates for convenience

    def tree_flatten(self):
        return (self.pinv_coords, self.mean_sq, self.landmarks), (self.k,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], *children)

    def fit_from_distances(self, D: Array) -> "LMDSTransform":
        D = jnp.asarray(D, jnp.float32)
        coords, evals, mean_sq = classical_mds_embed(D, self.k)
        # Directions whose eigenvalue is numerically zero relative to the
        # spectrum head carry no metric information; de Silva & Tenenbaum
        # drop them. Dividing by the raw (near-zero) eigenvalue instead
        # produces ~1/eps triangulation rows that blow out-of-sample
        # coordinates up by orders of magnitude whenever l ~ k.
        tiny = 1e-6 * jnp.maximum(jnp.max(evals), 1e-12)
        safe = jnp.maximum(evals, tiny)
        pinv = jnp.where(
            evals[None, :] > tiny, coords / safe[None, :], 0.0
        ).T  # (k, l): evec_j / sqrt(eval_j), zeroed on dead directions
        return dataclasses.replace(self, pinv_coords=pinv, mean_sq=mean_sq)

    def transform_from_distances(self, dists: Array) -> Array:
        """dists: (N, l) object-to-landmark distances (not squared)."""
        d2 = jnp.asarray(dists, jnp.float32) ** 2
        return -0.5 * (d2 - self.mean_sq[None, :]) @ self.pinv_coords.T
