"""Sharded nSimplex-Zen retrieval: per-shard streaming (or clustered IVF)
top-k + on-mesh ring merge.

For indexes too large for one device, the reduced (N, k) coordinate matrix is
row-sharded over a mesh axis. Each device runs the streaming fused top-k
(``kernels.ops.zen_topk``) over its local shard — never materialising a
(Q, N_shard) matrix — and emits its best-k candidates with *global* row ids
(local id + shard offset, derived from ``lax.axis_index`` inside shard_map).

The per-shard candidate lists are merged *inside* shard_map with a ring of
``lax.ppermute`` hops: every device forwards the candidate buffer it received
on the previous hop to its ring successor and folds the incoming candidates
into its running top-k, so after ``size(axis) - 1`` hops each device has seen
every shard's candidates. Merge traffic is O(Q·k) per hop — no
O(n_shards · k) host gather, and no host round-trip at all. The fold selects
by the lexicographic key ``(distance, global id)``, so every device converges
to the *same* replicated result regardless of the order candidates arrived
in, and equal-distance ties break toward the lower global id exactly like the
single-device dense/streaming paths.

``sharded_ivf_probe`` runs the clustered variant under the same scaffolding:
each device probes its local slice of the packed inverted-list tiles
(``kernels.ops.ivf_probe``) with a replicated per-query probe list; tile ids
are already global and padding rows are masked inside the probe
(id == -1 -> +inf), so the merge needs no padding compensation.

Both entry points accept an optional per-shard ``alive`` mask (degraded-shard
serving, see ``distributed.fault``): a dead shard's candidates are forced to
(+inf, -1) before the ring, so queries keep answering from the surviving
shards with reduced recall instead of raising.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # moved out of experimental in newer jax
    from jax.shard_map import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.kernels import ops as kernel_ops

Array = jax.Array


def _lex_topk(d: Array, ids: Array, k: int) -> Tuple[Array, Array]:
    """Smallest-k columns of (Q, w) candidates by the (distance, id) key.

    The id tie-break makes the selection canonical: any permutation of the
    candidate columns yields the same output, which is what lets every ring
    participant converge to an identical replicated top-k.
    """
    order = jnp.lexsort((ids, d), axis=-1)[..., :k]
    return (jnp.take_along_axis(d, order, axis=-1),
            jnp.take_along_axis(ids, order, axis=-1))


def _ring_merge(
    d: Array, ids: Array, n_neighbors: int, mesh, axis_names: Tuple[str, ...]
) -> Tuple[Array, Array]:
    """Merge per-shard (Q, k) candidates into a replicated global top-k.

    Runs inside shard_map. Along each sharded mesh axis in turn, every
    device forwards the buffer it received on the previous hop to its ring
    successor (so the *original* per-shard candidate sets circulate, O(Q·k)
    per hop) and folds the incoming buffer into its running best. For a
    multi-axis sharding the rings compose: the first axis' ring leaves every
    device of an axis group holding the group's merged top-k, which the next
    axis' ring then circulates.
    """
    best_d, best_i = _lex_topk(d, ids, n_neighbors)
    for a in axis_names:
        size = mesh.shape[a]
        if size == 1:
            continue
        perm = [(i, (i + 1) % size) for i in range(size)]
        recv_d, recv_i = best_d, best_i
        for _ in range(size - 1):
            recv_d = jax.lax.ppermute(recv_d, a, perm)
            recv_i = jax.lax.ppermute(recv_i, a, perm)
            best_d, best_i = _lex_topk(
                jnp.concatenate([best_d, recv_d], axis=1),
                jnp.concatenate([best_i, recv_i], axis=1),
                n_neighbors,
            )
    return best_d, best_i


def _apply_alive_mask(d: Array, ids: Array, alive_local) -> Tuple[Array, Array]:
    """Force a dead shard's local candidates to (+inf, -1) before the ring."""
    ok = alive_local[0]
    return (jnp.where(ok, d, jnp.inf),
            jnp.where(ok, ids, jnp.int32(-1)))


def sharded_knn_search(
    queries: Array,
    index: Array,
    n_neighbors: int = 10,
    mode: str = "zen",
    *,
    mesh,
    axis: Optional[Union[str, Tuple[str, ...]]] = None,
    chunk: int = 4096,
    force_kernel: bool = False,
    n_valid: Optional[int] = None,
    scales: Optional[Array] = None,
    alive: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Top-k of ``queries`` in a row-sharded ``index`` over ``mesh``.

    Args:
      queries: (Q, k) projected queries, replicated to every device.
      index:   (N, k) projected index, row-sharded over ``axis``; stored
               f32, bf16 or int8 (``kernels.quantize``).
      mesh:    the device mesh.
      axis:    mesh axis name (or tuple of names) the rows are sharded over;
               defaults to all mesh axes.
      chunk:   streaming chunk for the per-shard scan fallback off-TPU.
      force_kernel: run the Pallas kernel in interpret mode off-TPU.
      n_valid: number of real index rows when ``index`` was pre-padded to a
               shard-divisible length (e.g. by ``build_index``); trailing
               rows are treated as padding. Defaults to all rows.
      scales:  (N, 1) f32 per-row dequant scales when ``index`` is int8,
               sharded like the index rows; each shard dequantises its own
               tiles inside the streaming kernel.
      alive:   (n_shards,) bool, linearised in ``axis`` order; a False shard
               contributes nothing (degraded serving). Defaults to all-alive.

    Returns:
      (distances, indices), each (Q, n_neighbors), ascending distance, with
      indices referring to rows of the *global* index.
    """
    axis_names = resolve_axis_names(mesh, axis)
    n_shards = math.prod(mesh.shape[a] for a in axis_names)

    n = index.shape[0] if n_valid is None else n_valid
    n_neighbors = min(n_neighbors, n)
    if index.shape[0] % n_shards:
        shard_rows = -(-index.shape[0] // n_shards)  # ceil
        pad = shard_rows * n_shards - index.shape[0]
        index = jnp.pad(
            index, ((0, pad), (0, 0))
        )  # zero rows, never returned (see k_fetch below)
        if scales is not None:
            scales = jnp.pad(scales, ((0, pad), (0, 0)))
    else:  # pre-padded (or evenly divisible) index: no O(N) copy per call
        shard_rows = index.shape[0] // n_shards
    # Padding rows sit at the estimator distance of the origin, so they can
    # win local top-k slots from real candidates before the global-id mask
    # runs. All padding lives in the trailing shard(s): fetching that many
    # extra local candidates guarantees the true top-k survives the merge.
    n_pad = shard_rows * n_shards - n
    k_fetch = min(shard_rows, n_neighbors + min(n_pad, shard_rows))
    return _sharded_topk(
        queries, index, scales, alive, n=n, shard_rows=shard_rows,
        k_fetch=k_fetch, n_neighbors=n_neighbors, mode=mode, mesh=mesh,
        axis_names=axis_names, chunk=chunk, force_kernel=force_kernel,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n", "shard_rows", "k_fetch", "n_neighbors", "mode", "mesh",
        "axis_names", "chunk", "force_kernel",
    ),
)
def _sharded_topk(
    queries: Array,
    index: Array,
    scales: Optional[Array],
    alive: Optional[Array],
    *,
    n: int,
    shard_rows: int,
    k_fetch: int,
    n_neighbors: int,
    mode: str,
    mesh,
    axis_names: Tuple[str, ...],
    chunk: int,
    force_kernel: bool,
) -> Tuple[Array, Array]:
    shard_axes = axis_names if len(axis_names) > 1 else axis_names[0]

    def local_topk(q, x, *rest):
        # x: (shard_rows, kdim) — this device's shard
        rest = list(rest)
        s = rest.pop(0) if scales is not None else None
        al = rest.pop(0) if alive is not None else None
        off = jnp.int32(0)
        for a in axis_names:  # linearised shard position on the (sub)mesh
            off = off * mesh.shape[a] + jax.lax.axis_index(a)
        d, ids = kernel_ops.zen_topk(
            q, x, k_fetch, mode, scales=s,
            force_kernel=force_kernel, chunk=chunk
        )
        gids = ids + off * shard_rows
        pad = gids >= n  # padded tail rows never reach the merge
        d = jnp.where(pad, jnp.inf, d)
        gids = jnp.where(pad, jnp.int32(-1), gids)
        if al is not None:
            d, gids = _apply_alive_mask(d, gids, al)
        if k_fetch < n_neighbors:  # tiny shard: widen to the merge width
            fill = n_neighbors - k_fetch
            d = jnp.pad(d, ((0, 0), (0, fill)), constant_values=jnp.inf)
            gids = jnp.pad(gids, ((0, 0), (0, fill)), constant_values=-1)
        return _ring_merge(d, gids, n_neighbors, mesh, axis_names)

    in_specs = [P(), P(shard_axes, None)]
    operands = [queries, index]
    if scales is not None:
        in_specs.append(P(shard_axes, None))
        operands.append(scales)
    if alive is not None:
        in_specs.append(P(shard_axes))
        operands.append(alive)
    # the ring leaves every device holding the same merged top-k, so the
    # outputs are replicated (check_rep can't prove it through ppermute)
    return shard_map(
        local_topk,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(), P()),
        check_rep=False,
    )(*operands)


def resolve_axis_names(
    mesh, axis: Optional[Union[str, Tuple[str, ...]]]
) -> Tuple[str, ...]:
    """Normalise an ``axis`` argument: None -> all mesh axes, str -> 1-tuple."""
    if axis is None:
        return tuple(mesh.axis_names)
    if isinstance(axis, str):
        return (axis,)
    return tuple(axis)


def host_rows(x: Array, n_valid: Optional[int] = None):
    """Gather a (possibly row-sharded) array to one host copy.

    Used by the index checkpointing path (``ZenServer.save``): snapshots
    store canonical unsharded rows so the device count becomes a load-time
    choice. ``n_valid`` strips the trailing shard-padding rows that
    ``shard_rows`` appended.
    """
    import numpy as np

    out = np.asarray(jax.device_get(x))
    return out if n_valid is None else out[:n_valid]


def shard_rows(
    x: Array,
    *,
    mesh,
    axis: Optional[Union[str, Tuple[str, ...]]] = None,
) -> Tuple[Array, int]:
    """Row-shard ``x`` over ``mesh``, zero-padding to a divisible row count.

    The per-shard-save / reshard-on-load counterpart of :func:`host_rows`:
    pads (N, ...) with zero rows to a multiple of the shard count and
    device_puts it with ``NamedSharding(mesh, P(axes, None, ...))``. Returns
    ``(sharded array, n_valid)`` where ``n_valid`` is the original N —
    pass it back to :func:`sharded_knn_search` so padded rows are masked.
    """
    from jax.sharding import NamedSharding

    axis_names = resolve_axis_names(mesh, axis)
    n_shards = math.prod(mesh.shape[a] for a in axis_names)
    n_valid = x.shape[0]
    pad = (-n_valid) % n_shards
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    rows = axis_names if len(axis_names) > 1 else axis_names[0]
    spec = P(rows, *([None] * (x.ndim - 1)))
    return jax.device_put(x, NamedSharding(mesh, spec)), n_valid


def sharded_ivf_probe(
    queries: Array,
    tile_coords: Array,
    tile_ids: Array,
    probes: Array,
    n_neighbors: int = 10,
    mode: str = "zen",
    *,
    mesh,
    axis: Optional[Union[str, Tuple[str, ...]]] = None,
    tiles_per_cluster: int,
    tile_scales: Optional[Array] = None,
    force_kernel: bool = False,
    alive: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Clustered top-k of ``queries`` in mesh-sharded inverted-list tiles.

    Args:
      queries:     (Q, k) projected queries, replicated to every device.
      tile_coords: (S*C*T, tile_rows, k) packed tiles, row-sharded over
                   ``axis`` — each device holds its own shard's (C*T, ...)
                   inverted lists (see ``index.ivf.ShardedIVFZenIndex``);
                   stored f32, bf16 or int8.
      tile_ids:    (S*C*T, tile_rows) int32 *global* row ids, -1 = padding.
      probes:      (Q, nprobe) int32 cluster ids, replicated (one global
                   coarse quantizer).
      tiles_per_cluster: T of the packed layout.
      tile_scales: (C, 1) f32 per-cluster int8 dequant scales, replicated
                   (the scales follow the *global* assignment, like the
                   centroids — every shard sees the same values).
      alive:       (n_shards,) bool, linearised in ``axis`` order; a False
                   shard's tiles are dropped from the merge (degraded
                   serving). Defaults to all-alive.

    Returns (distances, indices), each (Q, n_neighbors), ascending, with
    global indices; slots the probed clusters cannot fill are (+inf, -1).
    """
    axis_names = resolve_axis_names(mesh, axis)
    return _sharded_ivf_topk(
        queries, tile_coords, tile_ids, probes, tile_scales, alive,
        n_neighbors=n_neighbors, mode=mode, mesh=mesh,
        axis_names=axis_names, tiles_per_cluster=tiles_per_cluster,
        force_kernel=force_kernel,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_neighbors", "mode", "mesh", "axis_names", "tiles_per_cluster",
        "force_kernel",
    ),
)
def _sharded_ivf_topk(
    queries: Array,
    tile_coords: Array,
    tile_ids: Array,
    probes: Array,
    tile_scales: Optional[Array],
    alive: Optional[Array],
    *,
    n_neighbors: int,
    mode: str,
    mesh,
    axis_names: Tuple[str, ...],
    tiles_per_cluster: int,
    force_kernel: bool,
) -> Tuple[Array, Array]:
    shard_axes = axis_names if len(axis_names) > 1 else axis_names[0]

    def local_probe(q, tc, ti, pr, *rest):
        # tc: (C*T, tile_rows, k) — this device's inverted lists, global ids
        rest = list(rest)
        ts = rest.pop(0) if tile_scales is not None else None
        al = rest.pop(0) if alive is not None else None
        d, gids = kernel_ops.ivf_probe(
            q, tc, ti, pr, n_neighbors, mode,
            tiles_per_cluster=tiles_per_cluster,
            tile_scales=ts, force_kernel=force_kernel,
        )
        if al is not None:
            d, gids = _apply_alive_mask(d, gids, al)
        # local padding already carries (+inf, -1): no compensation needed
        return _ring_merge(d, gids, n_neighbors, mesh, axis_names)

    in_specs = [P(), P(shard_axes, None, None), P(shard_axes, None), P()]
    operands = [queries, tile_coords, tile_ids, probes]
    if tile_scales is not None:
        in_specs.append(P())  # replicated, like the probes
        operands.append(tile_scales)
    if alive is not None:
        in_specs.append(P(shard_axes))
        operands.append(alive)
    return shard_map(
        local_probe,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(), P()),
        check_rep=False,
    )(*operands)
