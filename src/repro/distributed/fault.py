"""Fault-tolerance & straggler-mitigation runtime hooks.

This container is single-process CPU, so the cross-host signals are modelled
as in-process hooks with the same contracts a multi-controller deployment
uses (jax.distributed + coordination service):

* **StepMonitor** — per-step wall-time EMA; flags a straggler when a step
  exceeds ``threshold x`` the EMA. On a real pod the per-host step times are
  all-gathered (a tiny f32 collective piggybacked on the step); the slowest
  host is reported and, past a patience budget, the policy asks the runner to
  (a) rebalance input shards away from the slow host, then (b) checkpoint and
  re-launch without it (elastic restart via CheckpointManager resharding).
* **HeartbeatRegistry** — liveness bookkeeping with a deadline; a missed
  heartbeat marks the host failed and triggers the elastic-restart path.
* **preemption_aware_save** — the SIGTERM hook: checkpoint synchronously at
  the next step boundary when the platform announces preemption.

The trainer (launch/train.py) wires these in; unit tests drive them with a
fake clock.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ema: float
    ratio: float


class StepMonitor:
    def __init__(self, *, ema_decay: float = 0.9, threshold: float = 2.0,
                 warmup_steps: int = 5, patience: int = 3):
        self.ema_decay = ema_decay
        self.threshold = threshold
        self.warmup_steps = warmup_steps
        self.patience = patience
        self.ema: Optional[float] = None
        self.n = 0
        self.consecutive = 0
        self.events: list[StragglerEvent] = []

    def record(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        """Feed one step's wall time; returns an event when flagged."""
        self.n += 1
        if self.ema is None:
            self.ema = step_time
            return None
        flagged = None
        if step_time > self.threshold * self.ema:
            # never fold a straggler into the EMA (keep the baseline honest)
            # — warmup included, where absorbing one would inflate the EMA
            # enough to hide every later straggler behind the raised bar
            if self.n > self.warmup_steps:
                self.consecutive += 1
                flagged = StragglerEvent(step, step_time, self.ema,
                                         step_time / self.ema)
                self.events.append(flagged)
        else:
            self.consecutive = 0
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * step_time
        return flagged

    @property
    def should_escalate(self) -> bool:
        """Patience exhausted -> checkpoint + elastic restart."""
        return self.consecutive >= self.patience


class HeartbeatRegistry:
    """Liveness bookkeeping over an *expected* membership.

    ``register(host)`` declares that a host is supposed to beat; a host that
    registers (or is registered by the deployment) and then never beats is
    reported dead one deadline after registration — silence from birth is
    indistinguishable from an early crash and must not be invisible.
    ``beat`` on an unknown host implicitly registers it.
    """

    def __init__(self, deadline_s: float = 60.0, now: Callable[[], float] = time.monotonic):
        self.deadline_s = deadline_s
        self._now = now
        self._last: Dict[str, float] = {}        # host -> last beat time
        self._registered: Dict[str, float] = {}  # host -> registration time

    def register(self, host: str) -> None:
        """Declare expected membership (idempotent; keeps the first time)."""
        self._registered.setdefault(host, self._now())

    def beat(self, host: str) -> None:
        self._registered.setdefault(host, self._now())
        self._last[host] = self._now()

    def expected(self) -> list[str]:
        return sorted(self._registered)

    def _deadline_ref(self, host: str) -> float:
        """Last beat, or registration time for a host that never beat."""
        return self._last.get(host, self._registered[host])

    def dead_hosts(self) -> list[str]:
        t = self._now()
        return [h for h in self.expected()
                if t - self._deadline_ref(h) > self.deadline_s]

    def alive(self) -> list[str]:
        t = self._now()
        return sorted(h for h in self.expected()
                      if t - self._deadline_ref(h) <= self.deadline_s)


class ReplicaTracker:
    """Leader-side bookkeeping of a query-plane replica fleet.

    The replicated serving tier (``launch.replicate``) is pull-based —
    replicas poll the publish directory and swap on their own schedule — so
    the leader cannot *assume* coherence; it can only observe it. Each
    replica's supervisor calls :meth:`report` with the generation it is
    currently serving; the tracker folds that into a
    :class:`HeartbeatRegistry` (silence past the deadline = dead replica)
    and answers the two operator questions: who is alive, and who is still
    serving an older generation than the latest publish (*lagging* — legal,
    the replica keeps serving its old snapshot, but worth surfacing when a
    publish is not being picked up).
    """

    def __init__(self, deadline_s: float = 60.0,
                 now: Callable[[], float] = time.monotonic):
        self.heartbeats = HeartbeatRegistry(deadline_s=deadline_s, now=now)
        self._generation: Dict[str, int] = {}

    def report(self, replica: str, generation: int) -> None:
        """One replica status beat: the generation it currently serves."""
        self.heartbeats.beat(replica)
        self._generation[str(replica)] = int(generation)

    def generation_of(self, replica: str) -> Optional[int]:
        return self._generation.get(str(replica))

    def lagging(self, published_generation: int) -> list[str]:
        """Alive replicas serving a generation older than the published one
        (a replica that never reported counts as lagging from generation
        -1 — silence must not read as coherence)."""
        return [r for r in self.heartbeats.alive()
                if self._generation.get(r, -1) < published_generation]

    def coherent(self, published_generation: int) -> bool:
        """True when every *alive* replica serves the published generation."""
        return not self.lagging(published_generation)

    def status(self, published_generation: int) -> dict:
        """Operator snapshot: liveness + lag against the given publish."""
        return {
            "published_generation": int(published_generation),
            "replicas": dict(sorted(self._generation.items())),
            "alive": self.heartbeats.alive(),
            "dead": self.heartbeats.dead_hosts(),
            "lagging": self.lagging(published_generation),
        }


class PreemptionGuard:
    """SIGTERM-aware save trigger: ``if guard.should_save(): ckpt.save(...)``."""

    def __init__(self, install_signal: bool = True):
        self._flag = False
        if install_signal:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self._flag = True

    def request(self) -> None:  # manual trigger (tests / platform hook)
        self._flag = True

    def should_save(self) -> bool:
        return self._flag

    def clear(self) -> None:
        self._flag = False
