from . import retrieval, sharding
from .retrieval import sharded_knn_search
from .sharding import data_axes, opt_state_specs, param_specs

__all__ = [
    "sharding",
    "retrieval",
    "sharded_knn_search",
    "param_specs",
    "opt_state_specs",
    "data_axes",
]
