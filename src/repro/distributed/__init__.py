from . import sharding
from .sharding import data_axes, opt_state_specs, param_specs

__all__ = ["sharding", "param_specs", "opt_state_specs", "data_axes"]
