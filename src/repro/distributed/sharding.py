"""Sharding rules: parameter and input PartitionSpecs per architecture family.

Conventions (DESIGN.md §6):
  * ``model`` axis: tensor/expert parallel — attention heads & FFN width for
    LMs, expert dim for MoE, channel dim for MACE, embedding-table rows and
    vocab for recsys/LM heads;
  * data axes (``data`` alone, or ``("pod", "data")`` on the multi-pod mesh):
    batch / sequence(500k decode) / edges;
  * optimizer moments inherit the parameter specs (FSDP-compatible).

``param_specs(family, cfg, params_shape)`` maps a pytree of ShapeDtypeStructs
to a pytree of PartitionSpecs by leaf path, so the same rules drive real
training (device_put), the dry-run (in_shardings) and checkpoint resharding.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import AdamWState


def data_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


# -- LM transformer ------------------------------------------------------------

_LM_RULES = [
    # (path substring, spec builder given leaf ndim)
    ("embed", lambda nd: P("model", None)),
    ("lm_head", lambda nd: P(None, "model")),
    ("final_norm", lambda nd: P(None)),
    ("layers/wq", lambda nd: P(None, None, None, "model")),
    ("layers/wk", lambda nd: P(None, None, None, "model")),
    ("layers/wv", lambda nd: P(None, None, None, "model")),
    ("layers/wo", lambda nd: P(None, None, "model", None)),
    ("layers/bq", lambda nd: P(None, None, "model")),
    ("layers/bk", lambda nd: P(None, None, "model")),
    ("layers/bv", lambda nd: P(None, None, "model")),
    ("layers/w_gate", lambda nd: P(None, None, None, "model")),
    ("layers/w_up", lambda nd: P(None, None, None, "model")),
    ("layers/w_down", lambda nd: P(None, None, "model", None)),
    ("layers/router", lambda nd: P(None, None, None, "model")),
    ("layers/we_gate", lambda nd: P(None, None, "model", None, None)),
    ("layers/we_up", lambda nd: P(None, None, "model", None, None)),
    ("layers/we_down", lambda nd: P(None, None, "model", None, None)),
    ("layers/ws_gate_logit", lambda nd: P()),
    ("layers/ws_gate", lambda nd: P(None, None, None, "model")),
    ("layers/ws_up", lambda nd: P(None, None, None, "model")),
    ("layers/ws_down", lambda nd: P(None, None, "model", None)),
    ("layers/ln", lambda nd: P()),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def lm_param_specs(params_shape: Any) -> Any:
    def spec_for(path, leaf):
        s = _path_str(path)
        for frag, builder in _LM_RULES:
            if frag in s:
                sp = builder(leaf.ndim)
                # guard: rule rank must not exceed leaf rank
                if len(sp) <= leaf.ndim or sp == P():
                    return sp
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


# -- MACE ------------------------------------------------------------------


def gnn_param_specs(params_shape: Any) -> Any:
    """Channel-mixing linears shard their *output* channels over model; the
    radial MLP output (C * n_paths) also shards over model."""

    def spec_for(path, leaf):
        s = _path_str(path)
        if "embed" in s:
            return P(None, "model")
        if "rad_w2" in s:
            # (hidden, P, C): C aligned with the model axis -> per-edge
            # weighting is collective-free
            return P(None, None, "model")
        if "msg" in s:
            # (P, C_in, C_out): contract over the sharded C_in
            return P(None, "model", None)
        if "self" in s:
            return P("model", None)
        if "w_corr" in s:
            return P("model")
        if "ro_w1" in s:
            return P("model", None)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


# -- RecSys ------------------------------------------------------------------


def recsys_param_specs(params_shape: Any) -> Any:
    def spec_for(path, leaf):
        s = _path_str(path)
        if s in ("table",) or s.endswith("/table") or "wide" in s or "linear" in s:
            return P("model", None)  # row-sharded embedding tables
        if "deep/0/w" in s or "dnn/0/w" in s:
            return P(None, "model")
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def param_specs(family: str, params_shape: Any) -> Any:
    return {
        "lm": lm_param_specs,
        "gnn": gnn_param_specs,
        "recsys": recsys_param_specs,
    }[family](params_shape)


def opt_state_specs(param_spec: Any) -> AdamWState:
    """AdamW moments inherit parameter sharding; step is replicated."""
    return AdamWState(step=P(), mu=param_spec, nu=param_spec)


# -- input shardings per cell ---------------------------------------------------


def lm_input_shardings(cell_kind: str, shape: str, multi_pod: bool, cfg) -> dict:
    dp = data_axes(multi_pod)
    if cell_kind == "train":
        return {"batch": {"tokens": P(dp, None)}}
    if cell_kind == "prefill":
        return {"tokens": P(dp, None)}
    if cell_kind == "decode":
        if shape == "long_500k":
            # batch=1: sequence-parallel cache over the entire mesh
            seq_axes = ("pod", "data", "model") if multi_pod else ("data", "model")
            cache_spec = P(None, None, seq_axes, None, None)
            token_spec = P(None, None)
        else:
            cache_spec = P(None, dp, "model", None, None)
            token_spec = P(dp, None)
        return {
            "cache": cache_spec,  # broadcast to every cache leaf by caller
            "token": token_spec,
            "cache_len": P(),
        }
    raise ValueError(cell_kind)


def gnn_input_shardings(multi_pod: bool) -> dict:
    dp = data_axes(multi_pod)
    return {
        "batch": {
            "positions": P(),
            "node_feat": P(),
            "senders": P(dp),
            "receivers": P(dp),
            "edge_mask": P(dp),
            "node_mask": P(),
            "node_graph": P(),
            "target_energy": P(),
            "target_nodes": P(),
            "loss_node_mask": P(),
        }
    }


def recsys_input_shardings(cell_kind: str, multi_pod: bool) -> dict:
    dp = data_axes(multi_pod)
    out = {"batch": {"sparse": P(dp, None), "dense": P(dp, None),
                     "labels": P(dp)}}
    if cell_kind == "retrieval":
        # candidates row-sharded over the full mesh
        rows = ("pod", "data", "model") if multi_pod else ("data", "model")
        out["candidates"] = P(rows, None)
        out["batch"] = {"sparse": P(None, None), "dense": P(None, None),
                        "labels": P(None)}
    return out
