from .adamw import AdamW, apply_updates, clip_by_global_norm
from .schedules import constant, cosine_decay, linear_warmup_cosine
from .compression import CompressionState, compress_decompress, error_feedback_update

__all__ = [
    "AdamW",
    "apply_updates",
    "clip_by_global_norm",
    "constant",
    "cosine_decay",
    "linear_warmup_cosine",
    "CompressionState",
    "compress_decompress",
    "error_feedback_update",
]
