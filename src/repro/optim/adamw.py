"""AdamW with decoupled weight decay, f32 moments, global-norm clipping.

Pure-pytree implementation (no optax dependency). Moments are stored in f32
regardless of parameter dtype — the convention for bf16 training at scale;
under pjit the moments inherit the parameter sharding, so optimizer state is
sharded exactly like FSDP expects.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array           # scalar int32
    mu: Any               # first moments (f32 pytree)
    nu: Any               # second moments (f32 pytree)


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[Array], Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0

    def init(self, params: Any) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def _lr(self, step: Array) -> Array:
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(
        self, grads: Any, state: AdamWState, params: Any
    ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(lambda p, u: p + u, params, updates)


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn
