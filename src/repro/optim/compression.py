"""Gradient compression with error feedback for the data-parallel all-reduce.

int8 uniform quantisation per tensor with an f32 scale; the quantisation
residual is carried in an error-feedback buffer (Seide et al. / EF-SGD), so
the compressed all-reduce is unbiased over time. Used by the explicit
shard_map training path (launch/train.py --compress-grads): gradients are
quantised *before* the cross-data-shard psum, cutting DP collective bytes 4x
(f32->int8+scale), which is exactly the collective-roofline term the dry-run
tracks for train shapes.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class CompressionState(NamedTuple):
    error: Any  # f32 pytree, same structure as grads


def init_state(grads_like: Any) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def _quantize(x: Array) -> Tuple[Array, Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(x: Array) -> Tuple[Array, Array]:
    """Round-trip a tensor through int8; returns (reconstruction, residual)."""
    q, s = _quantize(x.astype(jnp.float32))
    rec = _dequantize(q, s)
    return rec, x.astype(jnp.float32) - rec


def error_feedback_update(
    grads: Any, state: CompressionState, axis_name: str | None = None
) -> Tuple[Any, CompressionState]:
    """EF-compressed gradient exchange.

    g_corrected = g + error;  q = Q(g_corrected);  error' = g_corrected - q;
    exchanged = psum(q) / n   (inside shard_map when axis_name given).
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        rec, resid = compress_decompress(corrected)
        if axis_name is not None:
            rec = jax.lax.pmean(rec, axis_name)
        return rec.astype(g.dtype), resid

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, CompressionState(error=new_e)
