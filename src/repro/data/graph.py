"""Graph data utilities: CSR neighbour sampling (GraphSAGE-style fanout) and
static-shape padding for jit.

The ``minibatch_lg`` shape requires a REAL neighbour sampler: given a batch of
root nodes, sample ``fanout[0]`` 1-hop neighbours per root and ``fanout[1]``
2-hop neighbours per 1-hop node from a CSR adjacency, deduplicate into a
subgraph with relabelled node ids, and pad to static (n_nodes, n_edges) for
the compiled step. Sampling is host-side numpy (data pipeline), as in every
production GNN stack; the device step sees only dense padded arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (nnz,)
    num_nodes: int

    @staticmethod
    def from_edges(senders: np.ndarray, receivers: np.ndarray, num_nodes: int
                   ) -> "CSRGraph":
        order = np.argsort(senders, kind="stable")
        s, r = senders[order], receivers[order]
        counts = np.bincount(s, minlength=num_nodes)
        indptr = np.zeros(num_nodes + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr=indptr, indices=r.astype(np.int64),
                        num_nodes=num_nodes)

    def neighbors(self, node: int) -> np.ndarray:
        return self.indices[self.indptr[node]: self.indptr[node + 1]]


def random_graph(num_nodes: int, avg_degree: int, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    nnz = num_nodes * avg_degree
    senders = rng.integers(0, num_nodes, nnz)
    receivers = rng.integers(0, num_nodes, nnz)
    return CSRGraph.from_edges(senders, receivers, num_nodes)


def sample_neighborhood(
    graph: CSRGraph,
    roots: np.ndarray,
    fanout: Sequence[int],
    rng: np.random.Generator,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Layer-wise fanout sampling.

    Returns (nodes, senders, receivers): global node ids of the subgraph and
    its edge list in *global* ids (relabelling happens in ``pad_subgraph``).
    Edges point sampled-neighbour -> frontier node (message direction).
    """
    nodes = [np.unique(roots)]
    senders, receivers = [], []
    frontier = nodes[0]
    for k in fanout:
        new_src = []
        for v in frontier:
            nbrs = graph.neighbors(v)
            if nbrs.size == 0:
                continue
            take = rng.choice(nbrs, size=min(k, nbrs.size), replace=False)
            new_src.append(np.stack([take, np.full(take.size, v)], axis=0))
        if not new_src:
            break
        e = np.concatenate(new_src, axis=1)
        senders.append(e[0])
        receivers.append(e[1])
        frontier = np.unique(e[0])
        nodes.append(frontier)
    all_nodes = np.unique(np.concatenate(nodes))
    if senders:
        s = np.concatenate(senders)
        r = np.concatenate(receivers)
    else:
        s = np.zeros(0, np.int64)
        r = np.zeros(0, np.int64)
    return all_nodes, s, r


def pad_subgraph(
    nodes: np.ndarray,
    senders: np.ndarray,
    receivers: np.ndarray,
    roots: np.ndarray,
    *,
    max_nodes: int,
    max_edges: int,
) -> dict:
    """Relabel to local ids and pad to static shapes (jit-stable)."""
    nodes = nodes[:max_nodes]
    lut = {int(g): i for i, g in enumerate(nodes)}
    keep = np.array(
        [int(s) in lut and int(r) in lut for s, r in zip(senders, receivers)],
        bool,
    ) if senders.size else np.zeros(0, bool)
    s = np.array([lut[int(x)] for x in senders[keep]], np.int32)[:max_edges]
    r = np.array([lut[int(x)] for x in receivers[keep]], np.int32)[:max_edges]
    n, e = nodes.shape[0], s.shape[0]
    out = {
        "local_nodes": nodes.astype(np.int64),
        "senders": np.pad(s, (0, max_edges - e)).astype(np.int32),
        "receivers": np.pad(r, (0, max_edges - e)).astype(np.int32),
        "edge_mask": np.pad(np.ones(e, np.float32), (0, max_edges - e)),
        "node_mask": np.pad(np.ones(n, np.float32), (0, max_nodes - n)),
        "root_mask": np.zeros(max_nodes, np.float32),
    }
    for g in roots:
        if int(g) in lut:
            out["root_mask"][lut[int(g)]] = 1.0
    return out


def sample_padded_batch(
    graph: CSRGraph,
    batch_nodes: int,
    fanout: Sequence[int],
    *,
    max_nodes: int,
    max_edges: int,
    seed: int = 0,
) -> dict:
    rng = np.random.default_rng(seed)
    roots = rng.choice(graph.num_nodes, size=batch_nodes, replace=False)
    nodes, s, r = sample_neighborhood(graph, roots, fanout, rng)
    return pad_subgraph(nodes, s, r, roots, max_nodes=max_nodes,
                        max_edges=max_edges)
