"""Deterministic, restart-exact input pipeline with background prefetch.

* batches are a pure function of (seed, step) — after a crash/elastic restart
  the trainer resumes at step k and receives byte-identical batches (the
  checkpoint only needs to store the step number, not pipeline state);
* a daemon thread keeps ``prefetch`` batches ahead of the consumer so host
  batch synthesis overlaps device compute (straggler decoupling);
* ``shard_for_host`` slices the global batch to this host's data-parallel
  rows for multi-controller deployments (here: host 0 of 1).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax


class PrefetchPipeline:
    def __init__(
        self,
        make_batch: Callable[[int], dict],   # step -> batch pytree
        *,
        start_step: int = 0,
        prefetch: int = 2,
    ):
        self.make_batch = make_batch
        self.step = start_step
        self.prefetch = prefetch
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self.make_batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def shard_for_host(
    batch: dict,
    *,
    host_index: int = 0,
    num_hosts: int = 1,
    batch_axis: int = 0,
) -> dict:
    """Slice the global batch to this host's rows (multi-controller input)."""
    if num_hosts == 1:
        return batch

    def slice_leaf(x):
        n = x.shape[batch_axis]
        per = n // num_hosts
        start = host_index * per
        idx = [slice(None)] * x.ndim
        idx[batch_axis] = slice(start, start + per)
        return x[tuple(idx)]

    return jax.tree.map(slice_leaf, batch)
