from . import graph, pipeline, synthetic

__all__ = ["synthetic", "pipeline", "graph"]
