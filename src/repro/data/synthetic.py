"""Synthetic data generators.

Two roles:
1. the paper's experimental spaces (§5.2/Appendix D): uniform/Gaussian
   Euclidean, low-rank manifold ("GloVe-like"), RELU'd CNN-feature-like
   (cosine), and l1-normalised probability spaces (Jensen-Shannon);
2. model-family batches for the assigned architectures (LM token streams,
   recsys click logs, geometric graphs) — deterministic in (seed, step) so a
   restarted trainer reproduces the exact batch sequence (fault tolerance).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

Array = jax.Array


# -- paper spaces ---------------------------------------------------------------


def uniform_space(key: jax.Array, n: int, dim: int) -> Array:
    return jax.random.uniform(key, (n, dim))


def gaussian_space(key: jax.Array, n: int, dim: int) -> Array:
    return jax.random.normal(key, (n, dim))


def manifold_space(
    key: jax.Array, n: int, dim: int, intrinsic: int, noise: float = 0.01
) -> Array:
    """Data on an ``intrinsic``-dimensional nonlinear manifold embedded in
    R^dim — the GloVe/CNN-feature stand-in (real-world spaces lie on complex
    manifolds; paper §5.4)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    z = jax.random.normal(k1, (n, intrinsic))
    w1 = jax.random.normal(k2, (intrinsic, 2 * intrinsic)) / np.sqrt(intrinsic)
    w2 = jax.random.normal(k3, (2 * intrinsic, dim)) / np.sqrt(2 * intrinsic)
    x = jnp.tanh(z @ w1) @ w2
    return x + noise * jax.random.normal(k4, (n, dim))


def relu_feature_space(key: jax.Array, n: int, dim: int, intrinsic: int) -> Array:
    """Non-negative CNN-activation-like data (cosine-metric experiments)."""
    x = manifold_space(key, n, dim, intrinsic)
    return jax.nn.relu(x)


def probability_space(
    key: jax.Array, n: int, dim: int, intrinsic: Optional[int] = None
) -> Array:
    """l1-normalised positive vectors (Jensen-Shannon domain, paper §5.6)."""
    if intrinsic is None:
        x = jax.random.uniform(key, (n, dim))
    else:
        x = jax.nn.softplus(manifold_space(key, n, dim, intrinsic))
    s = jnp.sum(x, axis=1, keepdims=True)
    return x / jnp.maximum(s, 1e-12)


# -- model-family batches ---------------------------------------------------------


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return {"tokens": jax.random.randint(key, (batch, seq), 0, vocab, jnp.int32)}


def lm_markov_batch(
    seed: int, step: int, batch: int, seq: int, vocab: int,
    concentration: float = 1.0,
) -> dict:
    """First-order Markov token streams (structured LM data).

    ``lm_batch`` draws i.i.d. uniform tokens — no learnable structure, so a
    trained LM collapses every next-token distribution toward the same
    unigram and the induced Jensen-Shannon space degenerates to a point
    cloud of near-duplicates. Here tokens follow a fixed (per ``seed``)
    peaked transition matrix: the model can learn genuine bigram structure,
    and its next-token distributions then *depend on context* — a
    probability-simplex corpus with real neighbourhood geometry for the
    paper's §5.6 JSD experiments. Deterministic in (seed, step).
    """
    kA, kB = jax.random.split(jax.random.PRNGKey(seed))
    # low-rank transition logits: conditional distributions live on a smooth
    # low-dimensional family inside the simplex (neither uniform noise nor
    # one-hot corners), so the learned JSD space has manifold structure
    rank = max(4, min(16, vocab // 32))
    A = jax.random.normal(kA, (vocab, rank))
    B = jax.random.normal(kB, (rank, vocab))
    logits = (A @ B) / (np.sqrt(rank) * concentration)
    kstep = jax.random.fold_in(jax.random.PRNGKey(seed + 7919), step)
    k0, kscan = jax.random.split(kstep)
    t0 = jax.random.randint(k0, (batch,), 0, vocab)

    def body(tok, k):
        nxt = jax.random.categorical(k, logits[tok], axis=-1)
        return nxt, nxt

    _, rest = jax.lax.scan(body, t0, jax.random.split(kscan, seq - 1))
    toks = jnp.concatenate([t0[:, None], rest.T], axis=1)
    return {"tokens": toks.astype(jnp.int32)}


def recsys_batch(
    seed: int, step: int, batch: int, vocab_sizes, n_dense: int = 0
) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    ks = jax.random.split(key, 3)
    maxes = jnp.asarray(vocab_sizes, jnp.int32)
    u = jax.random.uniform(ks[0], (batch, len(vocab_sizes)))
    # zipf-ish skew: hot rows are hit much more often (realistic table traffic)
    sparse = jnp.minimum(
        (u**3 * maxes[None, :]).astype(jnp.int32), maxes[None, :] - 1
    )
    out = {
        "sparse": sparse,
        "labels": jax.random.bernoulli(ks[1], 0.25, (batch,)).astype(jnp.float32),
    }
    if n_dense:
        out["dense"] = jax.random.normal(ks[2], (batch, n_dense), jnp.float32)
    return out


def two_tower_batch(
    seed: int, step: int, batch: int, vocab_sizes, n_items: int
) -> dict:
    """Criteo-shaped sparse user features + a co-clicked positive item id.

    The positive item is a deterministic hash of the user's full sparse
    pattern, so the (user pattern -> item) mapping is consistent
    across steps — learnable structure for the in-batch-softmax two-tower
    loss — while the zipf skew of ``recsys_batch`` keeps table traffic
    realistic. Deterministic in (seed, step) like every batch maker here.
    """
    base = recsys_batch(seed, step, batch, vocab_sizes)
    sparse = base["sparse"]
    # hash the three leading fields (distinct odd multipliers): repeated
    # patterns stay frequent enough under the zipf skew to be learnable,
    # while the learned item structure still spans a multi-field cross
    # rather than a rank-2 slice
    n_hash = min(3, sparse.shape[1])
    mult = (131 + 62 * jnp.arange(n_hash, dtype=jnp.int32))[None, :]
    items = jnp.sum(sparse[:, :n_hash] * mult, axis=1) % n_items
    return {"sparse": sparse, "items": items.astype(jnp.int32)}


def geometric_graph_batch(
    seed: int,
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    n_graphs: int = 1,
    node_level: bool = False,
    box: float = 8.0,
) -> dict:
    """Random geometric graph(s) with synthetic 3D positions (DESIGN.md: the
    assigned citation/product graphs carry no coordinates; positions are
    synthesised so MACE's geometric model is exercised at published scales)."""
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, box, size=(n_nodes, 3)).astype(np.float32)
    send = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    # bias edges toward spatial neighbours: jitter around sender positions
    recv = (send + rng.integers(1, max(n_nodes // 64, 2), n_edges)) % n_nodes
    recv = recv.astype(np.int32)
    node_graph = np.sort(rng.integers(0, n_graphs, n_nodes)).astype(np.int32)
    batch = {
        "positions": jnp.asarray(pos),
        "node_feat": jnp.asarray(
            rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
        ),
        "senders": jnp.asarray(send),
        "receivers": jnp.asarray(recv),
        "edge_mask": jnp.ones((n_edges,), jnp.float32),
        "node_mask": jnp.ones((n_nodes,), jnp.float32),
        "node_graph": jnp.asarray(node_graph),
    }
    if node_level:
        batch["target_nodes"] = jnp.asarray(
            rng.normal(size=(n_nodes,)).astype(np.float32))
        batch["loss_node_mask"] = jnp.ones((n_nodes,), jnp.float32)
    else:
        batch["target_energy"] = jnp.asarray(
            rng.normal(size=(n_graphs,)).astype(np.float32))
    return batch
