"""Versioned, atomic on-disk state for serving indexes.

``checkpoint.CheckpointManager`` handles *training* state (step-numbered,
async, elastic pytree restore). Serving indexes have different needs: a
single current snapshot, explicit format versioning (an index written by one
release must either load bit-exactly or be rejected loudly by another), and
no dependence on jax treedef serialisation. This module is that store:

  save_state(dir, arrays, meta, kind=...)   -> atomic versioned snapshot
  load_state(dir, expect_kind=...)          -> (arrays, meta) or raise

Layout: one ``.npy`` per array plus ``manifest.json`` holding
``{format, version, kind, meta, arrays}``. The write goes to a ``tmp.``
sibling directory, every file is fsync'd, and the directory is
``os.rename``'d into place (same discipline as
``CheckpointManager._write``). When overwriting, the previous snapshot is
first renamed aside to an ``old.`` sibling and only removed after the new
one is published — a crash at any point leaves either the old or the new
snapshot loadable (a leftover ``old.<name>`` directory means the crash hit
the narrow window between the two renames; rename it back to recover).

Consumers (``index.ivf.IVFZenIndex.save``, ``launch.serve.ZenServer.save``)
serialise to *canonical host arrays* — live members only, global ids, no
device layout — so a snapshot saved from S shards loads onto any other
device count (resharding happens at load, not at save).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

# bf16 numpy dtype (None when ml_dtypes is unavailable) — one resolution,
# shared with the storage subsystem, so the dtype the encoder writes is by
# construction the one this store decodes
from repro.kernels.quantize import BFLOAT16 as _BF16

#: on-disk format name; never reuse for a different layout
INDEX_FORMAT = "zen-index"
#: bump on any incompatible change to the manifest or array contract.
#: v2: quantised index storage — member coords may be int8 (with
#: ``cluster_scales``/``coord_scales`` arrays and a ``storage`` meta key)
#: or bf16 (stored as a uint16 view); a v1 reader would misinterpret the
#: raw quantised values as coordinates, so v2 snapshots must be rejected
#: by it loudly, which the version bump guarantees.
#: v3: product-quantised storage — under ``storage == "pq"`` the member
#: coords array holds (n, M) uint8 *codes* (residuals against the
#: assigned centroid) with their (M, 256, ds) f32 ``pq_codebooks`` array;
#: a v2 reader knows "pq" as no storage dtype and would fail confusingly
#: mid-load, so v3 rejects it at the door instead
INDEX_FORMAT_VERSION = 3
#: versions this build can still load; v1/v2 snapshots are strict subsets
#: of v3 (no "pq" storage — loaders default missing meta to "float32")
READABLE_VERSIONS = (1, 2, 3)


class CheckpointFormatError(ValueError):
    """Raised when a snapshot's format/version/kind does not match."""


def _fsync_dir(path: str) -> None:
    """fsync a directory so the rename that published into it is durable.

    File-content fsyncs alone leave the *directory entry* unjournalled: a
    power cut after ``os.rename`` could resurrect the old name. Best-effort
    on platforms whose directories cannot be opened (e.g. Windows).
    """
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform without dir fds
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_json_atomic(path: str, payload: Mapping[str, Any]) -> str:
    """Durably replace a small JSON file (tmp + fsync + rename + dir fsync).

    The publish-pointer primitive of the replicated serving tier
    (``launch.replicate``): readers see either the previous pointer or the
    new one, never a torn write — the same discipline as
    :func:`save_state`, applied to a single file.
    """
    path = os.path.abspath(path)
    tmp = os.path.join(os.path.dirname(path),
                       f"tmp.{os.path.basename(path)}")
    with open(tmp, "w") as f:
        json.dump(dict(payload), f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)  # atomic on POSIX
    _fsync_dir(os.path.dirname(path))
    return path


def save_state(
    directory: str,
    arrays: Mapping[str, np.ndarray],
    meta: Mapping[str, Any],
    *,
    kind: str,
) -> str:
    """Atomically write a versioned snapshot.

    Args:
      directory: target snapshot directory (created/replaced as a whole).
      arrays:    name -> host array; each is stored as ``<name>.npy``. Names
                 must be filesystem-safe (``[A-Za-z0-9_.-]``).
      meta:      JSON-serialisable metadata (ints, strings, lists...).
      kind:      consumer tag (e.g. ``"ivf-index"``, ``"zen-server"``)
                 checked again at load time.

    Returns the final snapshot directory path.
    """
    directory = os.path.abspath(directory)
    parent = os.path.dirname(directory)
    os.makedirs(parent, exist_ok=True)
    tmp = os.path.join(parent, f"tmp.{os.path.basename(directory)}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: Dict[str, Any] = {
        "format": INDEX_FORMAT,
        "version": INDEX_FORMAT_VERSION,
        "kind": kind,
        "meta": dict(meta),
        "arrays": {},
    }
    for name, arr in arrays.items():
        if not all(c.isalnum() or c in "_.-" for c in name):
            raise ValueError(f"unsafe array name {name!r}")
        arr = np.asarray(arr)
        dtype_name = str(arr.dtype)
        if _BF16 is not None and arr.dtype == _BF16:
            # .npy has no bf16 dtype tag (it round-trips as a raw 2-byte
            # void): store the bits as uint16 and view them back at load,
            # keyed off the manifest's dtype entry
            arr = arr.view(np.uint16)
        fname = f"{name}.npy"
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["arrays"][name] = {
            "file": fname, "dtype": dtype_name, "shape": list(arr.shape),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    # publish: move the old snapshot aside (not rmtree) so a crash between
    # the renames still leaves one loadable snapshot on disk
    old = os.path.join(parent, f"old.{os.path.basename(directory)}")
    if os.path.exists(directory):
        if os.path.exists(old):
            shutil.rmtree(old)
        os.rename(directory, old)
    os.rename(tmp, directory)  # atomic publish
    _fsync_dir(parent)  # make the rename itself durable, not just the files
    shutil.rmtree(old, ignore_errors=True)
    return directory


def load_state(
    directory: str,
    *,
    expect_kind: Optional[str] = None,
    mmap: bool = False,
) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Load a snapshot written by :func:`save_state`.

    Args:
      directory:   snapshot directory.
      expect_kind: when given, the manifest's ``kind`` must match.
      mmap:        memory-map the ``.npy`` files (read-only) instead of
                   materialising them — the tiered tile store serves a
                   host pool far larger than RAM straight off the snapshot
                   (``index.ivf.TieredIVFZenIndex.load``); fancy-indexed
                   block reads touch only the probed pages. bf16 arrays
                   come back as a (zero-copy) view of the mapped uint16
                   bits.

    Returns ``(arrays, meta)`` with host numpy arrays.

    Raises:
      FileNotFoundError:     no manifest at ``directory``.
      CheckpointFormatError: wrong format name, wrong (newer/older
                             incompatible) version, kind mismatch, or an
                             array whose dtype/shape disagrees with its
                             manifest entry (truncated/corrupt file).
    """
    path = os.path.join(directory, "manifest.json")
    if not os.path.exists(path):
        raise FileNotFoundError(f"no index snapshot at {directory}")
    with open(path) as f:
        manifest = json.load(f)
    if manifest.get("format") != INDEX_FORMAT:
        raise CheckpointFormatError(
            f"{directory}: format {manifest.get('format')!r}, "
            f"expected {INDEX_FORMAT!r}"
        )
    if manifest.get("version") not in READABLE_VERSIONS:
        raise CheckpointFormatError(
            f"{directory}: format version {manifest.get('version')!r} not "
            f"readable by this build (reads {READABLE_VERSIONS})"
        )
    if expect_kind is not None and manifest.get("kind") != expect_kind:
        raise CheckpointFormatError(
            f"{directory}: snapshot kind {manifest.get('kind')!r}, "
            f"expected {expect_kind!r}"
        )
    arrays: Dict[str, np.ndarray] = {}
    for name, entry in manifest["arrays"].items():
        arr = np.load(os.path.join(directory, entry["file"]),
                      mmap_mode="r" if mmap else None)
        if entry["dtype"] == "bfloat16":
            if _BF16 is None:  # pragma: no cover - ml_dtypes ships with jax
                raise CheckpointFormatError(
                    f"{directory}: array {name!r} is bfloat16 but ml_dtypes "
                    "is not available to decode it")
            if str(arr.dtype) != "uint16":
                raise CheckpointFormatError(
                    f"{directory}: array {name!r} is {arr.dtype}{arr.shape}, "
                    f"expected the uint16 bit-pattern of a bfloat16 array")
            arr = arr.view(_BF16)
        if (str(arr.dtype) != entry["dtype"]
                or list(arr.shape) != entry["shape"]):
            raise CheckpointFormatError(
                f"{directory}: array {name!r} is {arr.dtype}{arr.shape}, "
                f"manifest says {entry['dtype']}{tuple(entry['shape'])}"
            )
        arrays[name] = arr
    return arrays, manifest["meta"]
