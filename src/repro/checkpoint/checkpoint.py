"""Fault-tolerant checkpointing: atomic, async, elastic-resharding restore.

Design (DESIGN.md §6):
* **atomic** — a step directory is written under ``<dir>/tmp.step_N`` and
  os.rename'd to ``step_N`` only after every leaf and the manifest have been
  fsync'd; a crash mid-save never corrupts the latest checkpoint;
* **async** — ``save_async`` snapshots the host copies (device->host transfer
  happens synchronously, which is the only part that must block the step) and
  writes in a background thread; ``wait()`` joins before the next save;
* **elastic resharding** — arrays are stored UNSHARDED (gathered) with their
  PartitionSpec recorded in the manifest; ``restore`` device_puts each leaf
  with the *current* mesh's NamedSharding, so a job restarted on a different
  data-axis size (scale up/down, dead pod) resumes bit-exactly;
* retention — keeps the newest ``keep`` checkpoints, deletes older ones.

Storage is one ``.npy`` per leaf + a JSON manifest (treedef, dtypes, specs,
step). No external dependencies.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


_NATIVE_DTYPES = {
    "bool", "int8", "int16", "int32", "int64", "uint8", "uint16", "uint32",
    "uint64", "float16", "float32", "float64", "complex64", "complex128",
}


def _leaf_paths(tree) -> list[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "__".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name or "leaf", leaf))
    return out


def _spec_to_json(spec) -> list:
    if spec is None:
        return []
    return [list(ax) if isinstance(ax, tuple) else ax for ax in spec]


def _spec_from_json(obj) -> P:
    return P(*[tuple(ax) if isinstance(ax, list) else ax for ax in obj])


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -------------------------------------------------------------

    def save(self, step: int, tree: Any, specs: Any = None) -> str:
        """Synchronous atomic save. ``specs``: matching pytree of
        PartitionSpecs (or None for replicated)."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host, tree, specs)

    def save_async(self, step: int, tree: Any, specs: Any = None) -> None:
        """Device->host transfer now; disk write in a background thread."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, tree, specs), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, tree: Any, specs: Any) -> str:
        tmp = os.path.join(self.directory, f"tmp.step_{step:010d}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _leaf_paths(host_tree)
        spec_leaves = (
            [s for _, s in _leaf_paths(specs)] if specs is not None
            else [None] * len(leaves)
        )
        manifest = {"step": step, "leaves": []}
        for (name, arr), spec in zip(leaves, spec_leaves):
            arr = np.asarray(arr)
            fname = f"{name}.npy"
            stored_dtype = str(arr.dtype)
            to_save = arr
            if stored_dtype not in _NATIVE_DTYPES:  # bf16/f8: store raw bits
                to_save = arr.view(f"u{arr.dtype.itemsize}")
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, to_save)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append({
                "name": name,
                "file": fname,
                "dtype": stored_dtype,
                "shape": list(arr.shape),
                "spec": _spec_to_json(spec),
            })
        # treedef for structural restore — best-effort: proto serialization
        # rejects custom nodes (e.g. optimizer NamedTuples); restore(like=...)
        # does not need it
        try:
            manifest["treedef"] = (
                jax.tree_util.tree_structure(host_tree)
                .serialize_using_proto().hex()
            )
        except ValueError:
            manifest["treedef"] = None
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: Optional[int] = None,
        *,
        mesh=None,
        like: Any = None,
    ) -> Tuple[int, Any]:
        """Restore the given (or latest) step.

        With ``mesh``: each leaf is device_put with NamedSharding(mesh, spec)
        from the manifest — elastic resharding onto the current topology.
        With ``like``: the result is unflattened into like's treedef (dtype
        cast to like's leaves), otherwise the stored treedef is used.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = []
        for leaf in manifest["leaves"]:
            arr = np.load(os.path.join(d, leaf["file"]))
            if leaf["dtype"] not in _NATIVE_DTYPES:  # restore bf16/f8 bit view
                import ml_dtypes

                arr = arr.view(np.dtype(getattr(ml_dtypes, leaf["dtype"])))
            if mesh is not None:
                sharding = NamedSharding(mesh, _spec_from_json(leaf["spec"]))
                arr = jax.device_put(arr, sharding)
            arrays.append(arr)
        treedef = jax.tree_util.tree_structure(like) if like is not None else \
            jax.tree_util.tree_structure_from_proto_bytes(bytes.fromhex(manifest["treedef"])) \
            if hasattr(jax.tree_util, "tree_structure_from_proto_bytes") else None
        if treedef is None:
            raise RuntimeError("restore requires `like` on this jax version")
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if like is not None:
            tree = jax.tree.map(
                lambda x, l: x.astype(l.dtype) if hasattr(l, "dtype") else x,
                tree, like,
            )
        return step, tree
