from .checkpoint import CheckpointManager
from .index_io import (
    INDEX_FORMAT,
    INDEX_FORMAT_VERSION,
    CheckpointFormatError,
    load_state,
    save_state,
)

__all__ = [
    "CheckpointManager",
    "CheckpointFormatError",
    "INDEX_FORMAT",
    "INDEX_FORMAT_VERSION",
    "load_state",
    "save_state",
]
