"""Step builders: assemble (function, abstract args, shardings) per
(architecture x shape cell) — shared by the dry-run, the trainer and serving.

``build_plan(arch_id, shape, multi_pod=...)`` returns a LoweringPlan whose
``lower(mesh)`` produces the jit-lowered computation with every input bound to
a ShapeDtypeStruct (no device allocation) and production shardings attached.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as configs_lib
from repro.distributed import sharding as shard_lib
from repro.models import mace as mace_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.optim import AdamW

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class LoweringPlan:
    arch_id: str
    shape: str
    kind: str
    fn: Callable
    args: Tuple[Any, ...]           # pytrees of ShapeDtypeStruct
    in_specs: Tuple[Any, ...]       # matching pytrees of PartitionSpec
    out_specs: Any                  # pytree of PartitionSpec or None (auto)
    cfg: Any = None
    skip: Optional[str] = None

    def lower(self, mesh):
        to_sharding = lambda spec: NamedSharding(mesh, spec)
        in_sh = jax.tree.map(
            to_sharding, self.in_specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        out_sh = None
        if self.out_specs is not None:
            out_sh = jax.tree.map(
                to_sharding, self.out_specs,
                is_leaf=lambda x: isinstance(x, P),
            )
        # ambient mesh context: with_sharding_constraint inside the model code
        # takes bare PartitionSpecs and resolves them against this mesh.
        with mesh:
            jitted = jax.jit(self.fn, in_shardings=in_sh, out_shardings=out_sh)
            return jitted.lower(*self.args)


def _abstract(tree):
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def _broadcast_spec(tree, spec):
    return jax.tree.map(lambda _: spec, tree)


def make_optimizer() -> AdamW:
    return AdamW(learning_rate=3e-4, weight_decay=0.01, clip_norm=1.0)


# -- LM -------------------------------------------------------------------------


def _lm_plan(spec, cfg, cell, multi_pod: bool) -> LoweringPlan:
    dp = shard_lib.data_axes(multi_pod)
    pspecs = None
    params_shape = jax.eval_shape(partial(tfm.init_params, cfg), jax.random.PRNGKey(0))
    pspecs = shard_lib.lm_param_specs(params_shape)
    ins = configs_lib.input_specs(spec, cfg, cell)
    in_shard = shard_lib.lm_input_shardings(cell.kind, cell.shape, multi_pod, cfg)

    act = tfm.ActShard(
        tokens=P(dp, None),
        hidden=P(dp, None, None),
        logits=P(dp, None, "model") if cell.kind == "train" else P(dp, "model"),
    )
    if cell.shape == "long_500k":
        act = tfm.ActShard(tokens=None, hidden=None, logits=P(None, "model"))

    if cell.kind == "train":
        opt = make_optimizer()
        opt_shape = jax.eval_shape(opt.init, params_shape)
        ospecs = shard_lib.opt_state_specs(pspecs)
        nm = cfg.n_microbatches

        def train_step(params, opt_state, batch):
            if nm == 1:
                (loss, aux), grads = jax.value_and_grad(
                    lambda p: tfm.loss_fn(cfg, p, batch, shard=act),
                    has_aux=True,
                )(params)
            else:
                # gradient accumulation: activation memory / nm
                tokens = batch["tokens"]
                B = tokens.shape[0]
                mb = tokens.reshape(nm, B // nm, tokens.shape[1])
                mb = jax.lax.with_sharding_constraint(mb, P(None, dp, None))

                def acc_body(carry, mb_tokens):
                    gsum, lsum = carry
                    (loss, _), grads = jax.value_and_grad(
                        lambda p: tfm.loss_fn(
                            cfg, p, {"tokens": mb_tokens}, shard=act),
                        has_aux=True,
                    )(params)
                    gsum = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                    return (gsum, lsum + loss), None

                gzero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), _ = jax.lax.scan(
                    acc_body, (gzero, jnp.float32(0.0)), mb)
                grads = jax.tree.map(
                    lambda g, p: (g / nm).astype(p.dtype), gsum, params)
                loss = lsum / nm
                aux = {"loss": loss}
            updates, opt_state = opt.update(grads, opt_state, params)
            params = jax.tree.map(lambda p, u: p + u, params, updates)
            return params, opt_state, aux

        return LoweringPlan(
            spec.arch_id, cell.shape, cell.kind, train_step,
            args=(params_shape, opt_shape, ins["batch"]),
            in_specs=(pspecs, ospecs, in_shard["batch"]),
            out_specs=(pspecs, ospecs, P()),
            cfg=cfg, skip=cell.skip,
        )

    if cell.kind == "prefill":
        def prefill_step(params, tokens):
            return tfm.prefill(cfg, params, tokens, shard=act)

        cache_shape = jax.eval_shape(
            lambda: tfm.init_kv_cache(cfg, cell.dims["global_batch"],
                                      cell.dims["seq_len"]))
        cache_spec = _broadcast_spec(cache_shape, P(None, dp, "model", None, None))
        return LoweringPlan(
            spec.arch_id, cell.shape, cell.kind, prefill_step,
            args=(params_shape, ins["tokens"]),
            in_specs=(pspecs, in_shard["tokens"]),
            out_specs=((P(dp, "model")), cache_spec),
            cfg=cfg, skip=cell.skip,
        )

    if cell.kind == "decode":
        def decode_step(params, cache, token, cache_len):
            return tfm.decode_step(cfg, params, cache, token, cache_len, shard=act)

        cache_spec = _broadcast_spec(ins["cache"], in_shard["cache"])
        logits_spec = act.logits
        return LoweringPlan(
            spec.arch_id, cell.shape, cell.kind, decode_step,
            args=(params_shape, ins["cache"], ins["token"], ins["cache_len"]),
            in_specs=(pspecs, cache_spec, in_shard["token"], in_shard["cache_len"]),
            out_specs=(logits_spec, cache_spec),
            cfg=cfg, skip=cell.skip,
        )

    raise ValueError(cell.kind)


# -- GNN -------------------------------------------------------------------------


def _gnn_plan(spec, cfg, cell, multi_pod: bool) -> LoweringPlan:
    import dataclasses as dc

    from repro.configs import mace as mace_cfg_mod

    dp = shard_lib.data_axes(multi_pod)
    # bind the per-shape raw feature width (both full and reduced configs)
    cfg = mace_cfg_mod.for_shape(cfg, cell.dims["d_feat"])
    if (cell.dims["n_edges"] > 8_000_000 and cfg.edge_chunks == 1
            and not multi_pod):
        # full-batch giant graphs: edge-chunked A-basis accumulation (§Perf).
        # Disabled on the 3-axis mesh: XLA SPMD mis-partitions the channel-
        # sharded gather inside the chunk scan there ("Slice dim size 128
        # greater than dynamic slice dimension: 8" hlo-verifier failure);
        # the unchunked lowering compiles on both meshes.
        cfg = dataclasses.replace(cfg, edge_chunks=16)
    params_shape = jax.eval_shape(
        partial(mace_lib.init_params, cfg), jax.random.PRNGKey(0)
    )
    pspecs = shard_lib.gnn_param_specs(params_shape)
    ins = configs_lib.input_specs(spec, cfg, cell)
    static = ins["static"]
    in_shard_all = shard_lib.gnn_input_shardings(multi_pod)["batch"]
    in_shard = {k: in_shard_all[k] for k in ins["batch"]}

    opt = make_optimizer()
    opt_shape = jax.eval_shape(opt.init, params_shape)
    ospecs = shard_lib.opt_state_specs(pspecs)

    def train_step(params, opt_state, batch):
        full_batch = dict(batch, **static)

        (loss, aux), grads = jax.value_and_grad(
            lambda p: mace_lib.loss_fn(
                cfg, p, full_batch, edge_axes=dp, channel_axes="model"
            ),
            has_aux=True,
        )(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return params, opt_state, aux

    return LoweringPlan(
        spec.arch_id, cell.shape, cell.kind, train_step,
        args=(params_shape, opt_shape, ins["batch"]),
        in_specs=(pspecs, ospecs, in_shard),
        out_specs=(pspecs, ospecs, P()),
        cfg=cfg, skip=cell.skip,
    )


# -- RecSys -----------------------------------------------------------------------


def _recsys_plan(spec, cfg, cell, multi_pod: bool) -> LoweringPlan:
    dp = shard_lib.data_axes(multi_pod)
    params_shape = jax.eval_shape(
        partial(recsys_lib.init_params, cfg), jax.random.PRNGKey(0)
    )
    pspecs = shard_lib.recsys_param_specs(params_shape)
    ins = configs_lib.input_specs(spec, cfg, cell)
    in_shard_all = shard_lib.recsys_input_shardings(cell.kind, multi_pod)
    in_shard = {k: in_shard_all["batch"][k] for k in ins["batch"]}
    emb_shard = P(dp, None, None) if cell.kind != "retrieval" else None
    act_shard = P(dp, "model", None) if cell.kind != "retrieval" else None

    if cell.kind == "train":
        opt = make_optimizer()
        opt_shape = jax.eval_shape(opt.init, params_shape)
        ospecs = shard_lib.opt_state_specs(pspecs)

        def train_step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: recsys_lib.loss_fn(
                    cfg, p, batch, emb_shard=emb_shard, act_shard=act_shard
                ),
                has_aux=True,
            )(params)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = jax.tree.map(lambda p, u: p + u, params, updates)
            return params, opt_state, aux

        return LoweringPlan(
            spec.arch_id, cell.shape, cell.kind, train_step,
            args=(params_shape, opt_shape, ins["batch"]),
            in_specs=(pspecs, ospecs, in_shard),
            out_specs=(pspecs, ospecs, P()),
            cfg=cfg, skip=cell.skip,
        )

    if cell.kind == "serve":
        def serve_step(params, batch):
            return recsys_lib.forward(
                cfg, params, batch, emb_shard=emb_shard, act_shard=act_shard
            )

        return LoweringPlan(
            spec.arch_id, cell.shape, cell.kind, serve_step,
            args=(params_shape, ins["batch"]),
            in_specs=(pspecs, in_shard),
            out_specs=P(dp),
            cfg=cfg, skip=cell.skip,
        )

    if cell.kind == "retrieval":
        # candidates shard over the data axes (10^6 rows divide by 16/32 but
        # not by the full 256/512-way mesh product)
        cand_rows = dp

        if getattr(cfg, "retrieval_mode", "dense") == "zen":
            # the paper's technique at the serving layer: score against the
            # nSimplex-reduced index (zen_k floats/candidate instead of
            # embed_dim) — memory-roofline term drops by embed_dim/zen_k
            from repro.core.simplex import BaseSimplex, apex_project
            from repro.core.zen import estimate_pdist

            def retrieval_step(params, batch, index):
                q = recsys_lib.user_repr(cfg, params, batch)  # (B, d)
                base = BaseSimplex(chol=index["chol"],
                                   diag_g=index["diag_g"], d0=index["d0"])
                # B x zen_k reference distances -> apex coordinates
                from repro.core.metrics import euclidean_pdist
                qp = apex_project(base, euclidean_pdist(q, index["refs"]))
                d = estimate_pdist(qp, index["coords"], "zen")
                scores, ids = jax.lax.top_k(-d, 100)
                return {"scores": -scores, "ids": ids}

            cand_specs = {
                "coords": P(cand_rows, None),
                "refs": P(), "chol": P(), "diag_g": P(), "d0": P(),
            }
            return LoweringPlan(
                spec.arch_id, cell.shape, cell.kind, retrieval_step,
                args=(params_shape, ins["batch"], ins["candidates"]),
                in_specs=(pspecs, in_shard, cand_specs),
                out_specs={"scores": P(), "ids": P()},
                cfg=cfg, skip=cell.skip,
            )

        def retrieval_step(params, batch, candidates):
            q = recsys_lib.user_repr(cfg, params, batch)
            scores, ids = recsys_lib.retrieval_topk(q, candidates, k=100)
            return {"scores": scores, "ids": ids}

        return LoweringPlan(
            spec.arch_id, cell.shape, cell.kind, retrieval_step,
            args=(params_shape, ins["batch"], ins["candidates"]),
            in_specs=(pspecs, in_shard, P(cand_rows, None)),
            out_specs={"scores": P(), "ids": P()},
            cfg=cfg, skip=cell.skip,
        )

    raise ValueError(cell.kind)


# -- public ------------------------------------------------------------------------


def build_plan(
    arch_id: str,
    shape: str,
    *,
    reduced: bool = False,
    multi_pod: bool = False,
    overrides: Optional[dict] = None,
) -> LoweringPlan:
    """overrides: config-field replacements (hillclimb variants), e.g.
    {"unroll_layers": True, "n_microbatches": 4, "remat_policy": "dots"}."""
    spec = configs_lib.get_arch(arch_id)
    cell = spec.cell(shape)
    cfg = spec.make_reduced() if reduced else spec.make_config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if spec.family == "lm":
        return _lm_plan(spec, cfg, cell, multi_pod)
    if spec.family == "gnn":
        return _gnn_plan(spec, cfg, cell, multi_pod)
    if spec.family == "recsys":
        return _recsys_plan(spec, cfg, cell, multi_pod)
    raise ValueError(spec.family)
