"""nSimplex-Zen retrieval serving — the paper's technique as a production
feature (DESIGN.md §3).

Offline:  ``build_index`` fits the transform on a witness sample, projects the
          corpus to (N, k) apex coordinates (one pdist + one triangular solve,
          both kernel paths), and optionally row-shards the reduced index over
          a mesh.
Online:   ``ZenServer.query`` projects a query batch (k reference distances)
          and scores it with the *streaming fused top-k* path
          (``kernels.ops.zen_topk``): the estimator and a running top-k are
          fused over index tiles, so per-query peak memory is one tile —
          O(chunk + n_neighbors), flat in index size — instead of the dense
          (Q, N) estimator matrix. Sharded indexes run the same streaming
          search per device shard (``distributed.sharded_knn_search``) and
          merge the (Q, n_shards * k) candidate pool host-side. An optional
          exact re-rank of the candidate pool with true distances follows
          (paper [50]'s deployment pattern).

``build_index(..., index="ivf")`` swaps the flat scan for the *clustered* IVF
path (``repro.index``): a k-means coarse quantizer over the apex coordinates
plus padded inverted-list tiles, so each query scores only its ``nprobe``
nearest clusters — sublinear in N — at a recall knob the server exposes as
``ZenServer(nprobe=...)``. ``nprobe = n_clusters`` recovers the flat result.

Mutable corpus + persistence
----------------------------
The corpus is not frozen at build time. ``ZenServer.upsert(ids, vectors)``
projects new objects with the *already-fitted* transform (the paper's core
property: projection needs only distances to the k references, so it extends
to unseen data indefinitely) and inserts them into the live index;
``ZenServer.delete(ids)`` tombstones rows. Flat indexes tombstone by
rewriting the row's external id to ``-1`` and its coordinates to a far
sentinel (the row can never win a top-k slot); IVF indexes tombstone through
the inverted-list id padding (``repro.index.ivf``). ``maybe_compact`` checks
the churn thresholds and repacks when crossed. ``ZenServer.save``/``load``
persist the whole serving state — transform, coordinates/inverted lists, id
map, corpus — as a versioned snapshot (``repro.checkpoint.index_io``) that
restores bit-identically, including onto a different device count.

Serving frontend
----------------
``ZenServer(frontend=True)`` (CLI: ``--frontend [--max-batch N --cache
ROWS]``) attaches the ``repro.serving`` micro-batching scheduler: many
small concurrent callers coalesce into one shape-bucketed kernel dispatch
per tick, with an LRU result cache invalidated by the index ``generation``
counter and reject-on-full backpressure. Even without the frontend, every
query dispatches at bucketed shapes (power-of-two Q, fixed ``n_neighbors``
menu) so the jit cache stays a handful of entries — and so scheduled,
cached and direct responses are bit-identical (``tests/test_frontend.py``).

CLI (CPU demo):  PYTHONPATH=src python -m repro.launch.serve --n 20000 --dim \
                 256 --k 16 --queries 64 [--index ivf --nprobe 8] \
                 [--checkpoint /tmp/zen.ckpt] [--frontend --cache 1024]
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import index_io
from repro.core import metrics as metrics_lib
from repro.core import zen as zen_lib
from repro.core import pivots as pivots_lib
from repro.core.projection import NSimplexTransform
from repro.core.simplex import BaseSimplex
from repro.distributed import retrieval as retrieval_lib
from repro.kernels import quantize as quant
from repro.kernels.scoring import mask_invalid
from repro.serving import (
    DEFAULT_NEIGHBOR_MENU, MicroBatchScheduler, bucket_neighbors, bucket_q,
)

Array = jax.Array

#: snapshot kind tag for full serving state (transform + index + corpus)
SERVER_SNAPSHOT_KIND = "zen-server"
#: coordinate sentinel written into tombstoned flat rows — far enough that a
#: dead row can never win a top-k slot, small enough that f32 squared norms
#: stay finite (1e15^2 * k << f32 max)
_DEAD_COORD = 1.0e15
#: flat capacity growth quantum — amortises jit recompiles of the search
_GROW_ROWS = 4096


@dataclasses.dataclass
class ZenIndex:
    """Serving-side index state: fitted transform + searchable coordinates.

    Attributes:
      transform: fitted ``NSimplexTransform`` (projects corpus and queries).
      coords:    (cap, k) apex coordinates (possibly row-sharded). For a
                 mutable flat index, rows beyond the live set (tombstones,
                 growth slack) hold a far sentinel and never win a search.
                 ``None`` for IVF indexes restored from a checkpoint (the
                 inverted lists are the source of truth).
      corpus:    original vectors for exact re-ranking, indexed by external
                 id (row ``i`` holds the vector of id ``i``); optional.
      mesh:      device mesh when the index is row-sharded.
      n_valid:   number of live rows; ``None`` means every row of ``coords``
                 is live (immutable fast path).
      ivf:       ``IVFZenIndex`` / ``ShardedIVFZenIndex`` when built with
                 ``index="ivf"``.
      row_ids:   (cap,) int32 external id per flat row, ``-1`` for dead rows;
                 ``None`` while the flat index is untouched (ids == row
                 positions). Materialised by the first upsert/delete.
      n_deleted: flat tombstones accumulated since the last build/compact —
                 drives ``needs_compact`` (growth slack is *not* counted:
                 compacting it away would defeat the grow-in-quanta
                 recompile amortisation).
      storage:   resident dtype of the flat ``coords``, one of
                 ``kernels.quantize.SCALAR_STORAGE_DTYPES`` (the IVF path
                 additionally takes "pq"); the search kernels dequantise in
                 register, accumulation stays f32.
      coord_scales: (cap, 1) f32 per-row symmetric int8 scales, or ``None``
                 for f32/bf16 storage. Per *row* — a scale rides with its
                 row through mutation, compaction and resharding, so
                 untouched rows are never requantised, and the far-sentinel
                 dead rows get their own (huge) scale without poisoning
                 live neighbours.
      generation: monotonic churn counter — every upsert/delete/compact
                 that changes the searchable state bumps it, and the
                 serving frontend's result cache keys on it, so cached
                 responses can never outlive the index state that produced
                 them (``repro.serving.cache``).
    """

    transform: NSimplexTransform
    coords: Optional[Array]  # (cap, k) apex coordinates (possibly sharded)
    corpus: Optional[Array]  # original vectors for re-ranking (optional)
    mesh: Optional[object] = None  # device mesh when coords are row-sharded
    n_valid: Optional[int] = None  # live rows when coords hold dead slots
    ivf: Optional[object] = None   # IVFZenIndex / ShardedIVFZenIndex
    row_ids: Optional[Array] = None  # (cap,) int32 external ids, -1 = dead
    n_deleted: int = 0  # flat tombstones since the last build/compact
    storage: str = "float32"  # resident dtype of the flat coords
    coord_scales: Optional[Array] = None  # (cap, 1) int8 dequant scales
    generation: int = 0  # churn counter; invalidates frontend cache entries

    @property
    def size(self) -> int:
        """Number of live (searchable) rows."""
        if self.ivf is not None:
            return self.ivf.size
        if self.n_valid is not None:
            return self.n_valid
        return self.coords.shape[0]

    # -- storage helpers (flat path) ----------------------------------------
    def _host_coord_state(self):
        """Host copies of the raw coord values (+ per-row scales or None)."""
        vals = np.asarray(self.coords).copy()
        scl = (None if self.coord_scales is None
               else np.asarray(self.coord_scales, np.float32).copy())
        return vals, scl

    @staticmethod
    def _write_rows(vals, scl, where, new_f32):
        """Write f32 rows into the raw storage arrays at ``where``.

        int8 rows are quantised with their own fresh per-row scales;
        f32/bf16 rows are plain (casting) assignments. Only the written
        rows change — every other row keeps its exact stored bytes.
        """
        if scl is None:
            vals[where] = new_f32
        else:
            v, s = quant.encode_rows(new_f32, "int8")
            vals[where] = v
            scl[where] = s

    @staticmethod
    def _kill_rows(vals, scl, where):
        """Stamp the far-sentinel dead-row pattern at ``where``."""
        if scl is None:
            vals[where] = _DEAD_COORD
        else:  # 127 * (sentinel / 127) dequantises to the exact sentinel
            vals[where] = np.int8(127)
            scl[where] = np.float32(_DEAD_COORD / 127.0)

    # -- mutation (control plane; returns a new ZenIndex) -------------------
    def delete(self, ids: Sequence[int]) -> "ZenIndex":
        """Tombstone the given external ids; unknown ids are ignored."""
        self._check_not_sharded()
        if self.ivf is not None:
            self._check_not_tiered()
            new_ivf = self.ivf.delete(ids)
            if new_ivf is self.ivf:  # nothing removed: state unchanged
                return self
            return dataclasses.replace(self, ivf=new_ivf,
                                       generation=self.generation + 1)
        self._check_mutable()
        row_ids = self._host_row_ids()
        coords, scl = self._host_coord_state()
        mask = (row_ids >= 0) & np.isin(row_ids, np.asarray(ids, np.int64))
        if not mask.any():
            return self
        row_ids[mask] = -1
        self._kill_rows(coords, scl, mask)
        return dataclasses.replace(
            self,
            coords=jnp.asarray(coords),
            row_ids=jnp.asarray(row_ids.astype(np.int32)),
            n_valid=self.size - int(mask.sum()),
            n_deleted=self.n_deleted + int(mask.sum()),
            coord_scales=None if scl is None else jnp.asarray(scl),
            generation=self.generation + 1,
        )

    def upsert(self, ids: Sequence[int], coords_new: Array) -> "ZenIndex":
        """Insert (or replace) projected rows keyed by external id.

        Args:
          ids:        (B,) non-negative external ids; existing ids are
                      replaced in place, duplicate ids in the batch keep the
                      last occurrence.
          coords_new: (B, k) apex coordinates of the new rows.

        New rows reuse tombstoned slots first; when the capacity is
        exhausted the flat array grows by multiples of ``_GROW_ROWS``
        (growth slack rows are dead until used, so searches between growths
        compile once).
        """
        self._check_not_sharded()
        if self.ivf is not None:
            self._check_not_tiered()
            new_ivf = self.ivf.upsert(ids, coords_new)
            if new_ivf is self.ivf:  # empty batch: state unchanged
                return self
            return dataclasses.replace(self, ivf=new_ivf,
                                       generation=self.generation + 1)
        self._check_mutable()
        from repro.index.ivf import _check_ids, _dedupe_last_wins

        ids_np = np.asarray(ids, np.int64).ravel()
        _check_ids(ids_np)
        if ids_np.size == 0:
            return self
        new = np.asarray(coords_new, np.float32).reshape(ids_np.size, -1)
        ids_np, new = _dedupe_last_wins(ids_np, new)

        row_ids = self._host_row_ids()
        coords, scl = self._host_coord_state()
        # replace rows whose external id already exists
        sorter = np.argsort(row_ids, kind="stable")
        pos = np.searchsorted(row_ids, ids_np, sorter=sorter)
        pos = np.clip(pos, 0, row_ids.size - 1)
        hit = row_ids[sorter[pos]] == ids_np
        self._write_rows(coords, scl, sorter[pos[hit]], new[hit])
        ids_np, new = ids_np[~hit], new[~hit]
        n_live = self.size + int(ids_np.size)
        reclaimed = 0
        if ids_np.size:
            free = np.flatnonzero(row_ids < 0)[: ids_np.size]
            reclaimed = int(free.size)  # dead slots this batch refills
            if free.size < ids_np.size:  # grow capacity in fixed quanta
                deficit = int(ids_np.size - free.size)
                grow = -(-deficit // _GROW_ROWS) * _GROW_ROWS
                cap = row_ids.size
                row_ids = np.concatenate(
                    [row_ids, np.full(grow, -1, np.int64)])
                dead = np.empty((grow, coords.shape[1]), coords.dtype)
                coords = np.concatenate([coords, dead])
                if scl is not None:
                    scl = np.concatenate(
                        [scl, np.empty((grow, 1), np.float32)])
                self._kill_rows(coords, scl, slice(cap, cap + grow))
                free = np.concatenate([free, cap + np.arange(deficit)])
            row_ids[free] = ids_np
            self._write_rows(coords, scl, free, new)
        return dataclasses.replace(
            self,
            coords=jnp.asarray(coords),
            row_ids=jnp.asarray(row_ids.astype(np.int32)),
            n_valid=n_live,
            n_deleted=max(0, self.n_deleted - reclaimed),
            coord_scales=None if scl is None else jnp.asarray(scl),
            generation=self.generation + 1,
        )

    def compact(self, **kw) -> "ZenIndex":
        """Repack the live rows, dropping tombstones and growth slack.

        For IVF indexes this forwards to ``IVFZenIndex.compact`` (pass
        ``recluster=True`` to refit the quantizer); for flat indexes it
        rewrites ``coords``/``row_ids`` to the live rows only.
        """
        self._check_not_sharded()
        if self.ivf is not None:
            self._check_not_tiered()
            new_ivf = self.ivf.compact(**kw)
            if new_ivf is self.ivf:  # nothing to reclaim: state unchanged
                return self
            return dataclasses.replace(self, ivf=new_ivf,
                                       generation=self.generation + 1)
        self._check_mutable()
        if self.row_ids is None:
            return self
        row_ids = self._host_row_ids()
        live = row_ids >= 0
        return dataclasses.replace(
            self,
            # per-row scales ride with their rows: slicing is the whole
            # repack, no dequantise/requantise cycle
            coords=jnp.asarray(np.asarray(self.coords)[live]),
            row_ids=jnp.asarray(row_ids[live].astype(np.int32)),
            n_valid=int(live.sum()),
            n_deleted=0,
            coord_scales=(None if self.coord_scales is None else
                          jnp.asarray(np.asarray(self.coord_scales)[live])),
            generation=self.generation + 1,
        )

    def needs_compact(self, **kw) -> bool:
        """True when churn degraded the layout enough to repack.

        Flat indexes compare *tombstones* (deletes since the last
        build/compact) against the once-live rows — the same
        ``max_tombstone_ratio`` knob as ``IVFZenIndex.needs_compact``.
        Growth slack from upserts is deliberately not counted: it is what
        amortises search recompiles between capacity growths.
        """
        if self.mesh is not None:
            return False  # sharded indexes are immutable: nothing to compact
        if self.ivf is not None:
            if self._is_tiered():
                return False  # serve-only: no churn to compact away
            return self.ivf.needs_compact(**kw)
        max_ratio = kw.get("max_tombstone_ratio", 0.2)
        return (self.n_deleted / max(self.size + self.n_deleted, 1)
                > max_ratio)

    def _host_row_ids(self) -> np.ndarray:
        if self.row_ids is None:
            return np.arange(self.coords.shape[0], dtype=np.int64)
        return np.asarray(self.row_ids).astype(np.int64).copy()

    def _check_not_sharded(self):
        if self.mesh is not None:
            raise NotImplementedError(
                "mutating a mesh-sharded index in place is not supported: "
                "churn the single-host index, save(), and reload onto the "
                "mesh (resharding happens at load)"
            )

    def _check_mutable(self):
        self._check_not_sharded()
        if self.coords is None:
            raise ValueError("index has no flat coordinates to mutate")

    def _is_tiered(self) -> bool:
        from repro.index.ivf import TieredIVFZenIndex

        return isinstance(self.ivf, TieredIVFZenIndex)

    def _check_not_tiered(self):
        if self._is_tiered():
            raise NotImplementedError(
                "a tiered (host-offloaded) index is serve-only: churn the "
                "resident index and re-offload (build_index(..., "
                "offload=True) or TieredIVFZenIndex.from_index)"
            )


def build_index(
    corpus: Array,
    k: int,
    *,
    metric: str = "euclidean",
    key: Optional[jax.Array] = None,
    mesh=None,
    keep_corpus: bool = True,
    index: str = "flat",
    n_clusters: Optional[int] = None,
    tile_rows: int = 128,
    kmeans_iters: int = 15,
    storage: str = "float32",
    pq_m: Optional[int] = None,
    pivots: str = "random",
    offload: bool = False,
    hot_clusters: Optional[int] = None,
    offload_shards: int = 1,
    prefetch_cols: int = 2,
) -> ZenIndex:
    """Fit on the corpus (witness = corpus sample) and project every row.

    ``index="flat"`` keeps the (N, k) coordinates for the streaming scan;
    ``index="ivf"`` additionally fits a k-means coarse quantizer
    (``n_clusters`` defaults to ~4*sqrt(N)) and packs the inverted-list
    tiles so the server probes only a few clusters per query. With a
    ``mesh``, both variants shard rows (flat coordinates or inverted lists)
    over all mesh axes.

    ``storage`` picks the resident dtype of the searchable coordinates, one
    of ``kernels.quantize.STORAGE_DTYPES`` — "bfloat16" (half the bytes,
    plain cast), "int8" (quarter, symmetric scales: per row for the flat
    layout, per cluster for IVF tiles), or "pq" (IVF only: each member
    stores ``pq_m`` uint8 product-quantiser code bytes, ``kernels.pq``).
    The projection, quantizer fit and query math all stay f32; only what
    the probe kernels stream gets narrower.

    ``pivots`` picks the base-simplex selection strategy
    (``core.pivots.PIVOT_STRATEGIES``): the paper's "random" redraw loop by
    default, or a principled alternative ("kmeanspp", "farthest_first",
    "maxvol") — one fit-time knob that lifts estimator quality for every
    later query.

    ``offload=True`` (IVF only) drops the packed inverted-list tiles to a
    host-resident pool after the build (``index.ivf.TieredIVFZenIndex``):
    only the centroids, scales and the ``hot_clusters`` highest-traffic
    clusters stay device-resident, cold probes stream up in
    ``prefetch_cols``-wide double-buffered chunks, and the clusters are
    partitioned over ``offload_shards`` logical shards for degraded serving
    (``ZenServer.enable_fault_tolerance``). The offloaded index is
    serve-only: upsert/delete/compact raise.
    """
    if index not in ("flat", "ivf"):
        raise ValueError(f"index must be 'flat' or 'ivf', got {index!r}")
    if offload and index != "ivf":
        raise ValueError("offload=True requires index='ivf' (the tiered "
                         "tile store offloads inverted-list tiles)")
    if offload and mesh is not None:
        raise ValueError(
            "offload=True and mesh are mutually exclusive: the tiered "
            "store already splits device/host residency on one host; "
            "degraded serving over its logical shards replaces mesh "
            "sharding (offload_shards=...)")
    quant.check_storage(storage)
    if storage == "pq" and index != "ivf":
        raise ValueError(
            "storage='pq' is IVF-only (codes are per-cluster residuals); "
            "the flat layout takes "
            + "/".join(quant.SCALAR_STORAGE_DTYPES))
    if storage == "pq" and mesh is not None:
        raise NotImplementedError(
            "storage='pq' is single-host for now; drop the mesh or pick "
            "one of " + "/".join(quant.SCALAR_STORAGE_DTYPES))
    key = key if key is not None else jax.random.PRNGKey(0)
    tr = pivots_lib.select_references(
        corpus, k, key, metric=metric, strategy=pivots)
    coords = tr.transform(corpus)
    n = coords.shape[0]
    ivf = None
    coord_scales = None
    if index == "ivf":
        from repro.index import IVFZenIndex, ShardedIVFZenIndex

        n_clusters = n_clusters or max(1, min(n, int(round(4 * n ** 0.5))))
        builder = (
            functools.partial(ShardedIVFZenIndex.build, mesh=mesh)
            if mesh is not None else IVFZenIndex.build
        )
        if mesh is None:
            builder = functools.partial(builder, pq_m=pq_m)
        ivf = builder(
            coords, n_clusters, tile_rows=tile_rows, n_iters=kmeans_iters,
            key=jax.random.fold_in(key, 7), storage=storage,
        )
        if offload:
            from repro.index.ivf import TieredIVFZenIndex

            ivf = TieredIVFZenIndex.from_index(
                ivf, hot_clusters=hot_clusters,
                n_shards=offload_shards, prefetch_cols=prefetch_cols)
    elif storage != "float32":
        values, scales = quant.encode_rows(
            np.asarray(coords, np.float32), storage)
        coords = jnp.asarray(values)
        coord_scales = None if scales is None else jnp.asarray(scales)
    n_valid = None
    if mesh is not None and ivf is None:
        # pad once to a shard-divisible row count so every query batch skips
        # the O(N) re-pad; the search masks rows >= n_valid
        coords, n_valid = retrieval_lib.shard_rows(coords, mesh=mesh)
        if coord_scales is not None:
            coord_scales, _ = retrieval_lib.shard_rows(coord_scales,
                                                       mesh=mesh)
    return ZenIndex(transform=tr, coords=coords,
                    corpus=corpus if keep_corpus else None, mesh=mesh,
                    n_valid=n_valid, ivf=ivf, storage=storage,
                    coord_scales=coord_scales)


def load_index_snapshot(
    directory: str,
    *,
    mesh=None,
    mmap: bool = False,
    pool: Optional[str] = None,
    pool_kw: Optional[dict] = None,
) -> Tuple[ZenIndex, dict]:
    """Load a :meth:`ZenServer.save` snapshot into a ``ZenIndex``.

    The index-owner / query-plane split of the replicated serving tier
    (``repro.launch.replicate``) hinges on this function being independent
    of any server object: a replica loads the published snapshot into a
    fresh ``ZenIndex`` and swaps it under its long-lived ``ZenServer``
    without touching the leader's state.

    Args:
      directory: snapshot directory (``SERVER_SNAPSHOT_KIND``).
      mesh:      optional device mesh to reshard onto (flat coordinates are
                 re-padded/re-sharded, IVF inverted lists re-packed).
      mmap:      memory-map the snapshot arrays read-only instead of
                 materialising host copies. Device-resident layouts still
                 copy onto the device, but the host never holds a second
                 materialised copy — and for the tiered ``pool`` path the
                 cold tiles are *served* straight off the mapped files.
      pool:      optional ``TILE_POOL_SNAPSHOT_KIND`` snapshot directory
                 (published next to the server snapshot by
                 ``replicate.IndexLeader``): the IVF tier is opened as a
                 serve-only ``TieredIVFZenIndex`` over that pool
                 (``load(mmap=...)``) instead of re-packing resident tiles
                 — the billion-row replica shape. IVF snapshots only.
      pool_kw:   extra ``TieredIVFZenIndex.load`` options (``hot_clusters``,
                 ``hot_fraction``, ``prefetch_cols``, ``n_shards``, ...).

    Returns ``(index, server_kw)``: the restored index (its ``generation``
    is the *published* one, not a fresh counter — frontend cache keys
    depend on it) and the saved server construction kwargs.

    Raises ``checkpoint.CheckpointFormatError`` for snapshots written by an
    incompatible format version or of a different kind.
    """
    arrays, meta = index_io.load_state(
        directory, expect_kind=SERVER_SNAPSHOT_KIND, mmap=mmap)
    base = BaseSimplex(
        chol=jnp.asarray(arrays["base_chol"]),
        diag_g=jnp.asarray(arrays["base_diag_g"]),
        d0=jnp.asarray(arrays["base_d0"]),
    )
    tr = NSimplexTransform(
        k=int(meta["k"]), metric=meta["metric"],
        jitter=float(meta["jitter"]), refs=jnp.asarray(arrays["refs"]),
        base=base,
    )
    corpus = (jnp.asarray(arrays["corpus"])
              if "corpus" in arrays else None)
    generation = int(meta.get("generation", 0))
    if pool is not None and meta["index"] != "ivf":
        raise ValueError(
            "pool=... serves the IVF tier from a tile-pool snapshot; this "
            "snapshot holds a flat index")
    if pool is not None and mesh is not None:
        raise ValueError("pool=... and mesh are mutually exclusive (the "
                         "tiered store is single-host)")
    if meta["index"] == "ivf":
        from repro.index import IVFZenIndex, ShardedIVFZenIndex

        storage = meta.get("storage", "float32")
        if pool is not None:
            from repro.index.ivf import TieredIVFZenIndex

            ivf = TieredIVFZenIndex.load(pool, mmap=mmap,
                                         **dict(pool_kw or {}))
            # the server snapshot's wrapper generation is authoritative —
            # a pool republished out of band must not fork the key space
            ivf.generation = generation
        else:
            members = (arrays["ivf_member_coords"],
                       arrays["ivf_member_ids"].astype(np.int64),
                       arrays["ivf_member_assign"].astype(np.int64))
            scales = arrays.get("ivf_cluster_scales")
            if mesh is not None:
                ivf = ShardedIVFZenIndex._from_members(
                    *members, jnp.asarray(arrays["ivf_centroids"]),
                    int(meta["n_clusters"]), int(meta["tile_rows"]),
                    mesh=mesh, storage=storage, scales=scales)
            else:
                coords_m, mids, massign = members
                ivf = IVFZenIndex.from_members(
                    coords_m, mids, massign,
                    jnp.asarray(arrays["ivf_centroids"]),
                    int(meta["n_clusters"]), int(meta["tile_rows"]),
                    storage=storage, scales=scales,
                    codebooks=arrays.get("ivf_pq_codebooks"),
                    generation=generation)
        index = ZenIndex(transform=tr, coords=None, corpus=corpus,
                         mesh=mesh, ivf=ivf, storage=storage,
                         generation=generation)
    else:
        coords = jnp.asarray(arrays["coords"])
        row_ids = jnp.asarray(arrays["row_ids"].astype(np.int32))
        storage = meta.get("storage", "float32")
        coord_scales = (jnp.asarray(arrays["coord_scales"])
                        if "coord_scales" in arrays else None)
        n_valid = None
        if mesh is not None:
            coords, n_valid = retrieval_lib.shard_rows(coords, mesh=mesh)
            pad = coords.shape[0] - row_ids.shape[0]
            if pad:  # shard-padding positions map to the dead id
                row_ids = jnp.concatenate(
                    [row_ids, jnp.full((pad,), -1, jnp.int32)])
            if coord_scales is not None:
                coord_scales, _ = retrieval_lib.shard_rows(
                    coord_scales, mesh=mesh)
        index = ZenIndex(transform=tr, coords=coords, corpus=corpus,
                         mesh=mesh, n_valid=n_valid, row_ids=row_ids,
                         storage=storage, coord_scales=coord_scales,
                         generation=generation)
    return index, dict(meta.get("server", {}))


class ZenServer:
    """Batched k-NN serving over a reduced index.

    The search path never materialises a (Q, N) estimator matrix: single-host
    indexes stream through ``core.zen.knn_search`` (fused Pallas kernel on
    TPU, bounded-memory scan elsewhere) once the index exceeds ``chunk`` rows;
    mesh-sharded indexes run the streaming search per shard and merge the
    per-shard candidates host-side. IVF-built indexes probe only the
    ``nprobe`` nearest clusters per query (``repro.index``) — sublinear in
    index size, with ``nprobe`` as the recall/latency knob.

    Shape-bucketed dispatch
    -----------------------
    Every query — frontend-scheduled or direct — is served at *bucketed*
    shapes: the row count is padded to a power-of-two Q bucket (floor 2)
    and ``n_neighbors`` is rounded up to the fixed width menu
    (``repro.serving.DEFAULT_NEIGHBOR_MENU``), then sliced back. The jit
    cache therefore holds one entry per (Q bucket, width) pair instead of
    one per caller shape, and — because results are row-wise bit-identical
    across bucketed batch shapes — a coalesced, padded, or cached response
    is bit-identical to the same query served alone.

    Frontend
    --------
    ``frontend=True`` attaches a ``repro.serving.MicroBatchScheduler``:
    ``query`` becomes a thin client that submits rows to the scheduler
    (coalescing across concurrent callers, LRU result caching with
    generation-based invalidation, reject-on-full backpressure) and blocks
    for its answer; ``query(..., direct=True)`` is the escape hatch that
    bypasses the scheduler on the old synchronous path.
    """

    def __init__(self, index: ZenIndex, *, mode: str = "zen",
                 rerank_factor: int = 0, chunk: int = 8192,
                 nprobe: int = 8, force_kernel: bool = False,
                 frontend: bool = False, max_batch: int = 64,
                 cache_size: int = 0, queue_limit: int = 4096,
                 tick_interval: float = 0.002,
                 neighbor_menu: Sequence[int] = DEFAULT_NEIGHBOR_MENU,
                 clock=None):
        self.index = index
        self.mode = mode
        self.rerank_factor = rerank_factor
        self.chunk = chunk
        self.nprobe = nprobe
        self.force_kernel = force_kernel
        self.neighbor_menu = tuple(neighbor_menu)
        self.max_batch = max_batch
        self.cache_size = cache_size
        self._stats = {"queries": 0, "batches": 0, "latency_s": [],
                       "upserts": 0, "deletes": 0}
        # fault tolerance (enable_fault_tolerance): liveness registry,
        # preemption guard, and the degraded state they currently imply
        self.heartbeats = None
        self.preemption = None
        self._snapshot_dir: Optional[str] = None
        self._ft_shards: Tuple[str, ...] = ()
        self._degraded: Tuple[int, ...] = ()
        self._alive_mask: Optional[Array] = None
        self.frontend: Optional[MicroBatchScheduler] = None
        if frontend:
            kw = {"clock": clock} if clock is not None else {}
            self.frontend = MicroBatchScheduler(
                self, max_batch=max_batch, cache_size=cache_size,
                queue_limit=queue_limit, tick_interval=tick_interval,
                neighbor_menu=self.neighbor_menu, **kw)

    # -- bucketed dispatch core ----------------------------------------------
    def _query_geometry(self, n_neighbors: int) -> Tuple[int, int]:
        """(n_bucket, fetch width) a request dispatches at.

        ``n_bucket`` is the menu-rounded output width; the fetch width is
        the menu-rounded candidate-pool width (``n_neighbors *
        rerank_factor`` when re-ranking). Shared with the scheduler so
        direct and coalesced dispatches — and their cache keys — agree.
        """
        n_bucket = bucket_neighbors(n_neighbors, self.neighbor_menu)
        width = bucket_neighbors(
            n_neighbors * max(self.rerank_factor, 1), self.neighbor_menu)
        return n_bucket, max(width, n_bucket)

    def _query_block(self, queries: Array, width: int, n_bucket: int,
                     index: Optional[ZenIndex] = None
                     ) -> Tuple[Array, Array]:
        """Serve one already-padded block at bucketed shapes.

        Args:
          queries:  (Qp, m) raw query rows, ``Qp`` a power-of-two bucket
                    (padding rows are copies of real rows; their results
                    are sliced off by the caller, never observed).
          width:    bucketed candidate fetch width.
          n_bucket: bucketed output width (<= ``width``).
          index:    the ``ZenIndex`` snapshot to serve from (defaults to
                    the current ``self.index``). The whole block is served
                    from this one snapshot — ``self.index`` is read exactly
                    once — so concurrent churn swapping the live index can
                    never mix two index states within one query (the
                    scheduler passes the snapshot it keyed its cache
                    entries on).

        Returns (distances, ids), each (Qp, n_bucket) — project, search,
        optional exact re-rank, external-id mapping, and the (+inf, -1)
        fill for slots the index cannot serve. Both the direct path and
        the frontend scheduler dispatch through here, which is what makes
        their results (and cache entries) interchangeable bit-for-bit.
        """
        index = index if index is not None else self.index
        queries = jnp.asarray(queries)
        if index.size == 0:  # fully-deleted index: all slots unfilled
            return (jnp.full((queries.shape[0], n_bucket), jnp.inf,
                             jnp.float32),
                    jnp.full((queries.shape[0], n_bucket), -1, jnp.int32))
        qp = index.transform.transform(queries)
        n_fetch = min(width, index.size)
        if index.ivf is not None:
            # mesh-sharded IVF takes the device-resident alive mask; the
            # tiered store is instead masked up front (set_dead_shards)
            kw = ({"alive": self._alive_mask}
                  if self._alive_mask is not None and index.mesh is not None
                  else {})
            d, ids = index.ivf.search(
                qp, n_neighbors=n_fetch,
                nprobe=self.nprobe, mode=self.mode,
                force_kernel=self.force_kernel, **kw,
            )
        elif index.mesh is not None:
            d, ids = retrieval_lib.sharded_knn_search(
                qp, index.coords,
                n_neighbors=n_fetch, mode=self.mode,
                mesh=index.mesh, chunk=self.chunk,
                force_kernel=self.force_kernel, n_valid=index.n_valid,
                scales=index.coord_scales, alive=self._alive_mask,
            )
            d, ids = self._map_row_ids(d, ids, index)
        else:
            d, ids = zen_lib.knn_search(
                qp, index.coords,
                n_neighbors=n_fetch, mode=self.mode,
                chunk=self.chunk if index.coords.shape[0] > self.chunk
                else 0,
                scales=index.coord_scales,
                force_kernel=self.force_kernel,
            )
            d, ids = self._map_row_ids(d, ids, index)
        if self.rerank_factor and index.corpus is not None:
            d, ids = self._rerank(queries, ids, n_bucket, index)
        else:
            d, ids = d[:, :n_bucket], ids[:, :n_bucket]
        if d.shape[1] < n_bucket:
            # fewer live rows than the bucket width: pad to the full bucket
            pad = n_bucket - d.shape[1]
            d = jnp.pad(d, ((0, 0), (0, pad)), constant_values=jnp.inf)
            ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        return d, ids

    def query(self, queries: Array, n_neighbors: int = 10, *,
              direct: bool = False) -> Tuple[Array, Array]:
        """Serve one batch: (Q, m) raw queries -> (distances, ids).

        Args:
          queries:     (Q, m) raw (un-projected) query vectors.
          n_neighbors: neighbours to return per query.
          direct:      bypass the frontend scheduler (when one is attached)
                       and serve synchronously on the calling thread — the
                       unbatched escape hatch. Results are bit-identical
                       either way.

        Returns (distances, ids), each (Q, n_neighbors), ascending distance.
        Ids are *external* ids (stable across churn and checkpoint reload);
        slots the index cannot fill come back as (+inf, -1).
        """
        t0 = time.time()
        self.on_tick()  # refresh shard liveness / pending preemption save
        queries = jnp.asarray(queries)
        n_rows = int(queries.shape[0])
        if (self.frontend is not None and not direct
                and n_rows <= self.frontend.queue_limit):
            # batches beyond queue_limit fall through to the direct path:
            # they are already far past any coalescing benefit, and a
            # permanent reject-on-full for them would masquerade as
            # transient overload
            handle = self.frontend.submit(queries, n_neighbors)
            if not self.frontend.running:
                # no ticker thread: drive the scheduler inline so the
                # single-threaded caller still gets coalescing + caching
                self.frontend.flush()
            d_np, ids_np = handle.result()
            d, ids = jnp.asarray(d_np), jnp.asarray(ids_np)
        elif n_rows == 0:
            d = jnp.full((0, n_neighbors), jnp.inf, jnp.float32)
            ids = jnp.full((0, n_neighbors), -1, jnp.int32)
        else:
            n_bucket, width = self._query_geometry(n_neighbors)
            if n_rows <= self.max_batch:
                qp_rows = bucket_q(n_rows)
            else:
                # beyond max_batch, power-of-two padding would waste up to
                # ~2x scan compute; round up to a max_batch multiple
                # instead (waste < max_batch rows, shapes still bucketed)
                qp_rows = -(-n_rows // self.max_batch) * self.max_batch
            if qp_rows > n_rows:  # pad with copies of a real row
                queries = jnp.concatenate([
                    queries,
                    jnp.broadcast_to(queries[:1],
                                     (qp_rows - n_rows, queries.shape[1])),
                ])
            d, ids = self._query_block(queries, width, n_bucket)
            d, ids = d[:n_rows, :n_neighbors], ids[:n_rows, :n_neighbors]
        self._stats["queries"] += n_rows
        self._stats["batches"] += 1
        self._stats["latency_s"].append(time.time() - t0)
        return d, ids

    def _map_row_ids(self, d: Array, ids: Array, index: ZenIndex
                     ) -> Tuple[Array, Array]:
        """Map flat row positions to external ids (churned/reloaded index).

        With ``row_ids`` unset the two id spaces coincide and this is a
        no-op. Tombstoned rows cannot win a slot (their coordinates are a
        far sentinel), but any dead id that sneaks into an under-filled
        result is masked to (+inf, -1) — the same contract as the IVF path.
        """
        if index.row_ids is None:
            return d, ids
        ext = jnp.take(index.row_ids, jnp.maximum(ids, 0), axis=0)
        ext = jnp.where(ids >= 0, ext, -1)
        return mask_invalid(d, ext), ext

    # -- mutable corpus lifecycle -------------------------------------------
    def upsert(self, ids: Sequence[int], vectors: Array) -> None:
        """Project and insert (or replace) raw vectors under external ids.

        The fitted transform projects the (B, m) batch — no refit, the
        paper's out-of-sample property — and the index absorbs the rows
        (``ZenIndex.upsert``). When the server keeps a re-rank corpus it is
        grown/overwritten at the same ids so exact re-ranking stays
        consistent with the reduced index.
        """
        ids_np = np.asarray(ids, np.int64).ravel()
        vectors = jnp.asarray(vectors)
        qp = self.index.transform.transform(vectors)
        new_index = self.index.upsert(ids_np, qp)
        corpus = self.index.corpus
        if corpus is not None:
            host = np.asarray(corpus)
            hi = int(ids_np.max()) + 1 if ids_np.size else 0
            if hi > host.shape[0]:
                # the re-rank corpus is indexed *densely* by external id;
                # refuse growth a sparse huge id would turn into a silent
                # multi-GB allocation (use dense-ish ids, or
                # keep_corpus=False / rerank_factor=0 for sparse id spaces)
                limit = max(2 * host.shape[0], host.shape[0] + 1_000_000)
                if hi > limit:
                    raise ValueError(
                        f"upsert id {hi - 1} would grow the dense re-rank "
                        f"corpus from {host.shape[0]} to {hi} rows; ids "
                        "index the corpus by position — use dense ids or "
                        "drop the corpus (keep_corpus=False)"
                    )
                host = np.concatenate([
                    host,
                    np.zeros((hi - host.shape[0], host.shape[1]), host.dtype),
                ])
            else:
                host = host.copy()
            host[ids_np] = np.asarray(vectors, host.dtype)
            new_index = dataclasses.replace(
                new_index, corpus=jnp.asarray(host))
        self.index = new_index
        self._stats["upserts"] += int(ids_np.size)

    def delete(self, ids: Sequence[int]) -> None:
        """Tombstone external ids (flat and IVF; unknown ids are ignored)."""
        before = self.index.size
        self.index = self.index.delete(ids)
        self._stats["deletes"] += before - self.index.size

    def compact(self, **kw) -> None:
        """Repack the index now (see ``ZenIndex.compact``)."""
        self.index = self.index.compact(**kw)

    def maybe_compact(self, **thresholds) -> bool:
        """Compact iff churn crossed the thresholds; True when it ran.

        When the ``max_imbalance`` threshold is what tripped (IVF only),
        the compaction refits the quantizer (``recluster=True``) — a plain
        repack keeps the same assignments and cannot reduce imbalance, so
        it would trigger again on every call.
        """
        if not self.index.needs_compact(**thresholds):
            return False
        mi = thresholds.get("max_imbalance")
        if (mi is not None and self.index.ivf is not None
                and self.index.ivf.imbalance > mi):
            self.compact(recluster=True)
        else:
            self.compact()
        return True

    # -- fault tolerance ------------------------------------------------------
    def _default_shard_count(self) -> int:
        """Logical shard count implied by the index layout."""
        ivf = self.index.ivf
        if ivf is not None and hasattr(ivf, "set_dead_shards"):
            return int(ivf.n_shards)  # tiered: static cluster partition
        if self.index.mesh is not None:
            return int(self.index.mesh.devices.size)
        return 1

    def enable_fault_tolerance(self, shards=None, *,
                               deadline_s: float = 60.0, clock=None,
                               snapshot_dir: Optional[str] = None,
                               install_signal: bool = False):
        """Attach liveness + preemption handling (``distributed.fault``).

        Args:
          shards:      logical shard names expected to heartbeat — an int
                       (count; names become ``shard0..shardN-1``) or a
                       sequence of names. Defaults to the index's own shard
                       structure: ``n_shards`` for a tiered IVF index, the
                       mesh device count for a sharded one, else 1.
          deadline_s:  silence longer than this marks a shard dead.
          clock:       monotonic time source (tests inject a fake).
          snapshot_dir: when set, a platform preemption notice
                       (SIGTERM / ``preemption.request()``) triggers a full
                       server snapshot here at the next tick boundary.
          install_signal: install the real SIGTERM handler (off by default:
                       tests and embedded servers trigger manually).

        After this, each shard's supervisor calls :meth:`heartbeat`
        periodically; every query (and every frontend tick) refreshes the
        death verdicts via :meth:`on_tick`. A dead shard's data is masked
        out of the search — queries keep answering from the survivors with
        reduced recall instead of raising — and ``stats()`` reports the
        outage under ``"degraded_shards"``. Returns the registry.
        """
        from repro.distributed.fault import HeartbeatRegistry, PreemptionGuard

        if shards is None:
            shards = self._default_shard_count()
        if isinstance(shards, int):
            shards = [f"shard{i}" for i in range(shards)]
        self._ft_shards = tuple(str(s) for s in shards)
        kw = {"now": clock} if clock is not None else {}
        self.heartbeats = HeartbeatRegistry(deadline_s=deadline_s, **kw)
        for name in self._ft_shards:
            self.heartbeats.register(name)
        self.preemption = PreemptionGuard(install_signal=install_signal)
        self._snapshot_dir = snapshot_dir
        self._degraded = ()
        self._alive_mask = None
        return self.heartbeats

    def heartbeat(self, shard) -> None:
        """Record a liveness beat for ``shard`` (index or name)."""
        if self.heartbeats is None:
            raise RuntimeError("call enable_fault_tolerance() first")
        name = (self._ft_shards[shard] if isinstance(shard, int)
                else str(shard))
        self.heartbeats.beat(name)

    def on_tick(self) -> None:
        """Refresh liveness verdicts + run any pending preemption save.

        Called on every query and every frontend scheduler tick; a no-op
        until :meth:`enable_fault_tolerance`. Masking is applied only when
        the verdict *changes*, so steady state costs one clock read.
        """
        reg = self.heartbeats
        if reg is not None:
            dead_names = set(reg.dead_hosts())
            dead = tuple(i for i, n in enumerate(self._ft_shards)
                         if n in dead_names)
            if dead != self._degraded:
                self._degraded = dead
                ivf = self.index.ivf
                if ivf is not None and hasattr(ivf, "set_dead_shards"):
                    ivf.set_dead_shards(dead)
                elif self.index.mesh is not None:
                    alive = np.ones(len(self._ft_shards), bool)
                    alive[list(dead)] = False
                    self._alive_mask = (None if alive.all()
                                        else jnp.asarray(alive))
                # flat single-host index: nothing to mask — the registry
                # still tracks external replicas and stats() reports them
        guard = self.preemption
        if (guard is not None and guard.should_save()
                and self._snapshot_dir is not None):
            self.save(self._snapshot_dir)
            guard.clear()

    def _rerank(self, queries: Array, cand_ids: Array, n_neighbors: int,
                index: ZenIndex) -> Tuple[Array, Array]:
        """Exact re-rank of the Zen candidate pool with true distances."""
        from repro.index import exact_rerank

        return exact_rerank(
            queries, index.corpus, cand_ids, n_neighbors,
            metric=index.transform.metric,
        )

    def stats(self) -> dict:
        """Serving counters: query/batch totals, latency percentiles, churn.

        With a frontend attached, a ``"frontend"`` sub-dict adds the SLO
        instrumentation (p50/p95/p99 request latency, batch occupancy,
        cache hit rate, compile count, backpressure counters) and a
        ``"cache"`` sub-dict the LRU state (``repro.serving.stats``).
        """
        lat = np.asarray(self._stats["latency_s"] or [0.0])
        out = {
            "queries": self._stats["queries"],
            "batches": self._stats["batches"],
            "upserts": self._stats["upserts"],
            "deletes": self._stats["deletes"],
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
        }
        if self.heartbeats is not None:
            out["degraded_shards"] = [self._ft_shards[i]
                                      for i in self._degraded]
        ivf = self.index.ivf
        if ivf is not None and hasattr(ivf, "set_dead_shards"):
            out["tier"] = ivf.stats()  # hot/cold traffic + memory split
        if self.frontend is not None:
            out["frontend"] = self.frontend.stats.snapshot()
            out["cache"] = self.frontend.cache.info()
        return out

    # -- persistence ---------------------------------------------------------
    def save(self, directory: str) -> str:
        """Persist the full serving state as one versioned atomic snapshot.

        Everything needed to answer queries identically after a restart is
        written: the fitted transform (references + base simplex), the flat
        coordinates + external-id map *or* the IVF members + quantizer, and
        the re-rank corpus if kept. The snapshot is canonical host data —
        a server saved from a sharded mesh reloads onto any device count
        (``load(mesh=...)`` re-shards).
        """
        index = self.index
        tr = index.transform
        if tr.refs is None:
            raise ValueError(
                "distance-only transforms hold no reference coordinates and "
                "cannot serve raw-vector queries after reload; checkpointing "
                "them is unsupported"
            )
        arrays = {
            "refs": np.asarray(tr.refs, np.float32),
            "base_chol": np.asarray(tr.base.chol, np.float32),
            "base_diag_g": np.asarray(tr.base.diag_g, np.float32),
            "base_d0": np.asarray(tr.base.d0, np.float32),
        }
        meta = {
            "k": tr.k,
            "metric": tr.metric,
            "jitter": tr.jitter,
            "index": "ivf" if index.ivf is not None else "flat",
            "server": {
                "mode": self.mode,
                "rerank_factor": self.rerank_factor,
                "chunk": self.chunk,
                "nprobe": self.nprobe,
                "frontend": self.frontend is not None,
                "max_batch": self.max_batch,
                "cache_size": self.cache_size,
            },
        }
        if index.ivf is not None:
            from repro.index.ivf import snapshot_payload

            ivf_arrays, ivf_meta = snapshot_payload(index.ivf)
            arrays.update({f"ivf_{k}": v for k, v in ivf_arrays.items()})
            meta.update(ivf_meta)
        else:
            # raw storage-dtype rows + their per-row scales: the quantised
            # bytes round-trip untouched, any device count
            coords = retrieval_lib.host_rows(index.coords, index.n_valid) \
                if index.mesh is not None else np.asarray(index.coords)
            row_ids = index._host_row_ids()[: coords.shape[0]]
            live = row_ids >= 0
            arrays.update(
                coords=coords[live],
                row_ids=row_ids[live].astype(np.int32),
            )
            if index.coord_scales is not None:
                scales = retrieval_lib.host_rows(
                    index.coord_scales, index.n_valid) \
                    if index.mesh is not None \
                    else np.asarray(index.coord_scales)
                arrays["coord_scales"] = scales[live].astype(np.float32)
            meta["storage"] = index.storage
        # the *wrapper* churn counter is the published generation (set after
        # the ivf meta merge on purpose: the inner IVF keeps its own counter,
        # but cache keys — and therefore replica coherence — ride on this
        # one). Restored servers must not restart it from 0: a replica that
        # did would collide pre- and post-swap cache keys (launch.replicate).
        meta["generation"] = int(index.generation)
        if index.corpus is not None:
            arrays["corpus"] = np.asarray(index.corpus)
        return index_io.save_state(
            directory, arrays, meta, kind=SERVER_SNAPSHOT_KIND)

    @classmethod
    def load(cls, directory: str, *, mesh=None, mmap: bool = False,
             pool: Optional[str] = None, **server_kw) -> "ZenServer":
        """Restore a server from :meth:`save` — bit-identical search results.

        Args:
          directory: snapshot directory.
          mesh:      optional device mesh to reshard onto; may have a
                     different device count than the saving process (flat
                     coordinates are re-padded and re-sharded, IVF inverted
                     lists re-packed per shard).
          mmap:      memory-map the snapshot arrays read-only instead of
                     materialising host copies (see
                     :func:`load_index_snapshot`).
          pool:      optional tile-pool snapshot directory to serve the IVF
                     tier from (mmap'd tiered store; see
                     :func:`load_index_snapshot`).
          server_kw: overrides for the saved server config (``mode``,
                     ``rerank_factor``, ``chunk``, ``nprobe``,
                     ``force_kernel``).

        Raises ``checkpoint.CheckpointFormatError`` for snapshots written by
        an incompatible format version or of a different kind.
        """
        index, saved_kw = load_index_snapshot(
            directory, mesh=mesh, mmap=mmap, pool=pool)
        kw = dict(saved_kw)
        kw.update(server_kw)
        return cls(index, **kw)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=20000)
    p.add_argument("--dim", type=int, default=256)
    p.add_argument("--k", type=int, default=16)
    p.add_argument("--queries", type=int, default=64)
    p.add_argument("--batches", type=int, default=4)
    p.add_argument("--neighbors", type=int, default=10)
    p.add_argument("--metric", default="euclidean")
    p.add_argument("--rerank", type=int, default=4)
    p.add_argument("--index", default="flat", choices=["flat", "ivf"])
    p.add_argument("--clusters", type=int, default=0,
                   help="IVF cluster count (0 = ~4*sqrt(N))")
    p.add_argument("--nprobe", type=int, default=8)
    p.add_argument("--storage", default="float32",
                   choices=list(quant.STORAGE_DTYPES),
                   help=quant.storage_help())
    p.add_argument("--pq-m", type=int, default=0,
                   help="PQ subspace count M (storage=pq; 0 = ~k/4)")
    p.add_argument("--pivots", default="random",
                   choices=list(pivots_lib.PIVOT_STRATEGIES),
                   help="base-simplex (reference) selection strategy "
                        "(core.pivots; random = the paper's redraw loop)")
    p.add_argument("--offload", action="store_true",
                   help="host-offload the IVF tile pool (tiered store): "
                        "only centroids + a hot cluster set stay device-"
                        "resident, cold probes stream up double-buffered")
    p.add_argument("--hot-clusters", type=int, default=0,
                   help="device-resident hot set size (0 = 10%% of C)")
    p.add_argument("--offload-shards", type=int, default=1,
                   help="logical shards for degraded serving (tiered)")
    p.add_argument("--checkpoint", default=None, metavar="DIR",
                   help="restore the server from DIR if a snapshot exists "
                        "there, else build and save one (versioned, atomic)")
    p.add_argument("--frontend", action="store_true",
                   help="serve through the micro-batching frontend "
                        "(coalesced, shape-bucketed dispatches + result "
                        "cache; repro.serving)")
    p.add_argument("--max-batch", type=int, default=64,
                   help="largest coalesced dispatch (frontend mode)")
    p.add_argument("--cache", type=int, default=0, metavar="ROWS",
                   help="LRU result-cache capacity in rows (frontend mode; "
                        "0 disables)")
    args = p.parse_args()

    import os

    from repro.core import quality
    from repro.data import synthetic as syn

    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, args.n, args.dim, args.dim // 8)
    frontend_kw = dict(frontend=args.frontend, max_batch=args.max_batch,
                       cache_size=args.cache)
    if args.checkpoint and os.path.exists(
            os.path.join(args.checkpoint, "manifest.json")):
        server = ZenServer.load(args.checkpoint,
                                rerank_factor=args.rerank,
                                nprobe=args.nprobe, **frontend_kw)
        index = server.index
        ref_dim = int(index.transform.refs.shape[1])
        if ref_dim != args.dim:
            raise SystemExit(
                f"checkpoint {args.checkpoint} serves {ref_dim}-d vectors "
                f"but --dim is {args.dim}; pass --dim {ref_dim}")
        print(f"restored server from {args.checkpoint}")
    else:
        index = build_index(corpus, args.k, metric=args.metric,
                            index=args.index,
                            n_clusters=args.clusters or None,
                            storage=args.storage,
                            pq_m=args.pq_m or None,
                            pivots=args.pivots,
                            offload=args.offload,
                            hot_clusters=args.hot_clusters or None,
                            offload_shards=args.offload_shards)
        server = ZenServer(index, rerank_factor=args.rerank,
                           nprobe=args.nprobe, **frontend_kw)
        if args.checkpoint:
            print(f"saved snapshot to {server.save(args.checkpoint)}")
    print(f"index: {index.size} x {args.k} (from dim {args.dim}, "
          f"storage={index.storage})"
          + (f"; ivf: {index.ivf.n_clusters} clusters, nprobe={args.nprobe}"
             if index.ivf is not None else ""))

    qkey = jax.random.fold_in(key, 1)
    recalls = []
    for b in range(args.batches):
        q = syn.manifold_space(jax.random.fold_in(qkey, b), args.queries,
                               args.dim, args.dim // 8)
        d, ids = server.query(q, args.neighbors)
        true_d = metrics_lib.pairwise(args.metric, q, corpus)
        _, true_ids = jax.lax.top_k(-true_d, args.neighbors)
        hit = np.mean([
            len(set(np.asarray(ids)[i]) & set(np.asarray(true_ids)[i]))
            / args.neighbors
            for i in range(args.queries)
        ])
        recalls.append(hit)
    print(f"recall@{args.neighbors}: {np.mean(recalls):.3f}")
    print("latency:", server.stats())


if __name__ == "__main__":
    main()
