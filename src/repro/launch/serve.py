"""nSimplex-Zen retrieval serving — the paper's technique as a production
feature (DESIGN.md §3).

Offline:  ``build_index`` fits the transform on a witness sample, projects the
          corpus to (N, k) apex coordinates (one pdist + one triangular solve,
          both kernel paths), and optionally row-shards the reduced index over
          a mesh.
Online:   ``ZenServer.query`` projects a query batch (k reference distances)
          and scores it with the *streaming fused top-k* path
          (``kernels.ops.zen_topk``): the estimator and a running top-k are
          fused over index tiles, so per-query peak memory is one tile —
          O(chunk + n_neighbors), flat in index size — instead of the dense
          (Q, N) estimator matrix. Sharded indexes run the same streaming
          search per device shard (``distributed.sharded_knn_search``) and
          merge the (Q, n_shards * k) candidate pool host-side. An optional
          exact re-rank of the candidate pool with true distances follows
          (paper [50]'s deployment pattern).

CLI (CPU demo):  PYTHONPATH=src python -m repro.launch.serve --n 20000 --dim \
                 256 --k 16 --queries 64
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import metrics as metrics_lib
from repro.core import zen as zen_lib
from repro.core.projection import NSimplexTransform, select_references
from repro.distributed import retrieval as retrieval_lib
from repro.kernels import ops as kernel_ops

Array = jax.Array


@dataclasses.dataclass
class ZenIndex:
    transform: NSimplexTransform
    coords: Array            # (N, k) apex coordinates (possibly sharded)
    corpus: Optional[Array]  # original vectors for re-ranking (optional)
    mesh: Optional[object] = None  # device mesh when coords are row-sharded
    n_valid: Optional[int] = None  # real rows when coords are shard-padded

    @property
    def size(self) -> int:
        return self.n_valid if self.n_valid is not None else self.coords.shape[0]


def build_index(
    corpus: Array,
    k: int,
    *,
    metric: str = "euclidean",
    key: Optional[jax.Array] = None,
    mesh=None,
    keep_corpus: bool = True,
) -> ZenIndex:
    """Fit on the corpus (witness = corpus sample) and project every row."""
    key = key if key is not None else jax.random.PRNGKey(0)
    tr = select_references(corpus, k, key, metric=metric)
    coords = tr.transform(corpus)
    n_valid = None
    if mesh is not None:
        # pad once to a shard-divisible row count so every query batch skips
        # the O(N) re-pad; the search masks rows >= n_valid
        n_valid = coords.shape[0]
        n_shards = 1
        for a in mesh.axis_names:
            n_shards *= mesh.shape[a]
        pad = (-n_valid) % n_shards
        if pad:
            coords = jnp.pad(coords, ((0, pad), (0, 0)))
        rows = tuple(mesh.axis_names)  # shard rows over the whole mesh
        coords = jax.device_put(coords, NamedSharding(mesh, P(rows, None)))
    return ZenIndex(transform=tr, coords=coords,
                    corpus=corpus if keep_corpus else None, mesh=mesh,
                    n_valid=n_valid)


class ZenServer:
    """Batched k-NN serving over a reduced index.

    The search path never materialises a (Q, N) estimator matrix: single-host
    indexes stream through ``core.zen.knn_search`` (fused Pallas kernel on
    TPU, bounded-memory scan elsewhere) once the index exceeds ``chunk`` rows;
    mesh-sharded indexes run the streaming search per shard and merge the
    per-shard candidates host-side.
    """

    def __init__(self, index: ZenIndex, *, mode: str = "zen",
                 rerank_factor: int = 0, chunk: int = 8192,
                 force_kernel: bool = False):
        self.index = index
        self.mode = mode
        self.rerank_factor = rerank_factor
        self.chunk = chunk
        self.force_kernel = force_kernel
        self._stats = {"queries": 0, "batches": 0, "latency_s": []}

    def query(self, queries: Array, n_neighbors: int = 10
              ) -> Tuple[Array, Array]:
        """(Q, m) raw queries -> (distances, ids), each (Q, n_neighbors)."""
        t0 = time.time()
        qp = self.index.transform.transform(queries)
        n_fetch = n_neighbors * max(self.rerank_factor, 1)
        if self.index.mesh is not None:
            d, ids = retrieval_lib.sharded_knn_search(
                qp, self.index.coords,
                n_neighbors=min(n_fetch, self.index.size), mode=self.mode,
                mesh=self.index.mesh, chunk=self.chunk,
                force_kernel=self.force_kernel, n_valid=self.index.n_valid,
            )
        else:
            d, ids = zen_lib.knn_search(
                qp, self.index.coords,
                n_neighbors=min(n_fetch, self.index.size), mode=self.mode,
                chunk=self.chunk if self.index.size > self.chunk else 0,
                force_kernel=self.force_kernel,
            )
        if self.rerank_factor and self.index.corpus is not None:
            d, ids = self._rerank(queries, ids, n_neighbors)
        else:
            d, ids = d[:, :n_neighbors], ids[:, :n_neighbors]
        self._stats["queries"] += int(queries.shape[0])
        self._stats["batches"] += 1
        self._stats["latency_s"].append(time.time() - t0)
        return d, ids

    def _rerank(self, queries: Array, cand_ids: Array, n_neighbors: int
                ) -> Tuple[Array, Array]:
        """Exact re-rank of the Zen candidate pool with true distances."""
        cands = self.index.corpus[cand_ids]          # (Q, C, m)
        m = metrics_lib.get_metric(self.index.transform.metric)
        qn = m.normalize(queries) if m.normalize is not None else queries
        cn = m.normalize(cands) if m.normalize is not None else cands
        d = jnp.linalg.norm(
            qn[:, None, :].astype(jnp.float32) - cn.astype(jnp.float32), axis=-1
        )
        dd, pos = jax.lax.top_k(-d, n_neighbors)
        return -dd, jnp.take_along_axis(cand_ids, pos, axis=1)

    def stats(self) -> dict:
        lat = np.asarray(self._stats["latency_s"] or [0.0])
        return {
            "queries": self._stats["queries"],
            "batches": self._stats["batches"],
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
        }


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=20000)
    p.add_argument("--dim", type=int, default=256)
    p.add_argument("--k", type=int, default=16)
    p.add_argument("--queries", type=int, default=64)
    p.add_argument("--batches", type=int, default=4)
    p.add_argument("--neighbors", type=int, default=10)
    p.add_argument("--metric", default="euclidean")
    p.add_argument("--rerank", type=int, default=4)
    args = p.parse_args()

    from repro.core import quality
    from repro.data import synthetic as syn

    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, args.n, args.dim, args.dim // 8)
    index = build_index(corpus, args.k, metric=args.metric)
    server = ZenServer(index, rerank_factor=args.rerank)
    print(f"index: {index.size} x {args.k} (from dim {args.dim})")

    qkey = jax.random.fold_in(key, 1)
    recalls = []
    for b in range(args.batches):
        q = syn.manifold_space(jax.random.fold_in(qkey, b), args.queries,
                               args.dim, args.dim // 8)
        d, ids = server.query(q, args.neighbors)
        true_d = metrics_lib.pairwise(args.metric, q, corpus)
        _, true_ids = jax.lax.top_k(-true_d, args.neighbors)
        hit = np.mean([
            len(set(np.asarray(ids)[i]) & set(np.asarray(true_ids)[i]))
            / args.neighbors
            for i in range(args.queries)
        ])
        recalls.append(hit)
    print(f"recall@{args.neighbors}: {np.mean(recalls):.3f}")
    print("latency:", server.stats())


if __name__ == "__main__":
    main()
