"""nSimplex-Zen retrieval serving — the paper's technique as a production
feature (DESIGN.md §3).

Offline:  ``build_index`` fits the transform on a witness sample, projects the
          corpus to (N, k) apex coordinates (one pdist + one triangular solve,
          both kernel paths), and optionally row-shards the reduced index over
          a mesh.
Online:   ``ZenServer.query`` projects a query batch (k reference distances)
          and scores it with the *streaming fused top-k* path
          (``kernels.ops.zen_topk``): the estimator and a running top-k are
          fused over index tiles, so per-query peak memory is one tile —
          O(chunk + n_neighbors), flat in index size — instead of the dense
          (Q, N) estimator matrix. Sharded indexes run the same streaming
          search per device shard (``distributed.sharded_knn_search``) and
          merge the (Q, n_shards * k) candidate pool host-side. An optional
          exact re-rank of the candidate pool with true distances follows
          (paper [50]'s deployment pattern).

``build_index(..., index="ivf")`` swaps the flat scan for the *clustered* IVF
path (``repro.index``): a k-means coarse quantizer over the apex coordinates
plus padded inverted-list tiles, so each query scores only its ``nprobe``
nearest clusters — sublinear in N — at a recall knob the server exposes as
``ZenServer(nprobe=...)``. ``nprobe = n_clusters`` recovers the flat result.

CLI (CPU demo):  PYTHONPATH=src python -m repro.launch.serve --n 20000 --dim \
                 256 --k 16 --queries 64 [--index ivf --nprobe 8]
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import metrics as metrics_lib
from repro.core import zen as zen_lib
from repro.core.projection import NSimplexTransform, select_references
from repro.distributed import retrieval as retrieval_lib
from repro.kernels import ops as kernel_ops

Array = jax.Array


@dataclasses.dataclass
class ZenIndex:
    transform: NSimplexTransform
    coords: Array            # (N, k) apex coordinates (possibly sharded)
    corpus: Optional[Array]  # original vectors for re-ranking (optional)
    mesh: Optional[object] = None  # device mesh when coords are row-sharded
    n_valid: Optional[int] = None  # real rows when coords are shard-padded
    ivf: Optional[object] = None   # IVFZenIndex / ShardedIVFZenIndex

    @property
    def size(self) -> int:
        return self.n_valid if self.n_valid is not None else self.coords.shape[0]


def build_index(
    corpus: Array,
    k: int,
    *,
    metric: str = "euclidean",
    key: Optional[jax.Array] = None,
    mesh=None,
    keep_corpus: bool = True,
    index: str = "flat",
    n_clusters: Optional[int] = None,
    tile_rows: int = 128,
    kmeans_iters: int = 15,
) -> ZenIndex:
    """Fit on the corpus (witness = corpus sample) and project every row.

    ``index="flat"`` keeps the (N, k) coordinates for the streaming scan;
    ``index="ivf"`` additionally fits a k-means coarse quantizer
    (``n_clusters`` defaults to ~4*sqrt(N)) and packs the inverted-list
    tiles so the server probes only a few clusters per query. With a
    ``mesh``, both variants shard rows (flat coordinates or inverted lists)
    over all mesh axes.
    """
    if index not in ("flat", "ivf"):
        raise ValueError(f"index must be 'flat' or 'ivf', got {index!r}")
    key = key if key is not None else jax.random.PRNGKey(0)
    tr = select_references(corpus, k, key, metric=metric)
    coords = tr.transform(corpus)
    n = coords.shape[0]
    ivf = None
    if index == "ivf":
        from repro.index import IVFZenIndex, ShardedIVFZenIndex

        n_clusters = n_clusters or max(1, min(n, int(round(4 * n ** 0.5))))
        builder = (
            functools.partial(ShardedIVFZenIndex.build, mesh=mesh)
            if mesh is not None else IVFZenIndex.build
        )
        ivf = builder(
            coords, n_clusters, tile_rows=tile_rows, n_iters=kmeans_iters,
            key=jax.random.fold_in(key, 7),
        )
    n_valid = None
    if mesh is not None and ivf is None:
        # pad once to a shard-divisible row count so every query batch skips
        # the O(N) re-pad; the search masks rows >= n_valid
        n_valid = coords.shape[0]
        n_shards = 1
        for a in mesh.axis_names:
            n_shards *= mesh.shape[a]
        pad = (-n_valid) % n_shards
        if pad:
            coords = jnp.pad(coords, ((0, pad), (0, 0)))
        rows = tuple(mesh.axis_names)  # shard rows over the whole mesh
        coords = jax.device_put(coords, NamedSharding(mesh, P(rows, None)))
    return ZenIndex(transform=tr, coords=coords,
                    corpus=corpus if keep_corpus else None, mesh=mesh,
                    n_valid=n_valid, ivf=ivf)


class ZenServer:
    """Batched k-NN serving over a reduced index.

    The search path never materialises a (Q, N) estimator matrix: single-host
    indexes stream through ``core.zen.knn_search`` (fused Pallas kernel on
    TPU, bounded-memory scan elsewhere) once the index exceeds ``chunk`` rows;
    mesh-sharded indexes run the streaming search per shard and merge the
    per-shard candidates host-side. IVF-built indexes probe only the
    ``nprobe`` nearest clusters per query (``repro.index``) — sublinear in
    index size, with ``nprobe`` as the recall/latency knob.
    """

    def __init__(self, index: ZenIndex, *, mode: str = "zen",
                 rerank_factor: int = 0, chunk: int = 8192,
                 nprobe: int = 8, force_kernel: bool = False):
        self.index = index
        self.mode = mode
        self.rerank_factor = rerank_factor
        self.chunk = chunk
        self.nprobe = nprobe
        self.force_kernel = force_kernel
        self._stats = {"queries": 0, "batches": 0, "latency_s": []}

    def query(self, queries: Array, n_neighbors: int = 10
              ) -> Tuple[Array, Array]:
        """(Q, m) raw queries -> (distances, ids), each (Q, n_neighbors)."""
        t0 = time.time()
        qp = self.index.transform.transform(queries)
        n_fetch = n_neighbors * max(self.rerank_factor, 1)
        if self.index.ivf is not None:
            d, ids = self.index.ivf.search(
                qp, n_neighbors=min(n_fetch, self.index.size),
                nprobe=self.nprobe, mode=self.mode,
                force_kernel=self.force_kernel,
            )
        elif self.index.mesh is not None:
            d, ids = retrieval_lib.sharded_knn_search(
                qp, self.index.coords,
                n_neighbors=min(n_fetch, self.index.size), mode=self.mode,
                mesh=self.index.mesh, chunk=self.chunk,
                force_kernel=self.force_kernel, n_valid=self.index.n_valid,
            )
        else:
            d, ids = zen_lib.knn_search(
                qp, self.index.coords,
                n_neighbors=min(n_fetch, self.index.size), mode=self.mode,
                chunk=self.chunk if self.index.size > self.chunk else 0,
                force_kernel=self.force_kernel,
            )
        if self.rerank_factor and self.index.corpus is not None:
            d, ids = self._rerank(queries, ids, n_neighbors)
        else:
            d, ids = d[:, :n_neighbors], ids[:, :n_neighbors]
        self._stats["queries"] += int(queries.shape[0])
        self._stats["batches"] += 1
        self._stats["latency_s"].append(time.time() - t0)
        return d, ids

    def _rerank(self, queries: Array, cand_ids: Array, n_neighbors: int
                ) -> Tuple[Array, Array]:
        """Exact re-rank of the Zen candidate pool with true distances."""
        from repro.index import exact_rerank

        return exact_rerank(
            queries, self.index.corpus, cand_ids, n_neighbors,
            metric=self.index.transform.metric,
        )

    def stats(self) -> dict:
        lat = np.asarray(self._stats["latency_s"] or [0.0])
        return {
            "queries": self._stats["queries"],
            "batches": self._stats["batches"],
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
        }


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=20000)
    p.add_argument("--dim", type=int, default=256)
    p.add_argument("--k", type=int, default=16)
    p.add_argument("--queries", type=int, default=64)
    p.add_argument("--batches", type=int, default=4)
    p.add_argument("--neighbors", type=int, default=10)
    p.add_argument("--metric", default="euclidean")
    p.add_argument("--rerank", type=int, default=4)
    p.add_argument("--index", default="flat", choices=["flat", "ivf"])
    p.add_argument("--clusters", type=int, default=0,
                   help="IVF cluster count (0 = ~4*sqrt(N))")
    p.add_argument("--nprobe", type=int, default=8)
    args = p.parse_args()

    from repro.core import quality
    from repro.data import synthetic as syn

    key = jax.random.PRNGKey(0)
    corpus = syn.manifold_space(key, args.n, args.dim, args.dim // 8)
    index = build_index(corpus, args.k, metric=args.metric, index=args.index,
                        n_clusters=args.clusters or None)
    server = ZenServer(index, rerank_factor=args.rerank, nprobe=args.nprobe)
    print(f"index: {index.size} x {args.k} (from dim {args.dim})"
          + (f"; ivf: {index.ivf.n_clusters} clusters, nprobe={args.nprobe}"
             if index.ivf is not None else ""))

    qkey = jax.random.fold_in(key, 1)
    recalls = []
    for b in range(args.batches):
        q = syn.manifold_space(jax.random.fold_in(qkey, b), args.queries,
                               args.dim, args.dim // 8)
        d, ids = server.query(q, args.neighbors)
        true_d = metrics_lib.pairwise(args.metric, q, corpus)
        _, true_ids = jax.lax.top_k(-true_d, args.neighbors)
        hit = np.mean([
            len(set(np.asarray(ids)[i]) & set(np.asarray(true_ids)[i]))
            / args.neighbors
            for i in range(args.queries)
        ])
        recalls.append(hit)
    print(f"recall@{args.neighbors}: {np.mean(recalls):.3f}")
    print("latency:", server.stats())


if __name__ == "__main__":
    main()
