"""Analytic MODEL_FLOPS per cell — the 'useful compute' numerator of the
MODEL_FLOPS / HLO_FLOPs ratio in §Roofline (catches remat/redundancy waste).

Conventions:
* LM train:   6 * N_active * tokens  (fwd 2x + bwd 4x) + causal attention
              12 * L * B * S^2/2 * H * dh (score+out, fwd+bwd)
* LM prefill: 2 * N_active * tokens + attention fwd term
* LM decode:  2 * N_active * B  + 4 * L * B * S_cache * KV_eff * dh
* GNN train:  6 * (edge-path flops + node-mix flops)
* RecSys:     6x (train) or 2x (serve) the dense MLP/interaction flops;
              embedding GATHERS are bytes, not flops, and are excluded.

All values are GLOBAL (whole job); divide by n_devices when comparing with
per-device cost_analysis flops.
"""
from __future__ import annotations

from typing import Any


def estimate(plan) -> dict:
    fam = _family(plan)
    fn = {"lm": _lm, "gnn": _gnn, "recsys": _recsys}[fam]
    flops, n_params, n_active = fn(plan)
    return {
        "model_flops_global": float(flops),
        "param_count": int(n_params),
        "active_param_count": int(n_active),
    }


def _family(plan) -> str:
    mod = type(plan.cfg).__module__
    if "transformer" in mod:
        return "lm"
    if "mace" in mod:
        return "gnn"
    return "recsys"


def _lm(plan):
    cfg = plan.cfg
    from repro import configs as C

    cell = C.get_arch(plan.arch_id).cell(plan.shape)
    B, S = cell.dims["global_batch"], cell.dims["seq_len"]
    N = cfg.param_count()
    Na = cfg.active_param_count()
    L, H, dh, KV = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.n_kv_heads

    # attention fwd: QK^T + PV = 2 matmuls x 2 flops/MAC over S^2/2 causal
    # positions, per layer per batch row
    attn_fwd = 4 * L * B * (S * S / 2) * H * dh

    if plan.kind == "train":
        tokens = B * S
        dense = 6 * Na * tokens
        return dense + 3 * attn_fwd, N, Na       # bwd = 2x fwd
    if plan.kind == "prefill":
        tokens = B * S
        dense = 2 * Na * tokens
        return dense + attn_fwd, N, Na
    if plan.kind == "decode":
        dense = 2 * Na * B
        # one query against S cached positions, per layer; GQA contracts over
        # H query heads (kv replicated logically)
        eff_S = 0
        for w in cfg.layer_pattern:
            eff_S += min(w, S) if w else S
        eff_S /= len(cfg.layer_pattern)
        attn = 2 * 2 * L * B * eff_S * H * dh
        return dense + attn, N, Na
    raise ValueError(plan.kind)


def _gnn(plan):
    cfg = plan.cfg
    from repro import configs as C
    from repro.models.mace import _N_A_PATHS, _N_MSG0, _N_MSG1, _N_MSG2

    cell = C.get_arch(plan.arch_id).cell(plan.shape)
    E, Nn = cell.dims["n_edges"], cell.dims["n_nodes"]
    Ch = cfg.channels
    irrep = 1 + 3 + 9
    # per edge: radial MLP + path products + weighting
    rad = 2 * (cfg.n_rbf * cfg.radial_hidden + cfg.radial_hidden * Ch * _N_A_PATHS)
    paths = 40 * Ch            # ~#mul-adds across the 12 Cartesian paths
    per_edge = rad + paths
    # per node: B-basis products + channel mixing linears + self linears
    mix = 2 * Ch * Ch * (_N_MSG0 + 3 * _N_MSG1 + 9 * _N_MSG2 + irrep)
    corr = 120 * Ch
    per_node = mix + corr
    fwd = cfg.n_layers * (E * per_edge + Nn * per_node) + \
        2 * Nn * cfg.d_feat * Ch
    n_params = _count_params(cfg, "gnn")
    return 3 * fwd, n_params, n_params  # train: fwd + 2x bwd


def _recsys(plan):
    cfg = plan.cfg
    from repro import configs as C

    cell = C.get_arch(plan.arch_id).cell(plan.shape)
    B = cell.dims["batch"]
    F, d = cfg.n_sparse, cfg.embed_dim

    def mlp_flops(dims):
        return sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))

    per_ex = 0
    if cfg.model == "dlrm":
        per_ex += mlp_flops((cfg.n_dense,) + cfg.bot_mlp)
        nf = F + 1
        per_ex += 2 * nf * nf * d  # dot interaction
        per_ex += mlp_flops((nf * (nf - 1) // 2 + cfg.bot_mlp[-1],) + cfg.top_mlp)
    elif cfg.model == "autoint":
        di = d
        for _ in range(cfg.n_attn_layers):
            do = cfg.n_heads * cfg.d_attn
            per_ex += 4 * 2 * F * di * do + 2 * 2 * F * F * do
            di = do
        per_ex += 2 * F * di
    elif cfg.model == "wide_deep":
        per_ex += mlp_flops((F * d,) + cfg.mlp + (1,))
    elif cfg.model == "xdeepfm":
        hk = F
        for h in cfg.cin_layers:
            per_ex += 2 * hk * F * d + 2 * hk * F * h * d
            hk = h
        per_ex += mlp_flops((F * d,) + cfg.mlp + (1,))
    # per_ex already counts 2 flops/MAC; train = fwd + 2x bwd = 3x fwd
    mult = 3 if plan.kind == "train" else 1
    flops = mult * per_ex * B
    if plan.kind == "retrieval":
        flops = 2 * B * cell.dims["n_candidates"] * d
    n_params = cfg.total_rows * d
    return flops, n_params, n_params


def _count_params(cfg, family: str) -> int:
    import jax

    if family == "gnn":
        from repro.models.mace import init_params
        shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                                jax.random.PRNGKey(0))
        return sum(int(_prod(s.shape)) for s in jax.tree.leaves(shapes))
    raise ValueError(family)


def _prod(t):
    n = 1
    for x in t:
        n *= x
    return n
