"""Training entrypoint: any --arch on any mesh, with checkpoint/restart,
straggler monitoring, preemption-aware saves and optional gradient
compression.

CPU-scale usage (this container, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --steps 20 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt

On a real pod the same file runs with the production mesh (--mesh pod) and
full config; jax.distributed.initialize() is the only extra call (guarded by
--multihost). XLA flags for collective/compute overlap on TPU are recorded in
``TPU_PERF_FLAGS`` (applied when the backend is TPU).
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

# Latency-hiding / async-collective flags used on real TPU runs (documented
# for §Perf; harmless no-ops on CPU so they are not set here).
TPU_PERF_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=10)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--data-shards", type=int, default=1)
    p.add_argument("--model-shards", type=int, default=1)
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--multihost", action="store_true")
    args = p.parse_args()

    if args.multihost:  # pragma: no cover - real-cluster path
        jax.distributed.initialize()

    from repro import configs as C
    from repro.checkpoint import CheckpointManager
    from repro.data import synthetic as syn
    from repro.data.pipeline import PrefetchPipeline
    from repro.distributed import sharding as shard_lib
    from repro.distributed.fault import PreemptionGuard, StepMonitor
    from repro.launch.mesh import make_host_mesh
    from repro.models import mace as mace_lib
    from repro.models import recsys as recsys_lib
    from repro.models import transformer as tfm
    from repro.optim import AdamW, CompressionState
    from repro.optim import compression as comp_lib

    spec = C.get_arch(args.arch)
    cfg = spec.make_reduced() if args.reduced else spec.make_config()
    mesh = make_host_mesh(args.data_shards, args.model_shards)

    key = jax.random.PRNGKey(args.seed)
    if spec.family == "lm":
        init, loss_fn = tfm.init_params, tfm.loss_fn
        make_batch = lambda step: syn.lm_batch(
            args.seed, step, args.batch, args.seq, cfg.vocab_size)
    elif spec.family == "recsys":
        init, loss_fn = recsys_lib.init_params, recsys_lib.loss_fn
        make_batch = lambda step: syn.recsys_batch(
            args.seed, step, args.batch, cfg.vocab_sizes, cfg.n_dense)
    else:
        init, loss_fn = mace_lib.init_params, mace_lib.loss_fn
        make_batch = lambda step: dict(
            syn.geometric_graph_batch(
                args.seed + step, n_nodes=16 * args.batch,
                n_edges=48 * args.batch, d_feat=cfg.d_feat,
                n_graphs=args.batch),
            n_graphs=args.batch)

    params = init(cfg, key)
    opt = AdamW(learning_rate=3e-4)
    opt_state = opt.init(params)
    comp_state = None
    if args.compress_grads:
        comp_state = comp_lib.init_state(params)

    pspecs = shard_lib.param_specs(spec.family, jax.eval_shape(lambda: params))
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        start_step, (params, opt_state) = ckpt.restore(
            mesh=mesh if args.data_shards * args.model_shards > 1 else None,
            like=(params, opt_state),
        )
        print(f"resumed from step {start_step}")

    @jax.jit
    def train_step(params, opt_state, comp_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        if comp_state is not None:
            grads, comp_state = comp_lib.error_feedback_update(grads, comp_state)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return params, opt_state, comp_state, loss

    monitor = StepMonitor()
    guard = PreemptionGuard(install_signal=True)
    pipeline = PrefetchPipeline(make_batch, start_step=start_step)
    try:
        for _ in range(args.steps - start_step):
            step, batch = next(pipeline)
            t0 = time.time()
            params, opt_state, comp_state, loss = train_step(
                params, opt_state, comp_state, batch)
            loss = float(loss)
            dt = time.time() - t0
            ev = monitor.record(step, dt)
            if ev:
                print(f"straggler flagged at step {step}: "
                      f"{ev.ratio:.1f}x EMA ({ev.step_time:.2f}s)")
            if monitor.should_escalate and ckpt:
                print("straggler patience exhausted -> checkpoint + escalate")
                ckpt.save(step + 1, (params, opt_state),
                          (pspecs, shard_lib.opt_state_specs(pspecs)))
            if not np.isfinite(loss):
                raise FloatingPointError(f"loss diverged at step {step}")
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step}: loss={loss:.4f} ({dt*1e3:.0f} ms)")
            if ckpt and (
                (step + 1) % args.ckpt_every == 0 or guard.should_save()
            ):
                ckpt.save_async(step + 1, (params, opt_state),
                                (pspecs, shard_lib.opt_state_specs(pspecs)))
                if guard.should_save():
                    ckpt.wait()
                    print(f"preemption save at step {step + 1}")
                    break
    finally:
        pipeline.close()
        if ckpt:
            ckpt.wait()
    print("done")


if __name__ == "__main__":
    main()
