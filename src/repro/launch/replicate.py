"""Replicated query-plane serving: one index owner, N hot-swapping replicas.

The paper's transform is fitted once and then applied out-of-sample from
reference distances alone, so the fitted index is a read-mostly artifact —
the natural production shape (ROADMAP item 5) is a single **leader** that
owns churn and N **query-plane replicas** that only serve. This module is
that split, built entirely on the existing primitives:

* :class:`IndexLeader` wraps the one mutable ``ZenServer``. Churn goes
  through it (``upsert``/``delete``/``compact``); ``publish()`` writes the
  full serving state as an atomic versioned snapshot
  (``ZenServer.save`` -> ``checkpoint.index_io``) into a per-generation
  directory under the publish root, then atomically replaces the
  ``PUBLISHED.json`` pointer (``index_io.write_json_atomic``). The pointer
  is written strictly *after* the snapshot directory is complete, so a
  leader killed mid-publish leaves the previous pointer aimed at the
  previous — fully loadable — snapshot; the half-written attempt is a
  ``tmp.*`` sibling no reader ever follows.

* :class:`QueryReplica` watches the publish root. ``poll()`` reads the
  pointer and, on a new generation, loads the snapshot into a fresh
  ``ZenIndex`` (``serve.load_index_snapshot``, optionally ``mmap=True``
  and/or over a published tile pool for the tiered store) and swaps it
  under its long-lived ``ZenServer``. The swap is a single attribute
  assignment: in-flight queries already hold the old ``ZenIndex`` snapshot
  (``_query_block`` reads ``server.index`` exactly once per dispatch), and
  the replica additionally *pins* each generation with an in-flight
  counter so the old index — and any mmap'd files backing it — is released
  only after its last query resolves, never under one.

**Generation is the coherence key.** The published snapshot carries the
leader's monotonic ``generation`` churn counter, the restored index serves
under it (not a local counter restarted at 0), and the frontend result
cache keys every entry on it — so a pre-swap cache entry is structurally
unreachable after a hot-swap, on every replica, with no invalidation
message. ``MicroBatchScheduler.on_index_swap`` additionally evicts the
dead entries so they stop occupying LRU capacity.

Replicas are pull-based and may lag (a lagging replica keeps serving its
old generation — correct, just stale); the leader observes the fleet via
``distributed.fault.ReplicaTracker`` and hands off cleanly on preemption
(``enable_preemption``: publish one final snapshot, then refuse churn).

Deterministic simulation coverage lives in ``tests/test_replication.py``;
the open-loop SLO harness that drives replica fleets under offered load is
``repro.serving.loadgen``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import index_io
from repro.checkpoint.index_io import CheckpointFormatError
from repro.launch.serve import ZenServer, load_index_snapshot

#: pointer file the replicas watch, at the publish root
PUBLISH_POINTER = "PUBLISHED.json"
#: pointer format tag / version (checked by readers; never reuse the tag)
PUBLISH_FORMAT = "zen-publish"
PUBLISH_VERSION = 1


class LeaderHandedOff(RuntimeError):
    """Churn refused: the leader already published its handoff snapshot."""


class ReplicaNotReady(RuntimeError):
    """Query refused: the replica has not swapped to any snapshot yet."""


class PublishedSnapshot(NamedTuple):
    """One resolved publish-pointer target."""

    generation: int
    snapshot: str             # server snapshot directory (absolute)
    pool: Optional[str]       # tile-pool snapshot directory, when published


def _gen_dirname(generation: int) -> str:
    # zero-padded so lexicographic order == generation order (ls-friendly)
    return f"gen-{int(generation):012d}"


def read_pointer(root: str) -> Optional[PublishedSnapshot]:
    """Resolve the publish pointer under ``root``; ``None`` before the
    first publish. Raises :class:`CheckpointFormatError` for a pointer
    written by an unknown format/version (never guess at a layout)."""
    path = os.path.join(root, PUBLISH_POINTER)
    try:
        with open(path) as f:
            ptr = json.load(f)
    except FileNotFoundError:
        return None
    if (ptr.get("format") != PUBLISH_FORMAT
            or ptr.get("version") != PUBLISH_VERSION):
        raise CheckpointFormatError(
            f"{path}: publish pointer format "
            f"{ptr.get('format')!r} v{ptr.get('version')!r}, expected "
            f"{PUBLISH_FORMAT!r} v{PUBLISH_VERSION}")
    pool = ptr.get("pool")
    return PublishedSnapshot(
        generation=int(ptr["generation"]),
        snapshot=os.path.join(root, ptr["snapshot"]),
        pool=None if pool is None else os.path.join(root, pool),
    )


class IndexLeader:
    """The index owner: applies churn, publishes snapshots, tracks the fleet.

    Args:
      server:       the one mutable ``ZenServer`` (flat or resident IVF).
      root:         publish root directory (created on first publish).
      keep:         published generations retained after each publish (the
                    pointer target is always kept; older directories are
                    pruned — POSIX keeps the inodes alive for any lagging
                    replica that still mmaps them).
      publish_pool: also publish the IVF tier as a ``TieredIVFZenIndex``
                    tile-pool snapshot next to each server snapshot
                    (``<gen>.pool``), so replicas can serve the cold tiles
                    straight off the mmap'd files (resident-IVF leaders
                    only; the pool rides the same generation + pointer).
    """

    def __init__(self, server: ZenServer, root: str, *, keep: int = 2,
                 publish_pool: bool = False):
        if keep < 1:
            raise ValueError("keep must be >= 1 (the published snapshot)")
        if publish_pool and (server.index.ivf is None
                             or server.index._is_tiered()):
            raise ValueError(
                "publish_pool=True needs a resident IVF leader index (the "
                "pool is packed from the leader's inverted lists)")
        self.server = server
        self.root = os.path.abspath(root)
        self.keep = int(keep)
        self.publish_pool = bool(publish_pool)
        self.handed_off = False
        self.preemption = None           # PreemptionGuard (enable_preemption)
        self.replicas = None             # ReplicaTracker (track_replicas)
        self._published: Optional[PublishedSnapshot] = None

    # -- state ----------------------------------------------------------------
    @property
    def generation(self) -> int:
        """The live (possibly not yet published) churn generation."""
        return int(self.server.index.generation)

    @property
    def published_generation(self) -> Optional[int]:
        pub = self._published or read_pointer(self.root)
        return None if pub is None else pub.generation

    # -- churn (refused after handoff) ----------------------------------------
    def _check_owner(self) -> None:
        if self.handed_off:
            raise LeaderHandedOff(
                "this leader published its handoff snapshot (preemption); "
                "churn must move to the successor")

    def upsert(self, ids: Sequence[int], vectors) -> None:
        self._check_owner()
        self.server.upsert(ids, vectors)

    def delete(self, ids: Sequence[int]) -> None:
        self._check_owner()
        self.server.delete(ids)

    def compact(self, **kw) -> None:
        self._check_owner()
        self.server.compact(**kw)

    def maybe_compact(self, **thresholds) -> bool:
        self._check_owner()
        return self.server.maybe_compact(**thresholds)

    # -- publish ---------------------------------------------------------------
    def publish(self) -> PublishedSnapshot:
        """Atomically publish the current index state under its generation.

        Write order is the crash-safety argument: (1) the snapshot
        directory (itself tmp+fsync+rename atomic), (2) the pool when
        enabled, (3) the pointer (atomic file replace). A crash anywhere
        leaves the pointer aimed at a complete earlier snapshot; republish
        of the *same* generation is idempotent.
        """
        gen = self.generation
        os.makedirs(self.root, exist_ok=True)
        snap = os.path.join(self.root, _gen_dirname(gen))
        self.server.save(snap)
        pool = None
        if self.publish_pool:
            from repro.index.ivf import TieredIVFZenIndex

            tiered = TieredIVFZenIndex.from_index(self.server.index.ivf)
            # pool coherence rides the *wrapper* generation (the cache key),
            # not the inner IVF counter from_index propagated
            tiered.generation = gen
            pool = snap + ".pool"
            tiered.save(pool)
        index_io.write_json_atomic(
            os.path.join(self.root, PUBLISH_POINTER),
            {
                "format": PUBLISH_FORMAT,
                "version": PUBLISH_VERSION,
                "generation": gen,
                "snapshot": os.path.basename(snap),
                "pool": None if pool is None else os.path.basename(pool),
            },
        )
        self._published = PublishedSnapshot(gen, snap, pool)
        self._prune()
        return self._published

    def _prune(self) -> None:
        """Drop published generations beyond ``keep`` (never the pointer's)."""
        assert self._published is not None
        gens = sorted(
            (name for name in os.listdir(self.root)
             if name.startswith("gen-") and not name.endswith(".pool")),
            reverse=True)
        current = os.path.basename(self._published.snapshot)
        for name in gens[self.keep:]:
            if name == current:
                continue
            shutil.rmtree(os.path.join(self.root, name), ignore_errors=True)
            shutil.rmtree(os.path.join(self.root, name + ".pool"),
                          ignore_errors=True)

    # -- preemption handoff ----------------------------------------------------
    def enable_preemption(self, *, install_signal: bool = False):
        """Attach a ``PreemptionGuard``; check it via :meth:`maybe_handoff`."""
        from repro.distributed.fault import PreemptionGuard

        self.preemption = PreemptionGuard(install_signal=install_signal)
        return self.preemption

    def maybe_handoff(self) -> bool:
        """Publish-and-retire when the platform announced preemption.

        Returns True when the handoff ran: one final snapshot of the
        current generation is published (replicas keep serving, a successor
        leader loads it and resumes churn from the same counter) and every
        later churn call raises :class:`LeaderHandedOff`. Call this from
        the leader's control loop — e.g. once per churn batch.
        """
        guard = self.preemption
        if guard is None or not guard.should_save() or self.handed_off:
            return False
        self.publish()
        self.handed_off = True
        guard.clear()
        return True

    # -- fleet observation -----------------------------------------------------
    def track_replicas(self, *, deadline_s: float = 60.0, clock=None):
        """Attach a ``distributed.fault.ReplicaTracker`` for the fleet."""
        from repro.distributed.fault import ReplicaTracker

        kw = {"now": clock} if clock is not None else {}
        self.replicas = ReplicaTracker(deadline_s=deadline_s, **kw)
        return self.replicas

    def replica_report(self, replica: str, generation: int) -> None:
        """One replica status beat (its currently served generation)."""
        if self.replicas is None:
            raise RuntimeError("call track_replicas() first")
        self.replicas.report(replica, generation)

    def fleet_status(self) -> dict:
        """Liveness + lag of every reporting replica vs the last publish."""
        if self.replicas is None:
            raise RuntimeError("call track_replicas() first")
        pub = self.published_generation
        return self.replicas.status(-1 if pub is None else pub)


class _PinnedIndex:
    """One fully swapped-in index generation + its in-flight query count."""

    __slots__ = ("generation", "index", "inflight")

    def __init__(self, generation: int, index):
        self.generation = generation
        self.index = index
        self.inflight = 0


class QueryReplica:
    """A query-plane replica: watches the publish root, hot-swaps, serves.

    The replica owns one long-lived ``ZenServer`` (constructed from the
    saved server config at the first successful :meth:`poll`, with
    ``server_kw`` overrides — e.g. ``frontend=True, cache_size=...``).
    Swaps replace only ``server.index``, so the frontend scheduler, its
    stats, and its generation-keyed result cache survive across
    generations; queries in flight during a swap finish on the index they
    started on (pinned until their last row resolves) and a generation is
    never served before its snapshot is *fully* loaded — the swap is the
    publication point.

    ``poll()`` is explicitly non-throwing for torn or vanished publishes:
    a replica that cannot load the new pointer target keeps serving its
    current generation and counts the error (``poll_errors``), which is
    exactly the lagging-replica behaviour the leader's ``ReplicaTracker``
    surfaces.

    Args:
      root:      publish root (shared with the leader).
      name:      replica name used in ``stats()`` / fleet reports.
      mmap:      load snapshots with read-only memory-mapping.
      use_pool:  serve the IVF tier from the published tile pool when the
                 pointer advertises one (tiered mmap'd store).
      pool_kw:   extra ``TieredIVFZenIndex.load`` options.
      server_kw: ``ZenServer`` construction overrides on top of the saved
                 server config.
    """

    def __init__(self, root: str, *, name: str = "replica",
                 mmap: bool = False, use_pool: bool = False,
                 pool_kw: Optional[dict] = None, **server_kw):
        self.root = os.path.abspath(root)
        self.name = str(name)
        self.mmap = bool(mmap)
        self.use_pool = bool(use_pool)
        self.pool_kw = dict(pool_kw or {})
        self.server_kw = dict(server_kw)
        self.server: Optional[ZenServer] = None
        self.swaps = 0
        self.poll_errors = 0
        self.last_error: Optional[str] = None
        self._lock = threading.Lock()
        self._current: Optional[_PinnedIndex] = None
        self._retired: list[_PinnedIndex] = []        # pinned by in-flight
        self._released: list[int] = []                # fully released gens

    # -- swap protocol ---------------------------------------------------------
    @property
    def generation(self) -> Optional[int]:
        """Generation currently served; ``None`` before the first swap."""
        cur = self._current
        return None if cur is None else cur.generation

    def poll(self) -> bool:
        """Check the publish pointer; hot-swap when it moved forward.

        Returns True iff a swap happened. Never raises on a torn/missing
        publish — the replica keeps serving what it has (see class doc).
        """
        try:
            pub = read_pointer(self.root)
        except (CheckpointFormatError, json.JSONDecodeError, OSError) as e:
            self.poll_errors += 1
            self.last_error = repr(e)
            return False
        if pub is None:
            return False
        cur = self._current
        if cur is not None and pub.generation <= cur.generation:
            return False  # nothing newer (a pointer never moves backwards)
        try:
            index, saved_kw = load_index_snapshot(
                pub.snapshot, mmap=self.mmap,
                pool=pub.pool if self.use_pool else None,
                pool_kw=self.pool_kw if self.use_pool else None)
        except (FileNotFoundError, CheckpointFormatError, ValueError,
                KeyError, OSError) as e:
            # torn publish / pruned-under-us snapshot: serve on, stay lagged
            self.poll_errors += 1
            self.last_error = repr(e)
            return False
        # --- the swap: only now does the new generation become servable ---
        with self._lock:
            if self.server is None:
                kw = dict(saved_kw)
                kw.update(self.server_kw)
                self.server = ZenServer(index, **kw)
            else:
                self.server.index = index
            old = self._current
            self._current = _PinnedIndex(int(index.generation), index)
            if old is not None:
                self._retired.append(old)
            self._release_idle_locked()
            self.swaps += 1
            frontend = self.server.frontend
        if frontend is not None:
            frontend.on_index_swap(int(index.generation))
        return True

    # -- serving with generation pinning ---------------------------------------
    def query(self, queries, n_neighbors: int = 10, *,
              direct: bool = False) -> Tuple[np.ndarray, np.ndarray]:
        """Serve one batch, pinning the serving generation while in flight.

        The pin guarantees an index (and the mmap'd snapshot files backing
        it) outlives every query that may read it: a hot-swap during this
        call retires the old generation but cannot release it until the
        pin drops.
        """
        with self._lock:
            if self.server is None or self._current is None:
                raise ReplicaNotReady(
                    f"replica {self.name!r}: no published snapshot swapped "
                    "in yet (poll() after the leader's first publish)")
            pinned = self._current
            pinned.inflight += 1
            server = self.server
        try:
            return server.query(queries, n_neighbors, direct=direct)
        finally:
            with self._lock:
                pinned.inflight -= 1
                self._release_idle_locked()

    def _release_idle_locked(self) -> None:
        """Release retired generations whose last in-flight query resolved."""
        still = []
        for pin in self._retired:
            if pin.inflight == 0:
                self._released.append(pin.generation)
                pin.index = None  # drop the (possibly mmap-backed) arrays
            else:
                still.append(pin)
        self._retired = still

    # -- observability ---------------------------------------------------------
    def pinned_generations(self) -> Tuple[int, ...]:
        """Generations still alive: the serving one + retired-but-in-flight."""
        with self._lock:
            gens = [] if self._current is None else [self._current.generation]
            gens.extend(pin.generation for pin in self._retired)
            return tuple(sorted(gens))

    def released_generations(self) -> Tuple[int, ...]:
        """Retired generations fully released (no in-flight pins left)."""
        with self._lock:
            return tuple(self._released)

    def stats(self) -> dict:
        out = {
            "name": self.name,
            "generation": self.generation,
            "swaps": self.swaps,
            "poll_errors": self.poll_errors,
            "pinned_generations": list(self.pinned_generations()),
        }
        if self.server is not None:
            out["server"] = self.server.stats()
        return out
