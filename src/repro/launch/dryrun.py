import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract the roofline terms.

The two lines above MUST stay the first statements in this module — jax locks
the device count at first init, and the 16x16 / 2x16x16 meshes need 512
placeholder host devices. This flag is set ONLY here (smoke tests and benches
see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Artifacts: benchmarks/artifacts/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, the per-collective byte breakdown parsed from
the partitioned HLO, and wall times. EXPERIMENTS.md §Dry-run and §Roofline are
generated from these artifacts.
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Total bytes of every typed shape literal in an HLO result spec."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-collective byte totals from the partitioned (per-device) HLO.

    Bytes are the RESULT shapes of each collective op — the standard proxy for
    data moved per device per op (cost_analysis does not expose this).
    """
    out: dict = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        result_spec, opname = m.groups()
        base = opname
        if base.endswith("-start") or base.endswith("-done"):
            base = base.rsplit("-", 1)[0]
        if base in out:
            if opname.endswith("-done"):
                continue  # counted at -start
            out[base]["count"] += 1
            out[base]["bytes"] += _shape_bytes(result_spec)
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _parse_variant(variant: str) -> dict:
    """'unroll_layers=True,n_microbatches=4' -> typed dict."""
    out = {}
    if not variant:
        return out
    for item in variant.split(","):
        k, v = item.split("=")
        if v in ("True", "False"):
            out[k] = v == "True"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, artifact_dir: str,
             variant: str = "") -> dict:
    import jax  # after XLA_FLAGS

    from repro import configs as configs_lib
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_plan

    multi_pod = mesh_kind == "multipod"
    record: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "n_devices": 512 if multi_pod else 256,
    }
    if variant:
        record["variant"] = variant
        record["arch"] = f"{arch}@{variant}"
    plan = build_plan(arch, shape, multi_pod=multi_pod,
                      overrides=_parse_variant(variant))
    record["kind"] = plan.kind
    if plan.skip:
        record["status"] = "skipped"
        record["skip_reason"] = plan.skip
        _write(record, artifact_dir)
        print(f"SKIP {arch}/{shape}/{mesh_kind}: {plan.skip}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = plan.lower(mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    if os.environ.get("DRYRUN_DUMP_HLO"):
        dump = os.path.join(artifact_dir,
                            f"{record['arch']}__{shape}__{mesh_kind}.hlo")
        with open(dump, "w") as f:
            f.write(hlo)

    corrected = _scan_corrected_cost(plan, arch, shape, multi_pod, mesh)

    record.update({
        "status": "ok",
        "corrected": corrected,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        # cost_analysis is PER-DEVICE for the SPMD-partitioned module
        "cost": {k: v for k, v in ca.items()
                 if isinstance(v, (int, float)) and not k.startswith("util")},
        "collectives": coll,
        "model_flops": _model_flops(plan),
    })
    # peak per-device bytes: args are persistent (params+opt), temps transient
    record["memory"]["peak_bytes"] = (
        ma.argument_size_in_bytes + ma.temp_size_in_bytes
    )
    _write(record, artifact_dir)
    print(
        f"OK {arch}/{shape}/{mesh_kind}: compile={t_compile:.1f}s "
        f"flops/dev={record['cost'].get('flops', 0):.3e} "
        f"peak/dev={record['memory']['peak_bytes']/2**30:.2f}GiB "
        f"coll/dev={coll['total_bytes']/2**20:.1f}MiB ({coll['total_count']} ops)"
    )
    return record


def _scan_corrected_cost(plan, arch, shape, multi_pod, mesh):
    """XLA costs a while-loop body ONCE regardless of trip count, so scanned
    LM layers are undercounted. Correct by compiling unrolled 1-group and
    2-group depth variants (seconds each): body = cost(2g) - cost(1g);
    total = cost(1g) + (n_groups - 1) * body. Applies to flops and
    collective bytes; memory_analysis of the production (scan) lowering is
    kept as-is."""
    cfg = plan.cfg
    if not hasattr(cfg, "n_groups") or getattr(cfg, "unroll_layers", False):
        return None
    G = cfg.n_groups
    if G <= 2:
        return None
    from repro.launch.steps import build_plan

    def cost_of(n_groups):
        # neutralise every while-loop in the probe: unrolled layers, a single
        # microbatch (flops/collectives are token-count invariant) and direct
        # (unchunked) attention, so cost_analysis sees the whole step
        p = build_plan(
            arch, shape, multi_pod=multi_pod,
            overrides={"n_layers": n_groups * cfg.pattern_len,
                       "unroll_layers": True,
                       "n_microbatches": 1,
                       "query_chunk": 1 << 30},
        )
        c = p.lower(mesh).compile()
        ca = c.cost_analysis() or {}
        coll = parse_collectives(c.as_text())
        return ca.get("flops", 0.0), ca.get("bytes accessed", 0.0), \
            coll["total_bytes"]

    f1, b1, c1 = cost_of(1)
    f2, b2, c2 = cost_of(2)
    body_f, body_b, body_c = f2 - f1, b2 - b1, c2 - c1
    return {
        "flops": f1 + (G - 1) * body_f,
        "bytes_accessed": b1 + (G - 1) * body_b,
        "collective_bytes": c1 + (G - 1) * body_c,
        "per_group_flops": body_f,
        "method": "unrolled 1g/2g extrapolation",
    }


def _model_flops(plan) -> dict:
    """Analytic 'useful' FLOPs for the MODEL_FLOPS/HLO_FLOPs ratio (global)."""
    from repro.launch import model_flops
    return model_flops.estimate(plan)


def _write(record: dict, artifact_dir: str) -> None:
    os.makedirs(artifact_dir, exist_ok=True)
    fname = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    with open(os.path.join(artifact_dir, fname), "w") as f:
        json.dump(record, f, indent=1)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    p.add_argument("--all", action="store_true")
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--artifact-dir", default=None)
    p.add_argument("--variant", default="",
                   help="config overrides, e.g. unroll_layers=True,"
                        "n_microbatches=4 (artifact tagged arch@variant)")
    args = p.parse_args()
    artifact_dir = args.artifact_dir or os.path.normpath(ARTIFACT_DIR)

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    if args.all:
        # subprocess per cell: bounds compile-cache memory, survives one
        # cell's failure, and parallel-safe to re-run with --skip-existing
        from repro import configs as configs_lib

        failures = []
        for arch, shape in configs_lib.all_cells():
            for mesh_kind in meshes:
                fname = os.path.join(
                    artifact_dir, f"{arch}__{shape}__{mesh_kind}.json")
                if args.skip_existing and os.path.exists(fname):
                    print(f"CACHED {arch}/{shape}/{mesh_kind}")
                    continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                    "--artifact-dir", artifact_dir,
                ]
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    failures.append((arch, shape, mesh_kind))
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("all cells green")
        return

    try:
        run_cell(args.arch, args.shape, meshes[0], artifact_dir,
                 variant=args.variant)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
