"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis extends data parallelism across pods (gradient reduction becomes
hierarchical: reduce-scatter intra-pod over ICI, all-reduce inter-pod over
DCI), and extends index/sequence sharding for serving shapes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on Mesh
    from jax.sharding import AxisType
except ImportError:  # older jax: every mesh axis is implicitly "auto"
    AxisType = None


def _make_mesh(shape, axes, devices):
    if AxisType is None:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(
        shape, axes, devices=devices,
        axis_types=(AxisType.Auto,) * len(axes),
    )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; the "
            "dry-run entrypoint must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax"
        )
    return _make_mesh(shape, axes, devices[:n])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    return _make_mesh(
        (data, model), ("data", "model"), jax.devices()[: data * model]
    )
