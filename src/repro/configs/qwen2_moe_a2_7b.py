"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d_model=2048 16H (GQA
kv=16) d_ff=1408 (MoE expert width) vocab=151936, MoE 60 routed top-4 + 4
shared experts."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

from .base import ArchSpec, lm_cells


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-moe-a2.7b",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        n_experts=60,
        top_k=4,
        moe_d_ff=1408,
        n_shared_experts=4,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        dtype=jnp.bfloat16,
        remat_policy="minimal",
        n_microbatches=8,  # §Perf: activation memory / nm
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-moe-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        vocab_size=512,
        n_experts=8,
        top_k=4,
        moe_d_ff=32,
        n_shared_experts=2,
        moe_group_size=64,
        qkv_bias=True,
        tie_embeddings=False,
        dtype=jnp.float32,
        remat_policy="none",
        query_chunk=64,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="qwen2-moe-a2.7b",
        family="lm",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
        make_config=make_config,
        make_reduced=make_reduced,
        cells=lm_cells(full_attention_only=True),
    )
