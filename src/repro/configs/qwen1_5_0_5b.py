"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B]: dense 24L d_model=1024 16H (GQA kv=16)
d_ff=2816 vocab=151936, QKV bias."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

from .base import ArchSpec, lm_cells


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-0.5b",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        dtype=jnp.bfloat16,
        remat_policy="minimal",
        n_microbatches=2,  # §Perf: headroom under 16 GiB
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
        tie_embeddings=True,
        dtype=jnp.float32,
        remat_policy="none",
        query_chunk=64,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="qwen1.5-0.5b",
        family="lm",
        source="hf:Qwen/Qwen1.5-0.5B",
        make_config=make_config,
        make_reduced=make_reduced,
        cells=lm_cells(full_attention_only=True),
    )
