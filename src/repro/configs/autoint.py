"""autoint [arXiv:1810.11921]: n_sparse=39 embed_dim=16 n_attn_layers=3
n_heads=2 d_attn=32, self-attention feature interaction."""
from repro.models.recsys import RecsysConfig, criteo_vocab

from .base import ArchSpec, RECSYS_CELLS


def make_config() -> RecsysConfig:
    return RecsysConfig(
        name="autoint",
        model="autoint",
        n_sparse=39,
        embed_dim=16,
        vocab_sizes=tuple(criteo_vocab(39)),
        n_attn_layers=3,
        n_heads=2,
        d_attn=32,
    )


def make_reduced() -> RecsysConfig:
    return RecsysConfig(
        name="autoint-reduced",
        model="autoint",
        n_sparse=8,
        embed_dim=16,
        vocab_sizes=tuple([64] * 8),
        n_attn_layers=2,
        n_heads=2,
        d_attn=16,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="autoint",
        family="recsys",
        source="arXiv:1810.11921",
        make_config=make_config,
        make_reduced=make_reduced,
        cells=RECSYS_CELLS,
    )
