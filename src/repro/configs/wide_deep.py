"""wide-deep [arXiv:1606.07792]: n_sparse=40 embed_dim=32 mlp=1024-512-256,
concat interaction + wide linear branch."""
from repro.models.recsys import RecsysConfig, criteo_vocab

from .base import ArchSpec, RECSYS_CELLS


def make_config() -> RecsysConfig:
    return RecsysConfig(
        name="wide-deep",
        model="wide_deep",
        n_sparse=40,
        embed_dim=32,
        vocab_sizes=tuple(criteo_vocab(40)),
        mlp=(1024, 512, 256),
    )


def make_reduced() -> RecsysConfig:
    return RecsysConfig(
        name="wide-deep-reduced",
        model="wide_deep",
        n_sparse=8,
        embed_dim=8,
        vocab_sizes=tuple([64] * 8),
        mlp=(32, 16),
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="wide-deep",
        family="recsys",
        source="arXiv:1606.07792",
        make_config=make_config,
        make_reduced=make_reduced,
        cells=RECSYS_CELLS,
    )
