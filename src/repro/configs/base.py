"""Config substrate: architecture specs, shape cells, input-spec builders.

Every assigned architecture module exposes ``spec() -> ArchSpec`` with
  * the exact published configuration (``make_config``),
  * a reduced same-family smoke configuration (``make_reduced``),
  * its shape cells (the paper-assigned arch x shape grid), with explicit
    skip reasons where the shape table mandates one.

``input_specs(cfg, cell)`` returns jax.ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation — consumed by
launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    shape: str                      # e.g. "train_4k"
    kind: str                       # train | prefill | decode | serve | retrieval
    dims: Dict[str, int]
    skip: Optional[str] = None      # reason when the cell is mandated-skipped


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                     # lm | gnn | recsys
    source: str                     # citation tag from the assignment table
    make_config: Callable[[], Any]
    make_reduced: Callable[[], Any]
    cells: Tuple[ShapeCell, ...]

    def cell(self, shape: str) -> ShapeCell:
        for c in self.cells:
            if c.shape == shape:
                return c
        raise KeyError(f"{self.arch_id} has no shape {shape}")


# -- shared shape tables --------------------------------------------------------

LM_CELLS = (
    ShapeCell("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeCell("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeCell("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeCell("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)


def lm_cells(*, full_attention_only: bool) -> Tuple[ShapeCell, ...]:
    cells = list(LM_CELLS)
    if full_attention_only:
        cells[3] = dataclasses.replace(
            cells[3],
            skip=(
                "pure full-attention arch: long_500k requires sub-quadratic "
                "attention (shape-table instruction; see DESIGN.md "
                "§Arch-applicability)"
            ),
        )
    return tuple(cells)


GNN_CELLS = (
    ShapeCell("full_graph_sm", "train",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_graphs": 1}),
    ShapeCell("minibatch_lg", "train",
              {"n_nodes": 176128, "n_edges": 172032, "d_feat": 602,
               "batch_nodes": 1024, "n_graphs": 1,
               "pool_nodes": 232965, "pool_edges": 114615892}),
    ShapeCell("ogb_products", "train",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
               "n_graphs": 1}),
    ShapeCell("molecule", "train",
              {"n_nodes": 3840, "n_edges": 8192, "d_feat": 16, "n_graphs": 128}),
)

RECSYS_CELLS = (
    ShapeCell("train_batch", "train", {"batch": 65536}),
    ShapeCell("serve_p99", "serve", {"batch": 512}),
    ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    ShapeCell("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)


# -- input-spec builders --------------------------------------------------------


def lm_input_specs(cfg, cell: ShapeCell) -> dict:
    from repro.models import transformer as T

    B, S = cell.dims["global_batch"], cell.dims["seq_len"]
    i32 = jnp.int32
    if cell.kind == "train":
        return {"batch": {"tokens": SDS((B, S), i32)}}
    if cell.kind == "prefill":
        return {"tokens": SDS((B, S), i32)}
    if cell.kind == "decode":
        cache = jax.eval_shape(lambda: T.init_kv_cache(cfg, B, S))
        cache = jax.tree.map(lambda s: SDS(s.shape, s.dtype), cache)
        return {
            "cache": cache,
            "token": SDS((B, 1), i32),
            "cache_len": SDS((), i32),
        }
    raise ValueError(cell.kind)


def pad_edges(e: int, mult: int = 512) -> int:
    """Edge arrays shard over the data axes; pad to a shardable multiple
    (padding edges carry edge_mask = 0)."""
    return (e + mult - 1) // mult * mult


def gnn_input_specs(cfg, cell: ShapeCell) -> dict:
    d = cell.dims
    N, E, G = d["n_nodes"], pad_edges(d["n_edges"]), d["n_graphs"]
    f32, i32 = jnp.float32, jnp.int32
    node_level = cell.shape in ("minibatch_lg", "ogb_products")
    batch = {
        "positions": SDS((N, 3), f32),
        "node_feat": SDS((N, d["d_feat"]), f32),
        "senders": SDS((E,), i32),
        "receivers": SDS((E,), i32),
        "edge_mask": SDS((E,), f32),
        "node_mask": SDS((N,), f32),
        "node_graph": SDS((N,), i32),
    }
    if node_level:
        batch["target_nodes"] = SDS((N,), f32)
        batch["loss_node_mask"] = SDS((N,), f32)
    else:
        batch["target_energy"] = SDS((G,), f32)
    return {"batch": batch, "static": {"n_graphs": G, "node_level": node_level}}


def recsys_input_specs(cfg, cell: ShapeCell) -> dict:
    B = cell.dims["batch"]
    f32, i32 = jnp.float32, jnp.int32
    batch = {"sparse": SDS((B, cfg.n_sparse), i32)}
    if cfg.n_dense:
        batch["dense"] = SDS((B, cfg.n_dense), f32)
    if cell.kind == "train":
        batch["labels"] = SDS((B,), f32)
    out = {"batch": batch}
    if cell.kind == "retrieval":
        n_cand = cell.dims["n_candidates"]
        if getattr(cfg, "retrieval_mode", "dense") == "zen":
            k = cfg.zen_k
            # nSimplex-reduced index + replicated transform state
            out["candidates"] = {
                "coords": SDS((n_cand, k), f32),        # apex coordinates
                "refs": SDS((k, cfg.embed_dim), f32),
                "chol": SDS((k - 1, k - 1), f32),
                "diag_g": SDS((k - 1,), f32),
                "d0": SDS((k,), f32),
            }
        else:
            out["candidates"] = SDS((n_cand, cfg.embed_dim), f32)
    return out


def input_specs(spec: ArchSpec, cfg, cell: ShapeCell) -> dict:
    return {
        "lm": lm_input_specs,
        "gnn": gnn_input_specs,
        "recsys": recsys_input_specs,
    }[spec.family](cfg, cell)
