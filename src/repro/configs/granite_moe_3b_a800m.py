"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0 family]: 32L d_model=1536
24H (GQA kv=8) d_ff=512 (expert width), MoE 40 experts top-8."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

from .base import ArchSpec, lm_cells


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="granite-moe-3b-a800m",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        n_experts=40,
        top_k=8,
        moe_d_ff=512,
        n_shared_experts=0,
        qkv_bias=False,
        rope_theta=10_000.0,
        tie_embeddings=True,
        dtype=jnp.bfloat16,
        remat_policy="minimal",
        n_microbatches=8,  # §Perf: activation memory / nm
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        name="granite-moe-reduced",
        n_layers=2,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        d_ff=32,
        vocab_size=512,
        n_experts=8,
        top_k=4,
        moe_d_ff=32,
        moe_group_size=64,
        tie_embeddings=True,
        dtype=jnp.float32,
        remat_policy="none",
        query_chunk=64,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="granite-moe-3b-a800m",
        family="lm",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        make_config=make_config,
        make_reduced=make_reduced,
        cells=lm_cells(full_attention_only=True),
    )
