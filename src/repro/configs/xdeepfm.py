"""xdeepfm [arXiv:1803.05170]: n_sparse=39 embed_dim=10 cin_layers=200-200-200
mlp=400-400, CIN (compressed interaction network) + DNN + linear."""
from repro.models.recsys import RecsysConfig, criteo_vocab

from .base import ArchSpec, RECSYS_CELLS


def make_config() -> RecsysConfig:
    return RecsysConfig(
        name="xdeepfm",
        model="xdeepfm",
        n_sparse=39,
        embed_dim=10,
        vocab_sizes=tuple(criteo_vocab(39)),
        cin_layers=(200, 200, 200),
        mlp=(400, 400),
    )


def make_reduced() -> RecsysConfig:
    return RecsysConfig(
        name="xdeepfm-reduced",
        model="xdeepfm",
        n_sparse=8,
        embed_dim=8,
        vocab_sizes=tuple([64] * 8),
        cin_layers=(16, 16),
        mlp=(32, 32),
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="xdeepfm",
        family="recsys",
        source="arXiv:1803.05170",
        make_config=make_config,
        make_reduced=make_reduced,
        cells=RECSYS_CELLS,
    )
