"""Architecture registry: one module per assigned architecture (``--arch <id>``)
plus the paper's own experiment configurations (nsimplex_paper)."""
from __future__ import annotations

from . import (
    autoint,
    dlrm_rm2,
    gemma2_2b,
    granite_8b,
    granite_moe_3b_a800m,
    mace,
    qwen1_5_0_5b,
    qwen2_moe_a2_7b,
    wide_deep,
    xdeepfm,
)
from .base import ArchSpec, ShapeCell, input_specs

_MODULES = {
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "qwen1.5-0.5b": qwen1_5_0_5b,
    "gemma2-2b": gemma2_2b,
    "granite-8b": granite_8b,
    "mace": mace,
    "autoint": autoint,
    "wide-deep": wide_deep,
    "dlrm-rm2": dlrm_rm2,
    "xdeepfm": xdeepfm,
}


def list_archs() -> list[str]:
    return sorted(_MODULES)


def get_arch(arch_id: str) -> ArchSpec:
    try:
        return _MODULES[arch_id].spec()
    except KeyError:
        raise ValueError(
            f"unknown arch {arch_id!r}; available: {list_archs()}"
        ) from None


def all_cells() -> list[tuple[str, str]]:
    """Every (arch_id, shape) pair in the assignment grid (40 total)."""
    out = []
    for aid in list_archs():
        for cell in get_arch(aid).cells:
            out.append((aid, cell.shape))
    return out


__all__ = [
    "ArchSpec",
    "ShapeCell",
    "input_specs",
    "get_arch",
    "list_archs",
    "all_cells",
]
