"""dlrm-rm2 [arXiv:1906.00091]: n_dense=13 n_sparse=26 embed_dim=64
bot_mlp=13-512-256-64 top_mlp=512-512-256-1, dot interaction. Embedding
tables use the public Criteo Kaggle cardinalities (~33.8M rows)."""
from repro.models.recsys import CRITEO_26, RecsysConfig

from .base import ArchSpec, RECSYS_CELLS


def make_config() -> RecsysConfig:
    return RecsysConfig(
        name="dlrm-rm2",
        model="dlrm",
        n_sparse=26,
        embed_dim=64,
        vocab_sizes=tuple(CRITEO_26),
        n_dense=13,
        bot_mlp=(512, 256, 64),
        top_mlp=(512, 512, 256, 1),
    )


def make_reduced() -> RecsysConfig:
    return RecsysConfig(
        name="dlrm-reduced",
        model="dlrm",
        n_sparse=8,
        embed_dim=16,
        vocab_sizes=tuple([64] * 8),
        n_dense=13,
        bot_mlp=(32, 16),
        top_mlp=(32, 16, 1),
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dlrm-rm2",
        family="recsys",
        source="arXiv:1906.00091",
        make_config=make_config,
        make_reduced=make_reduced,
        cells=RECSYS_CELLS,
    )
