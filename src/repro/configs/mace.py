"""mace [arXiv:2206.07697]: n_layers=2 d_hidden=128 l_max=2
correlation_order=3 n_rbf=8, E(3)-equivariant higher-order message passing
(Cartesian-irrep TPU adaptation, see models/mace.py and DESIGN.md §2).

The assigned GNN shapes are citation/product graphs without atomic positions;
the data pipeline synthesises 3D coordinates (random low-distortion layout) so
the geometric model is exercised at the published scales — noted in DESIGN.md.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.mace import MACEConfig

from .base import ArchSpec, GNN_CELLS


def make_config() -> MACEConfig:
    return MACEConfig(
        name="mace",
        n_layers=2,
        channels=128,
        l_max=2,
        correlation=3,
        n_rbf=8,
        d_feat=1,       # overridden per shape by the launcher
        r_cut=5.0,
        dtype=jnp.bfloat16,
        remat=True,
    )


def for_shape(cfg: MACEConfig, d_feat: int) -> MACEConfig:
    return dataclasses.replace(cfg, d_feat=d_feat)


def make_reduced() -> MACEConfig:
    return MACEConfig(
        name="mace-reduced",
        n_layers=2,
        channels=16,
        n_rbf=4,
        d_feat=8,
        radial_hidden=16,
        readout_hidden=8,
        dtype=jnp.float32,
        remat=False,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="mace",
        family="gnn",
        source="arXiv:2206.07697",
        make_config=make_config,
        make_reduced=make_reduced,
        cells=GNN_CELLS,
    )
