"""gemma2-2b [arXiv:2408.00118]: dense 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000; local(4096)/global alternating attention, logit softcaps,
GeGLU, post-norms, (1+w) RMSNorm, sqrt(d) embedding scale, head_dim 256.

long_500k RUNS for this arch: the alternating local/global layout keeps half
the stack's KV at the 4096-token window (sub-quadratic sliding-window path);
the decode step lowers a mixed ring/full cache (DESIGN.md §Arch-applicability).
"""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

from .base import ArchSpec, lm_cells


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-2b",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        d_head=256,
        d_ff=9216,
        vocab_size=256000,
        layer_pattern=(4096, 0),  # local window 4096, then global
        logit_softcap=30.0,
        attn_softcap=50.0,
        post_norms=True,
        norm_plus_one=True,
        embed_scale=True,
        act="gelu",
        rope_theta=10_000.0,
        tie_embeddings=True,
        dtype=jnp.bfloat16,
        remat_policy="minimal",
        n_microbatches=4,  # §Perf: peak 27.4 GiB -> fits
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-reduced",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,
        d_ff=128,
        vocab_size=512,
        layer_pattern=(16, 0),
        logit_softcap=30.0,
        attn_softcap=50.0,
        post_norms=True,
        norm_plus_one=True,
        embed_scale=True,
        act="gelu",
        tie_embeddings=True,
        dtype=jnp.float32,
        remat_policy="none",
        query_chunk=64,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="gemma2-2b",
        family="lm",
        source="arXiv:2408.00118",
        make_config=make_config,
        make_reduced=make_reduced,
        cells=lm_cells(full_attention_only=False),  # hybrid: long_500k runs
    )
