"""granite-8b [arXiv:2405.04324]: dense llama-arch 36L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=49152 (code model)."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

from .base import ArchSpec, lm_cells


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="granite-8b",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=49152,
        qkv_bias=False,
        rope_theta=10_000_000.0,
        tie_embeddings=False,
        dtype=jnp.bfloat16,
        remat_policy="minimal",
        n_microbatches=8,  # §Perf: peak 59.3 -> 11.9 GiB/dev (fits v5e)
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        name="granite-8b-reduced",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        tie_embeddings=False,
        dtype=jnp.float32,
        remat_policy="none",
        query_chunk=64,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="granite-8b",
        family="lm",
        source="arXiv:2405.04324",
        make_config=make_config,
        make_reduced=make_reduced,
        cells=lm_cells(full_attention_only=True),
    )
