"""Model zoo: LM transformers (dense + MoE), MACE equivariant GNN, RecSys."""
from . import layers, mace, moe, recsys, transformer

__all__ = ["layers", "mace", "moe", "recsys", "transformer"]
