"""Shared neural-net layers: norms, RoPE, attention (query-chunked), MLPs.

Pure JAX (no flax): params are plain pytrees, layers are functions. Attention
is written to behave well under GSPMD auto-sharding:

* query-chunked softmax attention (``lax.map`` over query blocks) bounds the
  score tensor at (B, H, qc, Skv) per step — enough for 32k prefill with remat;
* the decode path (Sq == 1) is a direct einsum so a KV cache whose *sequence*
  axis is sharded across the mesh reduces via GSPMD-inserted collectives
  (sequence-parallel decode for the 500k-context shape);
* GQA is expressed by reshaping query heads into (kv_head, group) so the
  kv tensors are never materially repeated.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, weight: Array, eps: float = 1e-6, *, plus_one: bool = False) -> Array:
    acc = jnp.promote_types(x.dtype, jnp.float32)
    xf = x.astype(acc)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(acc)
    if plus_one:  # gemma-style (1 + w) parameterisation
        w = 1.0 + w
    return (y * w).astype(x.dtype)


def softcap(x: Array, cap: float) -> Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: Array, positions: Array, *, theta: float = 10000.0) -> Array:
    """Rotary embedding. x: (B, S, H, dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def _attn_block(
    q: Array,  # (B, qc, KV, G, dh) f32-ready
    k: Array,  # (B, Skv, KV, dh)
    v: Array,
    q_pos: Array,  # (B, qc)
    kv_pos: Array,  # (B, Skv)
    *,
    causal: bool,
    window: int,
    attn_softcap: float,
    scale: float,
) -> Array:
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    if attn_softcap:
        scores = softcap(scores, attn_softcap)
    mask = jnp.ones(
        (q_pos.shape[0], 1, 1, q_pos.shape[1], kv_pos.shape[1]), bool
    )
    if causal:
        mask &= (kv_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None])
    if window:
        mask &= (
            kv_pos[:, None, None, None, :]
            > q_pos[:, None, None, :, None] - window
        )
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out


def attention(
    q: Array,  # (B, Sq, H, dh)
    k: Array,  # (B, Skv, KV, dh)
    v: Array,  # (B, Skv, KV, dh)
    *,
    q_positions: Array,  # (B, Sq)
    kv_positions: Array,  # (B, Skv)
    causal: bool = True,
    window: int = 0,
    attn_softcap: float = 0.0,
    query_chunk: int = 1024,
    scale: Optional[float] = None,
) -> Array:
    """Softmax attention with GQA, causal/sliding-window masks and softcap.

    Returns (B, Sq, H, dh). Query-chunked when Sq > query_chunk.
    """
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else dh**-0.5
    qg = q.reshape(B, Sq, KV, G, dh)

    if Sq <= query_chunk:
        out = _attn_block(
            qg, k, v, q_positions, kv_positions,
            causal=causal, window=window, attn_softcap=attn_softcap, scale=scale,
        )
        return out.reshape(B, Sq, H, dh).astype(q.dtype)

    orig_sq = Sq
    if Sq % query_chunk:  # pad ragged query lengths with masked dummies
        pad = query_chunk - Sq % query_chunk
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)), constant_values=0)
        Sq += pad
    n_chunks = Sq // query_chunk
    qg_c = qg.reshape(B, n_chunks, query_chunk, KV, G, dh)
    qp_c = q_positions.reshape(B, n_chunks, query_chunk)

    def one_chunk(args):
        qc, qp = args
        return _attn_block(
            qc, k, v, qp, kv_positions,
            causal=causal, window=window, attn_softcap=attn_softcap, scale=scale,
        )

    # lax.map over query chunks: score tensor bounded at (B, H, qc, Skv)
    out = jax.lax.map(
        one_chunk,
        (jnp.moveaxis(qg_c, 1, 0), jnp.moveaxis(qp_c, 1, 0)),
    )  # (n_chunks, B, qc, KV, G, dh)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, dh)
    return out[:, :orig_sq].astype(q.dtype)


# -- parameter helpers --------------------------------------------------------


def dense_init(key: jax.Array, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in**-0.5
    return (
        jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale
    ).astype(dtype)


@dataclasses.dataclass(frozen=True)
class ActFn:
    name: str

    def __call__(self, x: Array) -> Array:
        if self.name == "silu":
            return jax.nn.silu(x)
        if self.name == "gelu":
            return jax.nn.gelu(x, approximate=True)
        if self.name == "relu":
            return jax.nn.relu(x)
        raise ValueError(self.name)


def mlp_glu(x: Array, wg: Array, wu: Array, wd: Array, act: ActFn) -> Array:
    """Gated-linear-unit FFN (SwiGLU / GeGLU): down(act(x wg) * (x wu))."""
    acc = jnp.float32
    g = act(jnp.einsum("...d,df->...f", x, wg, preferred_element_type=acc))
    u = jnp.einsum("...d,df->...f", x, wu, preferred_element_type=acc)
    return jnp.einsum(
        "...f,fd->...d", (g * u).astype(x.dtype), wd, preferred_element_type=acc
    ).astype(x.dtype)
