"""RecSys / ranking models: AutoInt, Wide&Deep, DLRM (RM2), xDeepFM.

Shared substrate:
* **EmbeddingBag in JAX** (taxonomy §RecSys: no native torch-style
  EmbeddingBag) — all per-field vocabularies are concatenated into one
  (total_rows, dim) table with static per-field offsets; a lookup is one
  gather (`jnp.take`), multi-hot bags reduce with ``jax.ops.segment_sum``.
  At scale the table rows shard over the ``model`` axis — GSPMD turns the
  gather into per-shard partial gathers + an all-reduce, which is exactly the
  embedding-exchange collective the roofline section tracks.
* feature-interaction op per model (self-attention / concat / dot / CIN);
* small dense MLP head with sigmoid-BCE loss;
* a **retrieval head** scoring one query against a candidate embedding matrix
  (``retrieval_cand`` shape): dense dot-product baseline plus the
  nSimplex-Zen-reduced variant (the paper's technique as a serving feature).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

Array = jax.Array

# Criteo Kaggle categorical cardinalities (public, arXiv:1906.00091 scale)
CRITEO_26 = [
    1460, 583, 10_131_227, 2_202_608, 305, 24, 12_517, 633, 3, 93_145, 5_683,
    8_351_593, 3_194, 27, 14_992, 5_461_306, 10, 5_652, 2_173, 4, 7_046_547,
    18, 15, 286_181, 105, 142_572,
]


def criteo_vocab(n_fields: int) -> list[int]:
    """n_fields vocab sizes: the 26 Criteo categorical tables, extended with
    128-bucket quantised dense features (the 39-field convention of the
    AutoInt / xDeepFM papers), then hashed cross-features of 10^4."""
    sizes = list(CRITEO_26)
    sizes += [128] * 13  # bucketised dense features -> 39
    while len(sizes) < n_fields:
        sizes.append(10_000)
    return sizes[:n_fields]


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str                      # autoint | wide_deep | dlrm | xdeepfm
    n_sparse: int
    embed_dim: int
    vocab_sizes: Tuple[int, ...]
    n_dense: int = 0
    # dlrm
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    # autoint
    n_attn_layers: int = 0
    n_heads: int = 0
    d_attn: int = 0
    # wide&deep / xdeepfm MLPs
    mlp: Tuple[int, ...] = ()
    cin_layers: Tuple[int, ...] = ()
    dtype: Any = jnp.float32
    table_dtype: Any = jnp.float32
    # retrieval_cand scoring: "dense" dot-product over (N_cand, embed_dim), or
    # "zen" over an nSimplex-reduced (N_cand, zen_k) index — the paper's
    # technique as a first-class serving feature (bytes scanned / embed_dim*4
    # per candidate drop to zen_k*4)
    retrieval_mode: str = "dense"
    zen_k: int = 16

    @property
    def total_rows(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def padded_rows(self) -> int:
        """Table rows padded to a mesh-shardable multiple (row sharding over
        the model axis requires divisibility); padding rows are unreachable
        because per-field offsets never address them."""
        return (self.total_rows + 511) // 512 * 512

    @property
    def offsets(self) -> Tuple[int, ...]:
        out, acc = [], 0
        for v in self.vocab_sizes:
            out.append(acc)
            acc += v
        return tuple(out)

    def param_count(self) -> int:
        # dominated by the embedding table
        n = self.total_rows * self.embed_dim
        return n  # MLPs counted at init in benchmarks


def _mlp_params(key, dims: Sequence[int], dtype) -> list:
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {"w": dense_init(ks[i], (dims[i], dims[i + 1]), dtype=dtype),
         "b": jnp.zeros((dims[i + 1],), dtype)}
        for i in range(len(dims) - 1)
    ]


def _mlp_apply(layers: list, x: Array, *, final_act: bool = False) -> Array:
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i + 1 < len(layers) or final_act:
            x = jax.nn.relu(x)
    return x


def init_params(cfg: RecsysConfig, key: jax.Array) -> dict:
    keys = iter(jax.random.split(key, 32))
    F, d = cfg.n_sparse, cfg.embed_dim
    params: dict = {
        # one concatenated table; per-field row offsets are static config
        "table": (
            jax.random.normal(next(keys), (cfg.padded_rows, d), jnp.float32)
            * (d**-0.5)
        ).astype(cfg.table_dtype),
    }
    if cfg.model == "dlrm":
        params["bot"] = _mlp_params(next(keys), (cfg.n_dense,) + cfg.bot_mlp, cfg.dtype)
        n_f = F + 1  # embeddings + bottom-MLP output
        n_int = n_f * (n_f - 1) // 2
        params["top"] = _mlp_params(
            next(keys), (n_int + cfg.bot_mlp[-1],) + cfg.top_mlp, cfg.dtype
        )
    elif cfg.model == "autoint":
        lays = []
        d_in = d
        for _ in range(cfg.n_attn_layers):
            lays.append({
                "wq": dense_init(next(keys), (d_in, cfg.n_heads * cfg.d_attn), dtype=cfg.dtype),
                "wk": dense_init(next(keys), (d_in, cfg.n_heads * cfg.d_attn), dtype=cfg.dtype),
                "wv": dense_init(next(keys), (d_in, cfg.n_heads * cfg.d_attn), dtype=cfg.dtype),
                "wres": dense_init(next(keys), (d_in, cfg.n_heads * cfg.d_attn), dtype=cfg.dtype),
            })
            d_in = cfg.n_heads * cfg.d_attn
        params["attn"] = lays
        params["out"] = _mlp_params(next(keys), (F * d_in, 1), cfg.dtype)
    elif cfg.model == "wide_deep":
        params["wide"] = (
            jax.random.normal(next(keys), (cfg.padded_rows, 1), jnp.float32) * 0.01
        ).astype(cfg.table_dtype)
        params["deep"] = _mlp_params(next(keys), (F * d,) + cfg.mlp + (1,), cfg.dtype)
    elif cfg.model == "xdeepfm":
        cins, h_prev = [], F
        for h in cfg.cin_layers:
            cins.append(
                {"w": dense_init(next(keys), (h_prev * F, h), dtype=cfg.dtype)}
            )
            h_prev = h
        params["cin"] = cins
        params["cin_out"] = _mlp_params(
            next(keys), (int(sum(cfg.cin_layers)), 1), cfg.dtype
        )
        params["dnn"] = _mlp_params(next(keys), (F * d,) + cfg.mlp + (1,), cfg.dtype)
        params["linear"] = (
            jax.random.normal(next(keys), (cfg.padded_rows, 1), jnp.float32) * 0.01
        ).astype(cfg.table_dtype)
    else:
        raise ValueError(cfg.model)
    return params


# -- embedding bag -------------------------------------------------------------


def embedding_bag(
    table: Array,
    indices: Array,          # (B, F) one-hot-per-field or (B, F, L) multi-hot
    offsets: Tuple[int, ...],
    *,
    weights: Optional[Array] = None,
    shard_spec: Any = None,
) -> Array:
    """Gather per-field embeddings; multi-hot bags sum-reduce over L.

    Returns (B, F, d). With the table row-sharded over the model axis, GSPMD
    lowers the take() into per-shard gathers + all-reduce.
    """
    off = jnp.asarray(offsets, jnp.int32)
    if indices.ndim == 2:
        flat = indices + off[None, :]
        emb = jnp.take(table, flat, axis=0)  # (B, F, d)
    else:
        B, F, L = indices.shape
        flat = indices + off[None, :, None]
        emb = jnp.take(table, flat, axis=0)  # (B, F, L, d)
        if weights is not None:
            emb = emb * weights[..., None]
        emb = jnp.sum(emb, axis=2)
    if shard_spec is not None:
        emb = jax.lax.with_sharding_constraint(emb, shard_spec)
    return emb.astype(jnp.float32)


# -- model forwards ------------------------------------------------------------


def forward(
    cfg: RecsysConfig,
    params: dict,
    batch: dict,
    *,
    emb_shard: Any = None,
    act_shard: Any = None,
) -> Array:
    """Logits (B,). batch: sparse (B,F[,L]) int32 [+ dense (B,n_dense) f32]."""
    emb = embedding_bag(
        params["table"], batch["sparse"], cfg.offsets, shard_spec=emb_shard
    )  # (B, F, d)
    B = emb.shape[0]

    def constrain(x):
        return (
            jax.lax.with_sharding_constraint(x, act_shard)
            if act_shard is not None else x
        )

    if cfg.model == "dlrm":
        bot = _mlp_apply(params["bot"], batch["dense"].astype(jnp.float32),
                         final_act=True)  # (B, d)
        z = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, F+1, d)
        inter = jnp.einsum("bfd,bgd->bfg", z, z,
                           preferred_element_type=jnp.float32)
        iu = jnp.triu_indices(z.shape[1], k=1)
        flat = inter[:, iu[0], iu[1]]  # (B, n_int)
        x = jnp.concatenate([bot, flat], axis=-1)
        return _mlp_apply(params["top"], x)[:, 0]

    if cfg.model == "autoint":
        x = emb  # (B, F, d)
        for l in params["attn"]:
            H, da = cfg.n_heads, cfg.d_attn
            q = (x @ l["wq"]).reshape(B, -1, H, da)
            k = (x @ l["wk"]).reshape(B, -1, H, da)
            v = (x @ l["wv"]).reshape(B, -1, H, da)
            scores = jnp.einsum("bfhd,bghd->bhfg", q, k,
                                preferred_element_type=jnp.float32)
            probs = jax.nn.softmax(scores, axis=-1)
            o = jnp.einsum("bhfg,bghd->bfhd", probs, v,
                           preferred_element_type=jnp.float32)
            o = o.reshape(B, x.shape[1], H * da)
            x = jax.nn.relu(o + x @ l["wres"])
            x = constrain(x)
        return _mlp_apply(params["out"], x.reshape(B, -1))[:, 0]

    if cfg.model == "wide_deep":
        deep = _mlp_apply(params["deep"], emb.reshape(B, -1))[:, 0]
        wide = embedding_bag(params["wide"], batch["sparse"], cfg.offsets)
        return deep + jnp.sum(wide, axis=(1, 2))

    if cfg.model == "xdeepfm":
        x0 = emb  # (B, F, d)
        xk = x0
        pooled = []
        for l in params["cin"]:
            z = jnp.einsum("bhd,bmd->bhmd", xk, x0,
                           preferred_element_type=jnp.float32)  # (B,Hk,F,d)
            z = constrain(z.reshape(B, -1, z.shape[-1]))  # (B, Hk*F, d)
            xk = jnp.einsum("bpd,ph->bhd", z, l["w"],
                            preferred_element_type=jnp.float32)
            pooled.append(jnp.sum(xk, axis=-1))  # (B, Hk+1)
        cin_logit = _mlp_apply(params["cin_out"],
                               jnp.concatenate(pooled, axis=-1))[:, 0]
        dnn_logit = _mlp_apply(params["dnn"], emb.reshape(B, -1))[:, 0]
        lin = embedding_bag(params["linear"], batch["sparse"], cfg.offsets)
        return cin_logit + dnn_logit + jnp.sum(lin, axis=(1, 2))

    raise ValueError(cfg.model)


def loss_fn(cfg: RecsysConfig, params: dict, batch: dict, **kw) -> Tuple[Array, dict]:
    """Sigmoid binary cross-entropy vs batch['labels'] (B,) in {0, 1}."""
    logits = forward(cfg, params, batch, **kw)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, {"loss": loss}


# -- retrieval head (paper integration point) ----------------------------------


def user_repr(cfg: RecsysConfig, params: dict, batch: dict) -> Array:
    """Query-side representation (B, embed_dim): mean of field embeddings —
    the Euclidean space handed to NSimplexTransform for reduced-candidate
    scoring."""
    emb = embedding_bag(params["table"], batch["sparse"], cfg.offsets)
    return jnp.mean(emb, axis=1)


def retrieval_scores(query_repr: Array, candidates: Array) -> Array:
    """Dense dot-product scoring: (B, d) x (N_cand, d) -> (B, N_cand).

    One batched matmul — never a loop (taxonomy §RecSys). The nSimplex-Zen
    variant scores ``zen_estimate(project(q), project(c))`` instead; see
    launch/serve.py.
    """
    return jnp.einsum(
        "bd,nd->bn", query_repr, candidates, preferred_element_type=jnp.float32
    )


def retrieval_topk(
    query_repr: Array, candidates: Array, k: int = 100
) -> Tuple[Array, Array]:
    scores = retrieval_scores(query_repr, candidates)
    return jax.lax.top_k(scores, k)


# -- two-tower retrieval training (the e2e serving workload's model) -----------


def init_two_tower_params(cfg: RecsysConfig, key: jax.Array, n_items: int) -> dict:
    """User tower = the embedding-bag table read by ``user_repr``; item tower
    = a dedicated (n_items, embed_dim) embedding table whose rows are the
    retrieval corpus handed to ``build_index``."""
    ku, ki = jax.random.split(key)
    d = cfg.embed_dim
    return {
        "table": jax.random.normal(ku, (cfg.padded_rows, d), jnp.float32)
        * (d**-0.5),
        "items": jax.random.normal(ki, (n_items, d), jnp.float32) * (d**-0.5),
    }


def item_repr(params: dict, item_ids: Optional[Array] = None) -> Array:
    """Item-tower embeddings: all rows, or a gathered (B, d) batch."""
    items = params["items"]
    if item_ids is None:
        return items
    return jnp.take(items, item_ids, axis=0)


def _l2(x: Array) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def two_tower_loss(
    cfg: RecsysConfig, params: dict, batch: dict, *, temperature: float = 0.1
) -> Tuple[Array, dict]:
    """In-batch sampled-softmax over L2-normalised towers.

    batch: sparse (B, F) user features + items (B,) positive item ids. Row
    i's positive is logit (i, i); every other item in the batch is a
    negative. Normalised towers make the trained dot-product ranking
    coincide with the Euclidean ranking of the same representations — the
    property the Zen-reduced retrieval head relies on.
    """
    u = _l2(user_repr(cfg, params, batch))          # (B, d)
    v = _l2(item_repr(params, batch["items"]))      # (B, d)
    logits = (u @ v.T) / temperature
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "in_batch_acc": acc}


def two_tower_towers(cfg: RecsysConfig, params: dict, batch: dict
                     ) -> Tuple[Array, Array]:
    """(users (B, d), all items (n_items, d)) — the query set and retrieval
    corpus of the trained model, as raw embeddings.

    The loss normalises internally; the towers are returned *unnormalised*
    because projecting learned embeddings onto the unit sphere collapses
    the reference-distance variance the nSimplex estimators feed on (every
    point is equidistant from the origin and near-equidistant from any
    reference), while coordinate methods are unaffected — Euclidean
    retrieval experiments on learned embeddings use the raw vectors."""
    return user_repr(cfg, params, batch), item_repr(params)
