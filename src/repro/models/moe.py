"""Mixture-of-Experts FFN with group-limited, capacity-bounded dispatch.

Design (DESIGN.md §6):
* tokens are reshaped into groups of ``moe_group_size``; groups align with the
  data-parallel sharding so the position-in-expert cumsum is *local* to a
  shard (no cross-device prefix scan);
* within a group, each token's top-k expert assignments claim a slot in an
  (E, C) buffer via a one-hot cumsum; assignments beyond the per-group
  capacity C = ceil(group * top_k / E * capacity_factor) are dropped
  (Switch/GShard semantics);
* expert buffers (groups, E, C, d) contract with expert weights (E, d, f)
  sharded over the ``model`` axis — expert parallelism; the gather/scatter
  between token- and expert-major layouts is where GSPMD inserts the
  all-to-all-like collectives the roofline section tracks;
* the expert count is padded to a multiple of the model-axis size; padded
  experts are masked to -inf in the router.

Router: softmax over true experts, top-k, renormalised combine weights
(the qwen2-moe convention, norm_topk_prob=True).
"""
from __future__ import annotations

import math
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from . import layers as L

if TYPE_CHECKING:  # pragma: no cover
    from .transformer import TransformerConfig

Array = jax.Array


def padded_experts(n_experts: int, multiple: int = 16) -> int:
    return int(math.ceil(n_experts / multiple) * multiple)


def capacity(group: int, top_k: int, n_experts_padded: int, factor: float) -> int:
    c = math.ceil(group * top_k / n_experts_padded * factor)
    return max(8, int(math.ceil(c / 8) * 8))


def moe_ffn(cfg: "TransformerConfig", p: dict, x: Array) -> Array:
    """x: (B, S, D) -> (B, S, D) routed through top-k experts."""
    B, S, D = x.shape
    E = p["we_gate"].shape[0]  # padded expert count (weights are pre-padded)
    T = B * S
    gs = min(cfg.moe_group_size, T)
    assert T % gs == 0, (T, gs)
    G = T // gs
    C = capacity(gs, cfg.top_k, E, cfg.capacity_factor)
    xt = x.reshape(G, gs, D)

    # --- router (f32 for numerics) ---------------------------------------
    logits = jnp.einsum(
        "gtd,de->gte", xt, p["router"], preferred_element_type=jnp.float32
    )
    if E > cfg.n_experts:  # mask padded experts
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, cfg.top_k)  # (G, gs, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # --- slot assignment within each group --------------------------------
    # flatten the k assignments per token: (G, gs*k)
    flat_e = expert_idx.reshape(G, gs * cfg.top_k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (G, gs*k, E)
    # log-depth prefix sum (O(n log n) adds) instead of jnp.cumsum's
    # potential O(n*window) reduce-window lowering on TPU. NOTE (§Perf): the
    # hypothesis that this cumsum dominated the MoE step's HLO FLOPs was
    # REFUTED by measurement (corrected flops unchanged); kept because the
    # log-depth form is never worse.
    pos = jax.lax.associative_scan(jnp.add, onehot, axis=1) - onehot
    pos_in_e = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = pos_in_e < C
    slot = flat_e * C + pos_in_e  # (G, gs*k) in [0, E*C)
    slot = jnp.where(keep, slot, E * C)  # dropped -> scatter to /dev/null row

    # --- dispatch: scatter token activations into expert buffers ----------
    token_of_assign = jnp.broadcast_to(
        jnp.arange(gs, dtype=jnp.int32)[None, :, None], (G, gs, cfg.top_k)
    ).reshape(G, gs * cfg.top_k)

    def dispatch_group(xg, slots, toks):
        buf = jnp.zeros((E * C + 1, D), xg.dtype)
        buf = buf.at[slots].set(xg[toks], mode="drop")
        return buf[: E * C].reshape(E, C, D)

    buffers = jax.vmap(dispatch_group)(xt, slot, token_of_assign)  # (G, E, C, D)

    # --- expert computation (E sharded over the model axis) ---------------
    act = L.ActFn(cfg.act)
    acc = jnp.float32
    g = act(jnp.einsum("gecd,edf->gecf", buffers, p["we_gate"],
                       preferred_element_type=acc))
    u = jnp.einsum("gecd,edf->gecf", buffers, p["we_up"],
                   preferred_element_type=acc)
    out_buf = jnp.einsum(
        "gecf,efd->gecd", (g * u).astype(x.dtype), p["we_down"],
        preferred_element_type=acc,
    ).astype(x.dtype)  # (G, E, C, D)

    # --- combine: gather expert outputs back to tokens, weighted ----------
    flat_gate = (gate.reshape(G, gs * cfg.top_k) * keep.astype(gate.dtype))

    def combine_group(buf, slots, gates):
        flat = buf.reshape(E * C, D)
        flat = jnp.concatenate([flat, jnp.zeros((1, D), flat.dtype)], axis=0)
        picked = flat[slots]  # (gs*k, D); dropped slots hit the zero row
        w = picked * gates[:, None].astype(picked.dtype)
        return jnp.sum(w.reshape(gs, cfg.top_k, D), axis=1)

    out = jax.vmap(combine_group)(out_buf, slot, flat_gate)  # (G, gs, D)
    return out.reshape(B, S, D)


def pad_expert_weights(params_layer: dict, n_experts: int, multiple: int = 16) -> dict:
    """Zero-pad the expert dimension of stacked MoE weights to a multiple of
    the model-axis size (router logits for padded experts are masked)."""
    E = padded_experts(n_experts, multiple)
    if E == n_experts:
        return params_layer
    out = dict(params_layer)
    pad = E - n_experts
    for name in ("we_gate", "we_up", "we_down"):
        w = out[name]  # (..., E, d, f)
        e_axis = w.ndim - 3
        widths = [(0, 0)] * w.ndim
        widths[e_axis] = (0, pad)
        out[name] = jnp.pad(w, widths)
    r = out["router"]  # (..., D, E)
    widths = [(0, 0)] * r.ndim
    widths[-1] = (0, pad)
    out["router"] = jnp.pad(r, widths)
    return out
