"""MACE: higher-order equivariant message passing (arXiv:2206.07697),
implemented with Cartesian irreps (l = 0, 1, 2) — the TPU-native adaptation.

Instead of spherical-harmonic CG tables (sparse, scalar-index heavy), features
are kept in Cartesian irrep form:
  h0: (N, C)        scalars            (l = 0)
  h1: (N, C, 3)     vectors            (l = 1)
  h2: (N, C, 3, 3)  traceless symmetric rank-2 tensors (l = 2)

All Clebsch-Gordan couplings become dense tensor algebra (dot, cross, outer,
contraction, symmetric-traceless projection) — exact E(3) equivariance with
MXU-friendly einsums (verified by the rotation property test). The MACE
structure is preserved faithfully:

  * Bessel radial basis (n_rbf) + polynomial cutoff envelope + radial MLP
    producing per-channel, per-path weights (channel-wise tensor product);
  * A-basis: density over neighbours via Y(r_hat) (x) h_j paths,
    edge -> node reduction with ``jax.ops.segment_sum`` (this IS the
    message-passing kernel on TPU — taxonomy §GNN);
  * B-basis: symmetric contractions of A up to correlation order nu = 3
    (A, A(x)A, (A(x)A)(x)A with per-channel path weights);
  * per-layer linear updates + residual, invariant (l=0) readout MLPs,
    per-graph energy via segment_sum over nodes.

Sharding at scale (DESIGN.md §6): the channel axis C is the tensor-parallel
("model") axis — every equivariant product is channel-wise local; only the
channel-mixing linears reduce over C. Edges shard over the data axis; the
edge->node segment_sum becomes local scatter + cross-shard all-reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

Array = jax.Array

_EYE3 = jnp.eye(3)


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    channels: int = 128          # d_hidden
    l_max: int = 2               # fixed: this implementation carries l <= 2
    correlation: int = 3         # correlation order (nu)
    n_rbf: int = 8
    d_feat: int = 1              # raw node-feature dim (embedded to channels)
    r_cut: float = 5.0
    radial_hidden: int = 64
    readout_hidden: int = 16
    dtype: Any = jnp.float32
    remat: bool = False
    # process edges in this many chunks (lax.scan accumulating the A-basis):
    # transient edge tensors shrink by the chunk count — the edge analogue of
    # gradient accumulation, needed for the 62M-edge full-batch shape
    edge_chunks: int = 1

    def param_count(self) -> int:
        # counted from init_params at trace time in benchmarks; rough estimate:
        C = self.channels
        per_layer = (
            self.n_rbf * self.radial_hidden
            + self.radial_hidden * C * _N_A_PATHS
            + C * C * (3 + _N_MSG0 + _N_MSG1 + _N_MSG2)
            + C * self.readout_hidden + self.readout_hidden
        )
        return self.d_feat * C + self.n_layers * per_layer


# path counts (see _product_paths): A-density paths 3+5+4; message inputs are
# [A_l, B2-paths_l, B3_l] = (1+3+1, 1+5+1, 1+4+1) per output l.
_N_A_PATHS = 12
_N_MSG0, _N_MSG1, _N_MSG2 = 5, 7, 6


# -- irrep algebra (all channel-wise; shapes (..., C[, 3[, 3]])) --------------


def sym_traceless(t: Array) -> Array:
    """Project (..., 3, 3) onto the l=2 (symmetric traceless) component."""
    s = 0.5 * (t + jnp.swapaxes(t, -1, -2))
    tr = jnp.trace(s, axis1=-2, axis2=-1)[..., None, None]
    return s - tr * _EYE3 / 3.0


def outer11(a: Array, b: Array) -> Array:
    """(...,3) x (...,3) -> l=2 part of the outer product."""
    return sym_traceless(a[..., :, None] * b[..., None, :])


def dot11(a: Array, b: Array) -> Array:
    return jnp.sum(a * b, axis=-1)


def cross11(a: Array, b: Array) -> Array:
    return jnp.cross(a, b)


def ddot22(a: Array, b: Array) -> Array:
    """double contraction (l2 (x) l2 -> l0)."""
    return jnp.sum(a * b, axis=(-2, -1))


def mat21(t: Array, v: Array) -> Array:
    """(...,3,3) . (...,3) -> (...,3)   (l2 (x) l1 -> l1)."""
    return jnp.einsum("...ij,...j->...i", t, v)


def mat22(a: Array, b: Array) -> Array:
    """l=2 part of the matrix product (l2 (x) l2 -> l2)."""
    return sym_traceless(jnp.einsum("...ij,...jk->...ik", a, b))


def _product_paths(
    u: Tuple[Array, Array, Array], v: Tuple[Array, Array, Array]
) -> Dict[int, list]:
    """All CG-allowed channel-wise products of two irrep triples (l <= 2)."""
    u0, u1, u2 = u
    v0, v1, v2 = v
    to0 = [u0 * v0, dot11(u1, v1), ddot22(u2, v2)]
    to1 = [
        u0[..., None] * v1,
        v0[..., None] * u1,
        cross11(u1, v1),
        mat21(u2, v1),
        mat21(v2, u1),
    ]
    to2 = [
        u0[..., None, None] * v2,
        v0[..., None, None] * u2,
        outer11(u1, v1),
        mat22(u2, v2),
    ]
    return {0: to0, 1: to1, 2: to2}


# -- radial basis --------------------------------------------------------------


def bessel_basis(d: Array, n_rbf: int, r_cut: float) -> Array:
    """sin(n pi d / rc) / d with smooth polynomial cutoff (E: (E, n_rbf))."""
    d = jnp.maximum(d, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    arg = n[None, :] * jnp.pi * d[:, None] / r_cut
    rbf = jnp.sqrt(2.0 / r_cut) * jnp.sin(arg) / d[:, None]
    # polynomial cutoff envelope (p = 5)
    x = jnp.clip(d / r_cut, 0.0, 1.0)
    env = 1.0 - 10.0 * x**3 + 15.0 * x**4 - 6.0 * x**5
    return rbf * env[:, None]


# -- params --------------------------------------------------------------------


def init_params(cfg: MACEConfig, key: jax.Array) -> dict:
    C = cfg.channels
    dt = cfg.dtype
    keys = iter(jax.random.split(key, 8 + 16 * cfg.n_layers))
    params = {"embed": dense_init(next(keys), (cfg.d_feat, C), dtype=dt), "layers": []}
    for _ in range(cfg.n_layers):
        layer = {
            # radial MLP: n_rbf -> hidden -> (n_paths, C) per-edge TP weights;
            # the (P, C) output layout aligns the C axis with the model-shard
            # axis so the per-edge weighting is collective-free (§Perf log)
            "rad_w1": dense_init(next(keys), (cfg.n_rbf, cfg.radial_hidden), dtype=dt),
            "rad_w2": dense_init(
                next(keys), (cfg.radial_hidden, _N_A_PATHS, C), dtype=dt
            ),
            # channel-mixing linears per output l, stored (P, C_in, C_out):
            # contraction runs over the SHARDED C_in (partial sums + one
            # reduce) instead of all-gathering a (N, P*C) concat
            "msg0": dense_init(next(keys), (_N_MSG0, C, C), dtype=dt),
            "msg1": dense_init(next(keys), (_N_MSG1, C, C), dtype=dt),
            "msg2": dense_init(next(keys), (_N_MSG2, C, C), dtype=dt),
            # self-connection linears per l
            "self0": dense_init(next(keys), (C, C), dtype=dt),
            "self1": dense_init(next(keys), (C, C), dtype=dt),
            "self2": dense_init(next(keys), (C, C), dtype=dt),
            # per-channel weights for the nu=2 / nu=3 symmetric contractions
            "w_corr2": dense_init(next(keys), (C,), 1.0, dtype=dt),
            "w_corr3": dense_init(next(keys), (C,), 1.0, dtype=dt),
            # invariant readout
            "ro_w1": dense_init(next(keys), (C, cfg.readout_hidden), dtype=dt),
            "ro_w2": dense_init(next(keys), (cfg.readout_hidden, 1), dtype=dt),
        }
        params["layers"].append(layer)
    return params


# -- forward -------------------------------------------------------------------


def _channel_mix(paths: list, w: Array) -> Array:
    """Mix per-path channel features: sum_p paths[p] @ w[p].

    w: (P, C_in, C_out). Each term contracts over the (model-sharded) C_in —
    local partial sums, ONE cross-shard reduce for the whole mix (vs the
    concat formulation, which all-gathered a full-C (N, P*C, ...) tensor)."""
    out = None
    for i, p in enumerate(paths):
        t = jnp.einsum("nc...,cd->nd...", p, w[i])
        out = t if out is None else out + t
    return out


def forward(
    cfg: MACEConfig,
    params: dict,
    batch: dict,
    *,
    edge_axes: Any = None,     # mesh axis name(s) for the edge dimension
    channel_axes: Any = None,  # mesh axis name(s) for the channel dimension
) -> Array:
    """Per-graph energies.

    batch:
      positions (N, 3) f32; node_feat (N, d_feat); senders/receivers (E,) i32;
      edge_mask (E,) f32 (0 for padding); node_graph (N,) i32 graph id;
      node_mask (N,) f32; n_graphs static int.
    Returns (n_graphs,) energies.
    """
    pos = batch["positions"].astype(jnp.float32)
    send, recv = batch["senders"], batch["receivers"]
    emask = batch["edge_mask"].astype(cfg.dtype)
    nmask = batch["node_mask"].astype(cfg.dtype)
    n_nodes = pos.shape[0]
    C = cfg.channels

    r = pos[recv] - pos[send]  # (E, 3)
    d = jnp.linalg.norm(r, axis=-1)
    rhat = r / jnp.maximum(d, 1e-9)[:, None]
    y1 = rhat.astype(cfg.dtype)  # (E, 3)
    y2 = sym_traceless(rhat[:, :, None] * rhat[:, None, :]).astype(cfg.dtype)
    rbf = bessel_basis(d, cfg.n_rbf, cfg.r_cut).astype(cfg.dtype)  # (E, n_rbf)

    h0 = (batch["node_feat"].astype(cfg.dtype) @ params["embed"])  # (N, C)
    h0 = h0 * nmask[:, None]
    h1 = jnp.zeros((n_nodes, C, 3), cfg.dtype)
    h2 = jnp.zeros((n_nodes, C, 3, 3), cfg.dtype)

    from jax.sharding import PartitionSpec as _P

    def constrain_edge(x, channel_dim: int = 1):
        """Edge-major tensors (E, C, ...): edge dim -> data, channels -> model."""
        if edge_axes is None and channel_axes is None:
            return x
        axes = [None] * x.ndim
        axes[0] = edge_axes
        axes[channel_dim] = channel_axes
        return jax.lax.with_sharding_constraint(x, _P(*axes))

    def constrain_node(x):
        """Node-major tensors (N, C[, 3[, 3]]): channels -> model, nodes local."""
        if channel_axes is None:
            return x
        spec = _P(None, channel_axes, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, spec)

    energy = jnp.zeros((n_nodes,), jnp.float32)

    def edge_pass(layer, h0, h1, h2, send_c, recv_c, y1_c, y2_c, rbf_c,
                  emask_c, d_c):
        """A-basis contribution of one edge chunk (full graph when chunks=1)."""
        # radial TP weights per edge: (E, n_paths, C)
        rw = jnp.einsum("eh,hpc->epc", jax.nn.silu(rbf_c @ layer["rad_w1"]),
                        layer["rad_w2"])
        rw = rw * emask_c[:, None, None]
        rw = constrain_edge(rw, channel_dim=2)

        # sender features gathered to edges (channel-sharded gather is local);
        # without the explicit constraints GSPMD all-gathers the full-C node
        # tensors per use (measured 408 GB/dev on ogb_products — §Perf log)
        s0 = constrain_edge(h0[send_c])
        s1 = constrain_edge(h1[send_c])
        s2 = constrain_edge(h2[send_c])
        ycast = (jnp.ones_like(d_c, cfg.dtype)[:, None], y1_c[:, None, :],
                 y2_c[:, None, :, :])
        prods = _product_paths(ycast, (s0, s1, s2))
        # weight each path per channel, then scatter-reduce to receivers
        a0 = sum(rw[:, i] * p for i, p in enumerate(prods[0]))
        a1 = sum(rw[:, 3 + i][..., None] * p for i, p in enumerate(prods[1]))
        a2 = sum(rw[:, 8 + i][..., None, None] * p for i, p in enumerate(prods[2]))
        a0, a1, a2 = constrain_edge(a0), constrain_edge(a1), constrain_edge(a2)
        # edge -> node reduction: local scatter per (edge, channel) shard +
        # cross-data-shard all-reduce (GSPMD); THE GNN message-passing kernel.
        A0 = jax.ops.segment_sum(a0, recv_c, num_segments=n_nodes)
        A1 = jax.ops.segment_sum(a1, recv_c, num_segments=n_nodes)
        A2 = jax.ops.segment_sum(a2, recv_c, num_segments=n_nodes)
        return constrain_node(A0), constrain_node(A1), constrain_node(A2)

    def one_layer(layer, h0, h1, h2):
        nc = cfg.edge_chunks
        if nc <= 1:
            A0, A1, A2 = edge_pass(layer, h0, h1, h2, send, recv, y1, y2,
                                   rbf, emask, d)
        else:
            # scan over edge chunks: transient edge tensors / nc ("gradient
            # accumulation for edges"); the A accumulators stay node-major
            E = send.shape[0]
            assert E % nc == 0, (E, nc)
            chunk = lambda a: a.reshape((nc, E // nc) + a.shape[1:])
            xs = (chunk(send), chunk(recv), chunk(y1), chunk(y2), chunk(rbf),
                  chunk(emask), chunk(d))

            def body(acc, xc):
                part = edge_pass(layer, h0, h1, h2, *xc)
                return jax.tree.map(jnp.add, acc, part), None

            body = jax.checkpoint(body) if cfg.remat else body
            C_ = cfg.channels
            # f32 accumulators: summing tens of millions of bf16 messages
            # needs the wider accumulator (node-major, so cheap per device)
            init = (
                constrain_node(jnp.zeros((n_nodes, C_), jnp.float32)),
                constrain_node(jnp.zeros((n_nodes, C_, 3), jnp.float32)),
                constrain_node(jnp.zeros((n_nodes, C_, 3, 3), jnp.float32)),
            )
            (A0, A1, A2), _ = jax.lax.scan(body, init, xs)
            A0, A1, A2 = (a.astype(cfg.dtype) for a in (A0, A1, A2))

        # symmetric contractions: nu=2 and nu=3 (B-basis)
        w2 = layer["w_corr2"]
        w3 = layer["w_corr3"]
        B2 = _product_paths((A0, A1, A2), (A0 * w2, A1 * w2[:, None], A2 * w2[:, None, None]))
        B2_0 = sum(B2[0]); B2_1 = sum(B2[1]); B2_2 = sum(B2[2])
        B3 = _product_paths((B2_0, B2_1, B2_2), (A0 * w3, A1 * w3[:, None], A2 * w3[:, None, None]))
        B3_0 = sum(B3[0]); B3_1 = sum(B3[1]); B3_2 = sum(B3[2])

        # messages: channel-mix of [A | B2-paths | B3] per output l; the
        # partial sums reduce once and land back channel-sharded
        m0 = constrain_node(_channel_mix([A0, *B2[0], B3_0], layer["msg0"]))
        m1 = constrain_node(_channel_mix([A1, *B2[1], B3_1], layer["msg1"]))
        m2 = constrain_node(_channel_mix([A2, *B2[2], B3_2], layer["msg2"]))

        # update with self-connection (residual); outputs pinned back to
        # channel-sharded bf16 so the cross-shard reduces are reduce-scatters
        # of cfg.dtype, never full-C f32 all-gathers
        dt = h0.dtype
        h0n = (jnp.einsum("nc,cd->nd", h0, layer["self0"]) + m0).astype(dt)
        h1n = (jnp.einsum("nci,cd->ndi", h1, layer["self1"]) + m1).astype(dt)
        h2n = (jnp.einsum("ncij,cd->ndij", h2, layer["self2"]) + m2).astype(dt)
        h0n = constrain_node(h0n * nmask[:, None])
        h1n = constrain_node(h1n * nmask[:, None, None])
        h2n = constrain_node(h2n * nmask[:, None, None, None])

        # invariant readout
        e = jax.nn.silu(h0n @ layer["ro_w1"]) @ layer["ro_w2"]  # (N, 1)
        return h0n, h1n, h2n, e[:, 0].astype(jnp.float32)

    for layer in params["layers"]:
        fn = jax.checkpoint(one_layer) if cfg.remat else one_layer
        h0, h1, h2, e = fn(layer, h0, h1, h2)
        energy = energy + e * nmask.astype(jnp.float32)

    if batch.get("node_level", False):
        return energy  # (N,) per-node predictions (sampled / full-batch training)
    n_graphs = batch["n_graphs"]
    return jax.ops.segment_sum(energy, batch["node_graph"], num_segments=n_graphs)


def loss_fn(cfg: MACEConfig, params: dict, batch: dict, **kw) -> Tuple[Array, dict]:
    """Regression MSE: graph-level vs target_energy (n_graphs,), or node-level
    vs target_nodes (N,) masked by loss_node_mask (sampled-training roots)."""
    pred = forward(cfg, params, batch, **kw)
    if batch.get("node_level", False):
        target = batch["target_nodes"].astype(jnp.float32)
        mask = batch.get("loss_node_mask", batch["node_mask"]).astype(jnp.float32)
    else:
        target = batch["target_energy"].astype(jnp.float32)
        mask = batch.get("graph_mask")
        mask = jnp.ones_like(pred) if mask is None else mask.astype(jnp.float32)
    se = (pred - target) ** 2 * mask
    loss = jnp.sum(se) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}


def node_descriptors(cfg: MACEConfig, params: dict, batch: dict) -> Array:
    """Invariant per-node descriptors (N, C): the Euclidean metric space the
    nSimplex DR consumes for similarity search over atomic environments."""
    return _final_h0(cfg, params, batch)


def _final_h0(cfg: MACEConfig, params: dict, batch: dict) -> Array:
    pos = batch["positions"].astype(jnp.float32)
    send, recv = batch["senders"], batch["receivers"]
    emask = batch["edge_mask"].astype(cfg.dtype)
    nmask = batch["node_mask"].astype(cfg.dtype)
    n_nodes = pos.shape[0]
    C = cfg.channels
    r = pos[recv] - pos[send]
    d = jnp.linalg.norm(r, axis=-1)
    rhat = r / jnp.maximum(d, 1e-9)[:, None]
    y1 = rhat.astype(cfg.dtype)
    y2 = sym_traceless(rhat[:, :, None] * rhat[:, None, :]).astype(cfg.dtype)
    rbf = bessel_basis(d, cfg.n_rbf, cfg.r_cut).astype(cfg.dtype)
    h0 = (batch["node_feat"].astype(cfg.dtype) @ params["embed"]) * nmask[:, None]
    h1 = jnp.zeros((n_nodes, C, 3), cfg.dtype)
    h2 = jnp.zeros((n_nodes, C, 3, 3), cfg.dtype)
    for layer in params["layers"]:
        rw = jnp.einsum("eh,hpc->epc", jax.nn.silu(rbf @ layer["rad_w1"]),
                        layer["rad_w2"])
        rw = rw * emask[:, None, None]
        s0, s1, s2 = h0[send], h1[send], h2[send]
        ycast = (jnp.ones_like(d, cfg.dtype)[:, None], y1[:, None, :], y2[:, None, :, :])
        prods = _product_paths(ycast, (s0, s1, s2))
        a0 = sum(rw[:, i] * p for i, p in enumerate(prods[0]))
        a1 = sum(rw[:, 3 + i][..., None] * p for i, p in enumerate(prods[1]))
        a2 = sum(rw[:, 8 + i][..., None, None] * p for i, p in enumerate(prods[2]))
        A0 = jax.ops.segment_sum(a0, recv, num_segments=n_nodes)
        A1 = jax.ops.segment_sum(a1, recv, num_segments=n_nodes)
        A2 = jax.ops.segment_sum(a2, recv, num_segments=n_nodes)
        w2, w3 = layer["w_corr2"], layer["w_corr3"]
        B2 = _product_paths((A0, A1, A2), (A0 * w2, A1 * w2[:, None], A2 * w2[:, None, None]))
        B3 = _product_paths(
            (sum(B2[0]), sum(B2[1]), sum(B2[2])),
            (A0 * w3, A1 * w3[:, None], A2 * w3[:, None, None]),
        )
        m0 = _channel_mix([A0, *B2[0], sum(B3[0])], layer["msg0"])
        m1 = _channel_mix([A1, *B2[1], sum(B3[1])], layer["msg1"])
        m2 = _channel_mix([A2, *B2[2], sum(B3[2])], layer["msg2"])
        h0 = (jnp.einsum("nc,cd->nd", h0, layer["self0"]) + m0) * nmask[:, None]
        h1 = (jnp.einsum("nci,cd->ndi", h1, layer["self1"]) + m1) * nmask[:, None, None]
        h2 = (jnp.einsum("ncij,cd->ndij", h2, layer["self2"]) + m2) * nmask[:, None, None, None]
    return h0.astype(jnp.float32)
