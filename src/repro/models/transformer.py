"""Decoder-only transformer family: dense + MoE, GQA, QKV-bias, RoPE,
sliding-window/global alternating layers, logit soft-capping.

Covers the assigned LM architectures:
  qwen2-moe-a2.7b, granite-moe-3b-a800m (MoE), qwen1.5-0.5b, gemma2-2b,
  granite-8b (dense).

Implementation notes:
* **scan-over-layers**: layer params are stacked along a leading axis and the
  stack is consumed by ``lax.scan`` — compile time and HLO size stay flat in
  depth (MaxText-style). Architectures with a repeating layer *pattern*
  (gemma-2 local/global alternation) scan over groups of ``len(pattern)``
  layers so each position keeps a static window size.
* **remat**: the scan body is wrapped in ``jax.checkpoint`` with a selectable
  policy (cfg.remat_policy), the standard memory/compute knob at scale.
* **activation sharding**: strategic ``with_sharding_constraint`` points are
  parameterised by an ``ActShard`` record so the same code runs single-device
  (all None) and under the production mesh.
* decode keeps a **ring buffer** KV cache for sliding-window layers (length
  = window) and a full-length cache for global layers, so the 500k-context
  shape only materialises 500k KV for the global half of the stack.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from . import moe as moe_lib

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ActShard:
    """Activation sharding constraints (None = leave to GSPMD)."""

    tokens: Any = None      # (batch, seq)
    hidden: Any = None      # (batch, seq, d_model)
    logits: Any = None      # (batch, seq, vocab)
    kv_cache: Any = None    # (groups, batch, seq, kv_heads, d_head)

    @staticmethod
    def none() -> "ActShard":
        return ActShard()


def _constrain(x: Array, spec) -> Array:
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None          # default d_model // n_heads
    # MoE (n_experts == 0 -> dense)
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 4096
    # attention / misc
    qkv_bias: bool = False
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    layer_pattern: Tuple[int, ...] = (0,)  # window per position; 0 = global
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    act: str = "silu"
    post_norms: bool = False               # gemma-2 style post-block norms
    norm_plus_one: bool = False            # gemma (1 + w) RMSNorm
    embed_scale: bool = False              # gemma sqrt(d_model) embed scaling
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    remat_policy: str = "minimal"          # none | minimal | dots
    query_chunk: int = 1024
    # unroll the layer scan into a python loop: larger HLO but (a) XLA can
    # optimize across layers and (b) cost_analysis counts every layer (a
    # while-loop body is costed ONCE regardless of trip count — the roofline
    # pass needs unrolled lowering for honest FLOP totals)
    unroll_layers: bool = False
    # gradient accumulation: split the batch into this many microbatches and
    # accumulate grads (activation memory / n_microbatches)
    n_microbatches: int = 1

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rows padded to a mesh-shardable multiple (embedding rows and
        logits shard over the model axis). Padded logits are masked to -inf
        before the softmax, so semantics are unchanged."""
        if self.vocab_size % 256 == 0 or self.vocab_size < 256:
            return self.vocab_size
        return (self.vocab_size + 255) // 256 * 256

    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.pattern_len == 0, (
            self.n_layers, self.layer_pattern)
        return self.n_layers // self.pattern_len

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Total parameters (for MODEL_FLOPS accounting)."""
        dh, H, KV = self.head_dim, self.n_heads, self.n_kv_heads
        attn = self.d_model * dh * (H + 2 * KV) + H * dh * self.d_model
        if self.qkv_bias:
            attn += dh * (H + 2 * KV)
        if self.is_moe:
            ffn = self.d_model * self.n_experts  # router
            ffn += 3 * self.d_model * self.moe_d_ff * self.n_experts
            if self.n_shared_experts:
                ffn += 3 * self.d_model * self.moe_d_ff * self.n_shared_experts
        else:
            ffn = 3 * self.d_model * self.d_ff
        norms = (4 if self.post_norms else 2) * self.d_model
        per_layer = attn + ffn + norms
        embed = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + self.d_model

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        dh, H, KV = self.head_dim, self.n_heads, self.n_kv_heads
        attn = self.d_model * dh * (H + 2 * KV) + H * dh * self.d_model
        ffn = self.d_model * self.n_experts
        ffn += 3 * self.d_model * self.moe_d_ff * (self.top_k + self.n_shared_experts)
        per_layer = attn + ffn + 2 * self.d_model
        embed = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + self.d_model


# -- init ---------------------------------------------------------------------


def init_params(cfg: TransformerConfig, key: jax.Array) -> dict:
    dh, H, KV, D = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    G, PL = cfg.n_groups, cfg.pattern_len
    keys = iter(jax.random.split(key, 64))
    dt = cfg.dtype

    def stack(shape, scale=None):
        return L.dense_init(next(keys), (G, PL) + tuple(shape), scale, dt)

    layer = {
        "wq": stack((D, H * dh)),
        "wk": stack((D, KV * dh)),
        "wv": stack((D, KV * dh)),
        "wo": stack((H * dh, D)),
        "ln1": jnp.zeros((G, PL, D), dt) if cfg.norm_plus_one else jnp.ones((G, PL, D), dt),
        "ln2": jnp.zeros((G, PL, D), dt) if cfg.norm_plus_one else jnp.ones((G, PL, D), dt),
    }
    if cfg.qkv_bias:
        layer["bq"] = jnp.zeros((G, PL, H * dh), dt)
        layer["bk"] = jnp.zeros((G, PL, KV * dh), dt)
        layer["bv"] = jnp.zeros((G, PL, KV * dh), dt)
    if cfg.post_norms:
        zeros = jnp.zeros((G, PL, D), dt)
        layer["ln1_post"] = zeros
        layer["ln2_post"] = zeros
    if cfg.is_moe:
        E, F = moe_lib.padded_experts(cfg.n_experts), cfg.moe_d_ff
        layer["router"] = stack((D, E), scale=D**-0.5)
        layer["we_gate"] = stack((E, D, F))
        layer["we_up"] = stack((E, D, F))
        layer["we_down"] = stack((E, F, D), scale=F**-0.5)
        if cfg.n_shared_experts:
            Fs = F * cfg.n_shared_experts
            layer["ws_gate"] = stack((D, Fs))
            layer["ws_up"] = stack((D, Fs))
            layer["ws_down"] = stack((Fs, D), scale=Fs**-0.5)
            layer["ws_gate_logit"] = stack((D, 1), scale=D**-0.5)
    else:
        layer["w_gate"] = stack((D, cfg.d_ff))
        layer["w_up"] = stack((D, cfg.d_ff))
        layer["w_down"] = stack((cfg.d_ff, D), scale=cfg.d_ff**-0.5)

    params = {
        "embed": L.dense_init(next(keys), (cfg.padded_vocab, D), 1.0, dt),
        "layers": layer,
        "final_norm": jnp.zeros((D,), dt) if cfg.norm_plus_one else jnp.ones((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(next(keys), (D, cfg.padded_vocab), None, dt)
    return params


# -- layer body ---------------------------------------------------------------


def _one_layer(
    cfg: TransformerConfig,
    p: dict,           # single-layer params (leading (G, PL) axes already indexed)
    x: Array,          # (B, S, D)
    positions: Array,  # (B, S)
    window: int,
    kv: Optional[Tuple[Array, Array]] = None,      # cached (k, v): (B, Skv, KV, dh)
    kv_positions: Optional[Array] = None,
) -> Tuple[Array, Tuple[Array, Array]]:
    B, S, D = x.shape
    dh, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    act = L.ActFn(cfg.act)
    npo = cfg.norm_plus_one

    h = L.rms_norm(x, p["ln1"], cfg.norm_eps, plus_one=npo)
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"], preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"], preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"], preferred_element_type=jnp.float32)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, dh).astype(cfg.dtype)
    k = k.reshape(B, S, KV, dh).astype(cfg.dtype)
    v = v.reshape(B, S, KV, dh).astype(cfg.dtype)
    q = L.rope(q, positions, theta=cfg.rope_theta)
    k = L.rope(k, positions, theta=cfg.rope_theta)

    if kv is not None:
        k_all = jnp.concatenate([kv[0], k], axis=1)
        v_all = jnp.concatenate([kv[1], v], axis=1)
        kv_pos = jnp.concatenate([kv_positions, positions], axis=1)
    else:
        k_all, v_all, kv_pos = k, v, positions

    attn = L.attention(
        q, k_all, v_all,
        q_positions=positions, kv_positions=kv_pos,
        causal=True, window=window, attn_softcap=cfg.attn_softcap,
        query_chunk=cfg.query_chunk,
    )
    attn = jnp.einsum(
        "bsf,fd->bsd", attn.reshape(B, S, H * dh), p["wo"],
        preferred_element_type=jnp.float32,
    ).astype(cfg.dtype)
    if cfg.post_norms:
        attn = L.rms_norm(attn, p["ln1_post"], cfg.norm_eps, plus_one=npo)
    x = x + attn

    h = L.rms_norm(x, p["ln2"], cfg.norm_eps, plus_one=npo)
    if cfg.is_moe:
        ffn = moe_lib.moe_ffn(cfg, p, h)
        if cfg.n_shared_experts:
            shared = L.mlp_glu(h, p["ws_gate"], p["ws_up"], p["ws_down"], act)
            gate = jax.nn.sigmoid(
                jnp.einsum("bsd,dk->bsk", h, p["ws_gate_logit"],
                           preferred_element_type=jnp.float32)
            ).astype(cfg.dtype)
            ffn = ffn + gate * shared
    else:
        ffn = L.mlp_glu(h, p["w_gate"], p["w_up"], p["w_down"], act)
    if cfg.post_norms:
        ffn = L.rms_norm(ffn, p["ln2_post"], cfg.norm_eps, plus_one=npo)
    x = x + ffn
    return x, (k, v)


def _scan_groups(cfg: TransformerConfig, body, x, layer_params, extra_xs=None):
    """lax.scan over layer groups, or an unrolled python loop (see
    cfg.unroll_layers). body(x, scanned) -> (x, y); ys are stacked."""
    if not cfg.unroll_layers:
        xs = layer_params if extra_xs is None else (layer_params, extra_xs)
        return jax.lax.scan(body, x, xs)
    ys = []
    for g in range(cfg.n_groups):
        gp = jax.tree.map(lambda a: a[g], layer_params)
        scanned = gp if extra_xs is None else (
            gp, jax.tree.map(lambda a: a[g], extra_xs))
        x, y = body(x, scanned)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *leaves: jnp.stack(leaves, axis=0), *ys)
    else:
        ys = None
    return x, ys


def _remat(cfg: TransformerConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "minimal":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(cfg.remat_policy)


def _lm_logits(cfg: TransformerConfig, params: dict, x: Array) -> Array:
    """Project hidden states to (padded) vocab logits; softcap; mask padding."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x, head,
                        preferred_element_type=jnp.float32)
    logits = L.softcap(logits, cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, -1e30, logits)
    return logits


# -- forward: training --------------------------------------------------------


def forward(
    cfg: TransformerConfig,
    params: dict,
    tokens: Array,  # (B, S) int32
    *,
    shard: ActShard = ActShard.none(),
) -> Array:
    """Token logits (B, S, V)."""
    B, S = tokens.shape
    tokens = _constrain(tokens, shard.tokens)
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    x = _constrain(x, shard.hidden)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def group_body(x, group_params):
        for pos in range(cfg.pattern_len):
            p = jax.tree.map(lambda a: a[pos], group_params)
            x, _ = _one_layer(cfg, p, x, positions, cfg.layer_pattern[pos])
        x = _constrain(x, shard.hidden)
        return x, None

    body = _remat(cfg, group_body)
    x, _ = _scan_groups(cfg, body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    logits = _lm_logits(cfg, params, x)
    return _constrain(logits, shard.logits)


def loss_fn(
    cfg: TransformerConfig,
    params: dict,
    batch: dict,
    *,
    shard: ActShard = ActShard.none(),
) -> Tuple[Array, dict]:
    """Next-token cross entropy. batch: {tokens (B,S), loss_mask (B,S) optional}."""
    tokens = batch["tokens"]
    logits = forward(cfg, params, tokens, shard=shard)  # (B, S, V) f32
    targets = tokens[:, 1:]
    lg = logits[:, :-1]
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    tgt_logit = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = logz - tgt_logit  # (B, S-1)
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(nll) if mask is None else mask[:, 1:].astype(nll.dtype)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "ntokens": jnp.sum(mask)}


# -- serving: prefill + decode ------------------------------------------------


def init_kv_cache(
    cfg: TransformerConfig, batch: int, seq_len: int
) -> dict:
    """Per-pattern-position caches. Sliding-window positions get a ring buffer
    of length min(window, seq_len); global positions a full-length buffer."""
    dh, KV, G = cfg.head_dim, cfg.n_kv_heads, cfg.n_groups
    caches = {}
    for pos, window in enumerate(cfg.layer_pattern):
        slen = min(window, seq_len) if window else seq_len
        caches[f"pos{pos}"] = {
            "k": jnp.zeros((G, batch, slen, KV, dh), cfg.dtype),
            "v": jnp.zeros((G, batch, slen, KV, dh), cfg.dtype),
        }
    return caches


def decode_step(
    cfg: TransformerConfig,
    params: dict,
    cache: dict,
    token: Array,       # (B, 1) int32
    cache_len: Array,   # scalar int32: number of valid cached positions
    *,
    shard: ActShard = ActShard.none(),
) -> Tuple[Array, dict]:
    """One autoregressive step against a KV cache of ``cache_len`` tokens.

    Returns (logits (B, V), updated cache). The sequence axis of global-layer
    caches may be sharded across the mesh (sequence-parallel decode); softmax
    over the sharded axis reduces via GSPMD collectives.
    """
    B = token.shape[0]
    x = params["embed"][token].astype(cfg.dtype)  # (B, 1, D)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    positions = jnp.full((B, 1), cache_len, jnp.int32)

    def group_body(x, scanned):
        group_params, caches = scanned
        new_caches = []
        for pos in range(cfg.pattern_len):
            p = jax.tree.map(lambda a: a[pos], group_params)
            window = cfg.layer_pattern[pos]
            ck, cv = caches[pos]["k"], caches[pos]["v"]
            slen = ck.shape[1]
            if window:
                # ring buffer: slot of the cached token at absolute pos p is
                # p % window; all occupied slots are in-window by construction.
                kv_pos = _ring_positions(cache_len, slen, B)
            else:
                kv_pos = jnp.broadcast_to(
                    jnp.arange(slen, dtype=jnp.int32), (B, slen))
                kv_pos = jnp.where(kv_pos < cache_len, kv_pos, jnp.int32(1 << 30))
            x, (k_new, v_new) = _one_layer(
                cfg, p, x, positions, window,
                kv=(ck, cv), kv_positions=kv_pos,
            )
            if window:
                slot = cache_len % jnp.int32(max(slen, 1))
            else:
                slot = jnp.minimum(cache_len, slen - 1)
            # index dtypes must match exactly (int32 even under x64)
            z = jnp.int32(0)
            slot = slot.astype(jnp.int32)
            ck = jax.lax.dynamic_update_slice(ck, k_new, (z, slot, z, z))
            cv = jax.lax.dynamic_update_slice(cv, v_new, (z, slot, z, z))
            new_caches.append({"k": ck, "v": cv})
        return x, new_caches

    # scan over layer groups; caches are scan xs/ys (leading G axis)
    cache_list = [cache[f"pos{p}"] for p in range(cfg.pattern_len)]
    body = lambda x, sc: group_body(x, sc)
    x, new_cache_list = _scan_groups(cfg, body, x, params["layers"],
                                     extra_xs=cache_list)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    logits = _lm_logits(cfg, params, x)[:, 0]
    new_cache = {f"pos{p}": c for p, c in enumerate(new_cache_list)}
    return _constrain(logits, shard.logits), new_cache


def _ring_positions(cache_len: Array, slen: int, batch: int) -> Array:
    """Absolute position held by each ring-buffer slot (invalid -> far future)."""
    slots = jnp.arange(slen, dtype=jnp.int32)
    # latest absolute position congruent to slot (mod slen) strictly < cache_len
    rem = (cache_len - 1 - slots) % slen
    pos = cache_len - 1 - rem
    pos = jnp.where(pos >= 0, pos, jnp.int32(1 << 30))
    pos = jnp.where(cache_len > 0, pos, jnp.int32(1 << 30))
    return jnp.broadcast_to(pos, (batch, slen))


def prefill(
    cfg: TransformerConfig,
    params: dict,
    tokens: Array,  # (B, S)
    *,
    pad_to: Optional[int] = None,
    shard: ActShard = ActShard.none(),
) -> Tuple[Array, dict]:
    """Run the prompt, returning (last-token logits (B, V), filled KV cache).

    Global-layer caches are padded to ``pad_to`` total positions (headroom for
    subsequent decode steps); sliding-window caches are rolled into the ring
    layout ``decode_step`` expects (position p at slot p % window).
    """
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    x = _constrain(x, shard.hidden)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def group_body(x, group_params):
        caches = []
        for pos in range(cfg.pattern_len):
            p = jax.tree.map(lambda a: a[pos], group_params)
            window = cfg.layer_pattern[pos]
            x, (k, v) = _one_layer(cfg, p, x, positions, window)
            if window:
                if window < S:
                    k, v = k[:, -window:], v[:, -window:]
                    # ring layout: position p lives at slot p % window
                    shift = (S - window) % window
                    k = jnp.roll(k, shift, axis=1)
                    v = jnp.roll(v, shift, axis=1)
            elif pad_to is not None and pad_to > S:
                widths = ((0, 0), (0, pad_to - S), (0, 0), (0, 0))
                k, v = jnp.pad(k, widths), jnp.pad(v, widths)
            caches.append({"k": k, "v": v})
        return _constrain(x, shard.hidden), caches

    body = _remat(cfg, group_body)
    x, cache_list = _scan_groups(cfg, body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    logits = _lm_logits(cfg, params, x[:, -1])
    cache = {f"pos{p}": c for p, c in enumerate(cache_list)}
    return logits, cache


def embeddings(
    cfg: TransformerConfig, params: dict, tokens: Array, **kw
) -> Array:
    """Mean-pooled final hidden states — the metric space the nSimplex DR
    consumes (DESIGN.md §4). (B, d_model)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def group_body(x, group_params):
        for pos in range(cfg.pattern_len):
            p = jax.tree.map(lambda a: a[pos], group_params)
            x, _ = _one_layer(cfg, p, x, positions, cfg.layer_pattern[pos])
        return x, None

    x, _ = jax.lax.scan(group_body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    return jnp.mean(x.astype(jnp.float32), axis=1)
